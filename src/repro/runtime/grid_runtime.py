"""The volunteer-grid training runtime: BOINC middleware x JAX training.

This is the paper's technique as a first-class training feature. A training
run is a BOINC *project*; one microbatch gradient computation is a *job*;
the emulated hosts execute jobs with real JAX compute while the virtual-time
simulator (§9) drives dispatch, deadlines, validation, credit, and churn.

  * jobs:        ("grad", step, shard) with est_flop_count = 6·N·tokens
  * app:         adaptive replication + fuzzy gradient comparator built on
                 the quorum_compare kernel (§3.4 adapted to bf16 tensors)
  * assimilator: accumulates canonical gradients; when a step's shards are
                 all assimilated, applies the AdamW update and submits the
                 next step's jobs (the linear-bounded allocator arbitrates
                 if multiple experiments share the grid)
  * faults:      malicious/erroneous hosts corrupt outputs (SDC model);
                 churned hosts trigger deadline re-dispatch (§4)
  * credit:      PFC accounting doubles as the FLOPs ledger

Replicated instances of a job receive byte-identical data (the pipeline is
deterministic in (shard, step)), so gradient quorum comparison is sound —
the tensor-scale analogue of homogeneous redundancy.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    App,
    AppVersion,
    GridSimulation,
    Job,
    Platform,
    ProjectServer,
    default_cpu_plan_class,
    make_population,
    next_id,
)
from repro.core.simulator import HostSpec
from repro.data.pipeline import DataConfig, make_batch
from repro.models.config import ModelConfig
from repro.models.layers import init_params
from repro.models.transformer import model_spec
from repro.optim.adamw import AdamWConfig, apply_updates, init_state
from repro.runtime.step_builder import make_grad_step


def _tree_to_numpy(tree: Any) -> Any:
    return jax.tree_util.tree_map(lambda x: np.asarray(x, dtype=np.float32), tree)


def grad_comparator(rtol: float = 1e-4, atol: float = 1e-6, max_bad_fraction: float = 1e-6):
    """Fuzzy gradient agreement (§3.4 'within specified tolerances')."""

    def cmp(a: Any, b: Any) -> bool:
        la = jax.tree_util.tree_leaves(a["grads"])
        lb = jax.tree_util.tree_leaves(b["grads"])
        if len(la) != len(lb):
            return False
        bad = 0
        total = 0
        for xa, xb in zip(la, lb):
            if xa.shape != xb.shape:
                return False
            ok = np.isclose(xa, xb, rtol=rtol, atol=atol)
            bad += ok.size - int(np.count_nonzero(ok))
            total += ok.size
        return total == 0 or (bad / total) <= max_bad_fraction

    return cmp


def _grad_corruptor(output: Any, rng) -> Any:
    """SDC model: flip a random scale on one gradient leaf."""
    out = {k: v for k, v in output.items()}
    leaves, treedef = jax.tree_util.tree_flatten(out["grads"])
    idx = rng.randrange(len(leaves))
    noise = 1.0 + 0.5 * rng.random()
    leaves = [l * noise if i == idx else l for i, l in enumerate(leaves)]
    out["grads"] = jax.tree_util.tree_unflatten(treedef, leaves)
    return out


@dataclass
class GridTrainResult:
    losses: List[float]
    steps_completed: int
    metrics: Any  # SimMetrics
    credit_total: Dict[str, float]
    jobs_retried: int
    virtual_time: float

    @property
    def final_loss(self) -> float:
        return self.losses[-1] if self.losses else float("nan")


class GridTrainer:
    """Trains a model through the BOINC grid (virtual time, real compute)."""

    def __init__(
        self,
        cfg: ModelConfig,
        data_cfg: DataConfig,
        opt_cfg: AdamWConfig,
        n_steps: int,
        n_hosts: int = 12,
        seed: int = 0,
        adaptive_replication: bool = True,
        min_quorum: int = 2,
        error_prob: float = 0.0,
        malicious_fraction: float = 0.0,
        availability: float = 1.0,
        churn_rate: float = 0.0,
        delay_bound: float = 4 * 3600.0,
        horizon: float = 90 * 86400.0,
    ) -> None:
        self.cfg = cfg
        self.data_cfg = data_cfg
        self.opt_cfg = opt_cfg
        self.n_steps = n_steps
        self.n_shards = data_cfg.n_shards

        key = jax.random.PRNGKey(seed)
        self.params = init_params(key, model_spec(cfg))
        self.opt_state = init_state(self.params)
        self._grad_fn = jax.jit(make_grad_step(cfg))
        self._apply = jax.jit(
            lambda p, g, s: apply_updates(opt_cfg, p, g, s)
        )

        # tokens per microbatch job -> est_flop_count (§3.3 / §6.3)
        tokens = data_cfg.batch_size * data_cfg.seq_len
        self._est_flops = cfg.train_flops_per_token() * tokens

        self.server = ProjectServer(name="grid-train", purge_delay=1e18)
        comparator = grad_comparator()
        app = App(
            name="grad",
            min_quorum=min_quorum,
            init_ninstances=min_quorum,
            max_error_instances=8,
            max_success_instances=12,
            delay_bound=delay_bound,
            adaptive_replication=adaptive_replication,
            comparator=comparator,
            fraction_done_exact=True,
        )
        for osn in ("windows", "mac", "linux"):
            app.add_version(
                AppVersion(
                    id=next_id("appver"),
                    app_name="grad",
                    platform=Platform(osn, "x86_64"),
                    version_num=1,
                    plan_class=default_cpu_plan_class(),
                )
            )
        self.server.add_app(app)
        self.server.assimilators["grad"] = self._assimilate
        self._app = app

        population = make_population(
            n_hosts,
            seed=seed + 1,
            error_prob=error_prob,
            malicious_fraction=malicious_fraction,
            availability=availability,
            churn_rate=churn_rate,
            horizon=horizon,
        )
        self.sim = GridSimulation(
            self.server,
            population,
            seed=seed + 2,
            executor=self._execute,
            corruptor=_grad_corruptor,
        )
        self.horizon = horizon
        self._grad_cache: Dict[Tuple[int, int], Any] = {}
        self._pending: Dict[int, Dict[int, Any]] = {}  # step -> shard -> grads
        self._job_meta: Dict[int, Tuple[int, int]] = {}  # job_id -> (step, shard)
        self.losses: List[float] = []
        self.steps_completed = 0
        self._delay_bound = delay_bound

    # ------------------------------------------------------------------

    def _submit_step_jobs(self, step: int, now: float) -> None:
        self._pending[step] = {}
        for shard in range(self.n_shards):
            job = Job(
                id=next_id("job"),
                app_name="grad",
                est_flop_count=self._est_flops,
                delay_bound=self._delay_bound,
                submitter="trainer",
                payload=("grad", step, shard),
            )
            self._job_meta[job.id] = (step, shard)
            self.server.submit_job(job, now)

    # ------------------------------------------------------------------

    def _execute(self, job: Job, host) -> Any:
        """Real JAX compute for a job (cached: replicas see identical data,
        hence identical correct results — homogeneous redundancy)."""
        _, step, shard = job.payload
        key = (step, shard)
        if key not in self._grad_cache:
            batch_np = make_batch(self.data_cfg, shard, step)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            grads, metrics = self._grad_fn(self.params, batch)
            self._grad_cache[key] = {
                "grads": _tree_to_numpy(grads),
                "loss": float(metrics["loss"]),
            }
        return self._grad_cache[key]

    # ------------------------------------------------------------------

    def _assimilate(self, job: Job, output: Any) -> None:
        meta = self._job_meta.get(job.id)
        if meta is None:
            return
        step, shard = meta
        if output is None:
            # job failed outright (error limits): resubmit the (step, shard)
            if shard not in self._pending.get(step, {}):
                replacement = Job(
                    id=next_id("job"),
                    app_name="grad",
                    est_flop_count=self._est_flops,
                    delay_bound=self._delay_bound,
                    submitter="trainer",
                    payload=("grad", step, shard),
                )
                self._job_meta[replacement.id] = (step, shard)
                self.server.submit_job(replacement, self.sim.now)
            return
        bucket = self._pending.get(step)
        if bucket is None or shard in bucket:
            return
        bucket[shard] = output
        if len(bucket) == self.n_shards and step == self.steps_completed:
            self._apply_step(step)

    def _apply_step(self, step: int) -> None:
        bucket = self._pending.pop(step)
        outs = [bucket[s] for s in range(self.n_shards)]
        loss = float(np.mean([o["loss"] for o in outs]))
        grads = jax.tree_util.tree_map(
            lambda *xs: jnp.asarray(np.mean(np.stack(xs), axis=0)),
            *[o["grads"] for o in outs],
        )
        self.params, self.opt_state, _ = self._apply(self.params, grads, self.opt_state)
        self.losses.append(loss)
        self.steps_completed = step + 1
        # free the cache for this step (file deleter analogue)
        for shard in range(self.n_shards):
            self._grad_cache.pop((step, shard), None)
        if self.steps_completed < self.n_steps:
            self._submit_step_jobs(self.steps_completed, self.sim.now)

    # ------------------------------------------------------------------

    def run(self) -> GridTrainResult:
        self._submit_step_jobs(0, 0.0)
        # run in windows so we can stop as soon as training finishes
        window = 6 * 3600.0
        t = 0.0
        while self.steps_completed < self.n_steps and t < self.horizon:
            t = min(self.horizon, t + window)
            self.sim.run(t)
        self.sim.audit_validation()
        retries = sum(tr.metrics.retries_created for tr in self.server.transitioners)
        return GridTrainResult(
            losses=self.losses,
            steps_completed=self.steps_completed,
            metrics=self.sim.metrics,
            credit_total=dict(self.server.credit.total),
            jobs_retried=retries,
            virtual_time=self.sim.now,
        )
