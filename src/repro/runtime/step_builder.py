"""Builds jitted, sharded train / prefill / decode steps for any
(architecture x shape x mesh) cell — the single entry point used by the
trainer, the server, the dry-run, and the benchmarks.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, no device allocation) — the dry-run lowers
against these.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.logical import logical_sharding_scope
from repro.distributed.sharding import (
    ShardingRules,
    batch_specs,
    make_rules,
    param_specs,
    tree_specs_from_axes,
)
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.layers import abstract_params
from repro.models.transformer import (
    cache_axes,
    cache_spec,
    forward,
    model_spec,
    train_loss,
)
from repro.optim.adamw import AdamWConfig, AdamWState, apply_updates, init_state


# ---------------------------------------------------------------------------
# Input specs (dry-run stand-ins)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for every input of the step kind of ``shape``."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch: Dict[str, Any] = {"labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.input_mode == "embeds":
            batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {}
        if cfg.input_mode == "embeds":
            batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        out = {"batch": batch}
        if cfg.has_decode:
            out["cache"] = cache_spec(cfg, b, s)
        return out
    if shape.kind == "decode":
        return {
            "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "cache": cache_spec(cfg, b, s),
            "index": jax.ShapeDtypeStruct((), jnp.int32),
        }
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------


def make_train_step(
    cfg: ModelConfig, opt_cfg: AdamWConfig
) -> Callable[..., Tuple[Any, AdamWState, Dict[str, jax.Array]]]:
    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: train_loss(p, cfg, batch), has_aux=True
        )(params)
        new_params, new_opt, opt_metrics = apply_updates(opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, **parts, **opt_metrics}
        return new_params, new_opt, metrics

    return train_step


def make_grad_step(cfg: ModelConfig) -> Callable[..., Tuple[Any, Dict[str, jax.Array]]]:
    """Gradient-only step — the grid runtime's microbatch job body."""

    def grad_step(params, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: train_loss(p, cfg, batch), has_aux=True
        )(params)
        return grads, {"loss": loss, **parts}

    return grad_step


def make_prefill_step(cfg: ModelConfig) -> Callable[..., Tuple[jax.Array, Any]]:
    def prefill_step(params, batch, cache):
        logits, new_cache, _ = forward(
            params,
            cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            cache=cache,
            cache_index=jnp.asarray(0, jnp.int32),
        )
        return logits[:, -1:, :], new_cache

    return prefill_step


def make_encoder_step(cfg: ModelConfig) -> Callable[..., jax.Array]:
    """Encoder-only forward (hubert prefill cells)."""

    def encoder_step(params, batch):
        logits, _, _ = forward(
            params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds")
        )
        return logits

    return encoder_step


def make_decode_step(cfg: ModelConfig) -> Callable[..., Tuple[jax.Array, Any]]:
    def decode_step(params, tokens, cache, index):
        logits, new_cache, _ = forward(
            params, cfg, tokens=tokens, cache=cache, cache_index=index
        )
        return logits, new_cache

    return decode_step


# ---------------------------------------------------------------------------
# Sharded (jitted) step bundles
# ---------------------------------------------------------------------------


@dataclass
class StepBundle:
    """Everything needed to run/lower one (arch, shape, mesh) cell."""

    cfg: ModelConfig
    shape: ShapeConfig
    mesh: Mesh
    rules: ShardingRules
    fn: Callable  # the jitted step
    in_specs: Tuple[Any, ...]  # ShapeDtypeStructs, in call order
    param_pspecs: Any
    kind: str

    def _spec_fn(self):
        mesh, rules = self.mesh, self.rules

        def spec_fn(shape, axes):
            return NamedSharding(mesh, rules.spec_for(shape, axes))

        return spec_fn

    def lower(self):
        # the logical-constraint scope must be active while jit traces
        with logical_sharding_scope(self._spec_fn()):
            return self.fn.lower(*self.in_specs)

    def __call__(self, *args):
        with logical_sharding_scope(self._spec_fn()):
            return self.fn(*args)


def _sharding(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def build_step(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    opt_cfg: Optional[AdamWConfig] = None,
    rules_overrides: Optional[Dict[str, Tuple[str, ...]]] = None,
    donate: bool = True,
) -> StepBundle:
    """Construct the jitted sharded step for one cell."""
    rules = make_rules(mesh, rules_overrides)
    spec_tree = model_spec(cfg)
    p_abstract = abstract_params(spec_tree, cfg.param_dtype)
    p_pspecs = param_specs(rules, spec_tree)
    p_shardings = _sharding(mesh, p_pspecs)
    ins = input_specs(cfg, shape)

    if shape.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()
        step = make_train_step(cfg, opt_cfg)
        opt_abstract = jax.eval_shape(init_state, p_abstract)
        opt_pspecs = AdamWState(count=P(), mu=p_pspecs, nu=p_pspecs)
        opt_shardings = AdamWState(
            count=NamedSharding(mesh, P()),
            mu=_sharding(mesh, p_pspecs),
            nu=_sharding(mesh, p_pspecs),
        )
        b_pspecs = batch_specs(rules, ins["batch"])
        b_shardings = _sharding(mesh, b_pspecs)
        fn = jax.jit(
            step,
            in_shardings=(p_shardings, opt_shardings, b_shardings),
            out_shardings=(p_shardings, opt_shardings, None),
            donate_argnums=(0, 1) if donate else (),
        )
        return StepBundle(
            cfg, shape, mesh, rules, fn,
            (p_abstract, opt_abstract, ins["batch"]), p_pspecs, "train",
        )

    if shape.kind == "prefill":
        b_pspecs = batch_specs(rules, ins["batch"])
        b_shardings = _sharding(mesh, b_pspecs)
        if not cfg.has_decode:
            step = make_encoder_step(cfg)
            fn = jax.jit(
                step,
                in_shardings=(p_shardings, b_shardings),
                out_shardings=None,
            )
            return StepBundle(
                cfg, shape, mesh, rules, fn, (p_abstract, ins["batch"]), p_pspecs, "prefill"
            )
        c_axes = cache_axes(cfg)
        c_pspecs = tree_specs_from_axes(rules, ins["cache"], c_axes)
        c_shardings = _sharding(mesh, c_pspecs)
        step = make_prefill_step(cfg)
        fn = jax.jit(
            step,
            in_shardings=(p_shardings, b_shardings, c_shardings),
            out_shardings=(None, c_shardings),
            donate_argnums=(2,) if donate else (),
        )
        return StepBundle(
            cfg, shape, mesh, rules, fn,
            (p_abstract, ins["batch"], ins["cache"]), p_pspecs, "prefill",
        )

    if shape.kind == "decode":
        c_axes = cache_axes(cfg)
        c_pspecs = tree_specs_from_axes(rules, ins["cache"], c_axes)
        c_shardings = _sharding(mesh, c_pspecs)
        tok_sharding = NamedSharding(
            mesh, rules.spec_for((shape.global_batch, 1), ("batch", None))
        )
        step = make_decode_step(cfg)
        fn = jax.jit(
            step,
            in_shardings=(
                p_shardings,
                tok_sharding,
                c_shardings,
                NamedSharding(mesh, P()),
            ),
            out_shardings=(None, c_shardings),
            donate_argnums=(2,) if donate else (),
        )
        return StepBundle(
            cfg, shape, mesh, rules, fn,
            (p_abstract, ins["tokens"], ins["cache"], ins["index"]),
            p_pspecs, "decode",
        )

    raise ValueError(shape.kind)


def model_flops_for_cell(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS for the roofline table."""
    if shape.kind == "train":
        return cfg.train_flops_per_token() * shape.tokens
    if shape.kind == "prefill":
        per = cfg.train_flops_per_token() / 3.0  # forward only: 2·N
        return per * shape.tokens
    # decode: one token per sequence against a seq_len context
    return cfg.decode_flops_per_token(context=shape.seq_len) * shape.global_batch
