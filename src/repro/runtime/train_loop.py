"""Single-process training driver (the synchronous SPMD limit case).

The volunteer-grid (asynchronous, fault-tolerant) driver lives in
``grid_runtime.py``; this loop is what each *worker* runs internally, and
what the quickstart example uses. Checkpoint/restart follows the paper's
request/ack protocol (checkpoint/checkpointer.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer, CheckpointPolicy
from repro.data.pipeline import DataConfig, global_batch
from repro.models.config import ModelConfig
from repro.models.layers import init_params
from repro.models.transformer import model_spec
from repro.optim.adamw import AdamWConfig, apply_updates, init_state
from repro.runtime.step_builder import make_train_step


@dataclass
class TrainResult:
    steps: int
    losses: List[float]
    wall_time: float
    restored_from: Optional[int] = None

    @property
    def final_loss(self) -> float:
        return self.losses[-1] if self.losses else float("nan")


def train(
    cfg: ModelConfig,
    data_cfg: DataConfig,
    opt_cfg: AdamWConfig,
    steps: int,
    seed: int = 0,
    checkpoint_dir: Optional[str] = None,
    checkpoint_period: int = 50,
    log_every: int = 10,
    log_fn: Callable[[str], None] = print,
    resume: bool = True,
) -> TrainResult:
    key = jax.random.PRNGKey(seed)
    spec = model_spec(cfg)
    params = init_params(key, spec)
    opt_state = init_state(params)
    start_step = 0
    restored = None

    ckpt = Checkpointer(checkpoint_dir) if checkpoint_dir else None
    policy = CheckpointPolicy(period_steps=checkpoint_period)
    if ckpt is not None and resume and ckpt.latest_step() is not None:
        start_step, trees = ckpt.restore({"params": params, "opt": opt_state})
        params, opt_state = trees["params"], trees["opt"]
        restored = start_step
        log_fn(f"[train] restored checkpoint at step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    losses: List[float] = []
    t0 = time.time()
    for step in range(start_step, steps):
        batch_np = global_batch(data_cfg, step)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if log_every and (step % log_every == 0 or step == steps - 1):
            log_fn(
                f"[train] step={step} loss={loss:.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} lr={float(metrics['lr']):.2e}"
            )
        if ckpt is not None and policy.should_checkpoint(step + 1):
            # masked section: checkpoint only at the step boundary (§3.6)
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
            policy.ack(step + 1)
    return TrainResult(
        steps=steps - start_step,
        losses=losses,
        wall_time=time.time() - t0,
        restored_from=restored,
    )
