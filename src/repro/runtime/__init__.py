from .grid_runtime import GridTrainer, GridTrainResult, grad_comparator
from .serve_loop import BatchServer, Request, ServeMetrics
from .step_builder import (
    StepBundle,
    build_step,
    input_specs,
    make_decode_step,
    make_encoder_step,
    make_grad_step,
    make_prefill_step,
    make_train_step,
    model_flops_for_cell,
)
from .train_loop import TrainResult, train

__all__ = [
    "BatchServer",
    "GridTrainResult",
    "GridTrainer",
    "Request",
    "ServeMetrics",
    "StepBundle",
    "TrainResult",
    "build_step",
    "grad_comparator",
    "input_specs",
    "make_decode_step",
    "make_encoder_step",
    "make_grad_step",
    "make_prefill_step",
    "make_train_step",
    "model_flops_for_cell",
    "train",
]
