"""Batched serving driver: continuous batching over a shared KV cache.

Requests are BOINC-style jobs with deadlines: the admission queue is
ordered EDF (the paper's §10.7 low-latency direction, implemented here as a
basic working version), admission joins the running batch at slot
granularity, and each decode step advances every live slot by one token.
Non-replicated (serving results are user-visible and latency-bound;
validation spot-checks can be layered via the grid runtime if desired).
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import init_cache
from repro.runtime.step_builder import make_decode_step, make_prefill_step


@dataclass
class Request:
    id: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    deadline: float = float("inf")  # EDF admission (§10.7)
    submitted_at: float = 0.0
    tokens_out: List[int] = field(default_factory=list)
    started_at: Optional[float] = None
    finished_at: Optional[float] = None


@dataclass
class ServeMetrics:
    requests_done: int = 0
    tokens_generated: int = 0
    total_latency: float = 0.0
    decode_steps: int = 0
    wall_time: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_generated / self.wall_time if self.wall_time else 0.0

    @property
    def mean_latency(self) -> float:
        return self.total_latency / self.requests_done if self.requests_done else 0.0


class AdmissionQueue:
    """EDF priority queue for admission (§10.7).

    A binary heap keyed ``(deadline, seq)``: ``pop`` is the
    earliest-deadline request, and the monotone submission sequence breaks
    deadline ties FIFO — the same order the old ``list.sort`` (stable) +
    ``pop(0)`` produced, at O(log n) per operation instead of an O(n log n)
    re-sort on every admission pass."""

    def __init__(self) -> None:
        self._heap: List[Tuple[float, int, Request]] = []
        self._seq = 0

    def push(self, req: Request) -> None:
        heapq.heappush(self._heap, (req.deadline, self._seq, req))
        self._seq += 1

    def pop(self) -> Request:
        return heapq.heappop(self._heap)[2]

    def __len__(self) -> int:
        return len(self._heap)


class BatchServer:
    """Slot-based continuous batching with a fixed decode batch."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        batch_slots: int = 4,
        max_seq: int = 256,
    ) -> None:
        assert cfg.has_decode, "encoder-only archs don't serve decode"
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self._decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))
        self._prefill_cache: Dict[int, Any] = {}
        self.queue = AdmissionQueue()
        self.metrics = ServeMetrics()

    def submit(self, req: Request) -> None:
        self.queue.push(req)

    # -- single-sequence prefill into a slot cache, then batched decode --

    def run(self, max_steps: int = 10_000) -> ServeMetrics:
        t0 = time.time()
        # one shared cache batch; slot i holds request i of the active set
        cache = init_cache(self.cfg, self.slots, self.max_seq)
        active: List[Optional[Request]] = [None] * self.slots
        lengths = np.zeros((self.slots,), np.int32)
        prefill = jax.jit(make_prefill_step(self.cfg))
        steps = 0

        def admit() -> None:
            # EDF: earliest-deadline-first admission (§10.7)
            for i in range(self.slots):
                if active[i] is None and self.queue:
                    req = self.queue.pop()
                    req.started_at = time.time()
                    # per-slot prefill (batch=1) then merge into the batch cache
                    s = len(req.prompt)
                    one = init_cache(self.cfg, 1, self.max_seq)
                    toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
                    logits, one = prefill(self.params, {"tokens": toks}, one)
                    nxt = int(jnp.argmax(logits[0, -1, : self.cfg.vocab]))
                    req.tokens_out.append(nxt)
                    nonlocal cache
                    cache = _merge_slot(cache, one, i)
                    active[i] = req
                    lengths[i] = s

        while steps < max_steps:
            admit()
            if all(a is None for a in active):
                break
            # batched decode step at the max current index
            toks = np.zeros((self.slots, 1), np.int32)
            for i, req in enumerate(active):
                if req is not None and req.tokens_out:
                    toks[i, 0] = req.tokens_out[-1]
            idx = int(lengths.max())
            logits, cache = self._decode(
                self.params, jnp.asarray(toks), cache, jnp.asarray(idx, jnp.int32)
            )
            steps += 1
            self.metrics.decode_steps += 1
            nxt = np.asarray(jnp.argmax(logits[:, 0, : self.cfg.vocab], axis=-1))
            for i, req in enumerate(active):
                if req is None:
                    continue
                req.tokens_out.append(int(nxt[i]))
                lengths[i] += 1
                self.metrics.tokens_generated += 1
                done = (
                    len(req.tokens_out) >= req.max_new_tokens
                    or lengths[i] >= self.max_seq - 2
                )
                if done:
                    req.finished_at = time.time()
                    self.metrics.requests_done += 1
                    self.metrics.total_latency += req.finished_at - (req.started_at or t0)
                    active[i] = None
        self.metrics.wall_time = time.time() - t0
        return self.metrics


def _merge_slot(batch_cache: Any, one_cache: Any, slot: int) -> Any:
    """Copy a single-sequence cache into slot ``slot`` of the batch cache.

    Cache layouts put batch right after the stacked layer axes; SSM leaves
    are (L, B, ...) and attention leaves (L, B, S, ...), hybrid adds a
    groups axis — in all cases the batch axis is the first axis whose size
    differs between the two trees.

    ``dynamic_update_slice_in_dim`` writes only the target slot on-device;
    no leaf is ever pulled to the host, so the merge stays traceable (it
    works under ``jax.jit``) and never round-trips the full cache."""

    def one(bc, oc):
        bc = jnp.asarray(bc)
        oc = jnp.asarray(oc)
        for ax in range(bc.ndim):
            if bc.shape[ax] != oc.shape[ax]:
                return jax.lax.dynamic_update_slice_in_dim(bc, oc, slot, axis=ax)
        return bc  # identical shapes (shouldn't happen for B>1)

    return jax.tree_util.tree_map(one, batch_cache, one_cache)
