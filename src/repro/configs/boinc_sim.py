"""The paper's own configuration: the volunteer-grid simulation defaults.

Numbers from §1.1 of the paper: ~700,000 active devices, 4M CPU cores,
average 16.5 CPU GigaFLOPS and 11.4 GB RAM, desktop availability ~60%,
85/7/7 Windows/Mac/Linux split; per-project scale like SETI@home /
Einstein@Home (~1 PetaFLOPS each). Simulations scale the population down
while keeping the per-host statistics.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class BoincSimConfig:
    # per-host statistics (§1.1)
    cpu_gflops_mean: float = 16.5
    ram_gb_mean: float = 11.4
    ncpus: int = 6  # ~4M cores / 700k devices
    availability_desktop: float = 0.6
    availability_mobile: float = 0.4
    os_split_windows: float = 0.85
    os_split_mac: float = 0.07
    os_split_linux: float = 0.07
    # replication defaults (§3.4, §4)
    min_quorum: int = 2
    init_ninstances: int = 2
    max_error_instances: int = 3
    max_success_instances: int = 6
    delay_bound_days: float = 14.0
    adaptive_threshold: int = 10
    # server (§5.1)
    job_cache_slots: int = 1024
    # client (§6.2)
    buffer_lo_days: float = 0.1
    buffer_hi_days: float = 0.5
    time_slice_s: float = 3600.0
    rpc_poll_s: float = 600.0


CONFIG = BoincSimConfig()
