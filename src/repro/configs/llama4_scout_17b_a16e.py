"""llama4-scout-17b-a16e [moe] — MoE top-1 + shared expert, early fusion,
hf:meta-llama/Llama-4-Scout-17B-16E.

48L d_model=5120 40H (GQA kv=8) expert d_ff=8192 vocab=202048,
MoE 16 experts top-1 (+1 shared expert). Early-fusion vision tower is a
frontend stub per the assignment. Full attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    d_expert=8192,
    vocab=202048,
    n_experts=16,
    top_k=1,
    n_shared_experts=1,
    rope_theta=500_000.0,
)

SMOKE_CONFIG = CONFIG.scaled(
    name="llama4-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    d_expert=128,
    vocab=512,
    n_experts=4,
    top_k=1,
    n_shared_experts=1,
    attn_chunk=32,
    remat=False,
)
