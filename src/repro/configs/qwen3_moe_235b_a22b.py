"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, hf:Qwen/Qwen3-235B-A22B
(family hf:Qwen/Qwen3-30B-A3B).

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936,
MoE 128e top-8, qk_norm. Full attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    d_expert=1536,
    vocab=151936,
    n_experts=128,
    top_k=8,
    qk_norm=True,
    rope_theta=1_000_000.0,
)

SMOKE_CONFIG = CONFIG.scaled(
    name="qwen3-moe-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=64,
    d_expert=64,
    vocab=512,
    n_experts=8,
    top_k=2,
    attn_chunk=32,
    remat=False,
)
