"""command-r-plus-104b [dense] — GQA, no-bias, hf:CohereForAI/c4ai-command-r-plus.

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000; head_dim=128.
The FSDP+TP sharding stress case of the pool. Full attention ->
long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab=256000,
    rope_theta=75_000_000.0,
)

SMOKE_CONFIG = CONFIG.scaled(
    name="command-r-smoke",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab=512,
    attn_chunk=32,
    remat=False,
)
