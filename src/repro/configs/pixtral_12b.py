"""pixtral-12b [vlm] — pixtral-ViT + mistral-nemo backbone,
hf:mistralai/Pixtral-12B-2409.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072; head_dim=128.
The ViT patchifier is a frontend STUB: train/prefill consume precomputed
patch+text embeddings from ``input_specs()``; decode embeds text tokens.
Full attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    input_mode="embeds",
    rope_theta=1_000_000.0,
)

SMOKE_CONFIG = CONFIG.scaled(
    name="pixtral-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    attn_chunk=32,
    remat=False,
)
