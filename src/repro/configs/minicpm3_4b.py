"""minicpm3-4b [dense] — MLA, hf:openbmb/MiniCPM3-4B.

62L d_model=2560 40H (GQA kv=40 via MLA) d_ff=6400 vocab=73448.
MLA ranks from the HF config: q_lora_rank=768, kv_lora_rank=256,
qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64.
Full attention -> long_500k skipped (DESIGN.md §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab=73448,
    attention="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
)

SMOKE_CONFIG = CONFIG.scaled(
    name="minicpm3-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    q_lora_rank=32,
    kv_lora_rank=16,
    qk_nope_dim=16,
    qk_rope_dim=8,
    v_head_dim=16,
    attn_chunk=32,
    remat=False,
)
