"""Architecture registry: one module per assigned architecture.

``get_config(arch)`` returns the FULL published config (exercised only via
the dry-run); ``get_smoke_config(arch)`` returns the reduced same-family
config used by CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCHS: List[str] = [
    "mamba2-130m",
    "minicpm3-4b",
    "qwen3-0.6b",
    "command-r-plus-104b",
    "phi4-mini-3.8b",
    "llama4-scout-17b-a16e",
    "qwen3-moe-235b-a22b",
    "pixtral-12b",
    "hubert-xlarge",
    "zamba2-1.2b",
]

_MODULES: Dict[str, str] = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE_CONFIG
