"""hubert-xlarge [audio] — encoder-only (w2v2 arch), arXiv:2106.07447.

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (masked-prediction
cluster codebook). The conv feature encoder is a frontend STUB: inputs are
precomputed 20ms frame embeddings. Encoder-only: no decode step ->
decode_32k and long_500k skipped per the assignment.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
    encoder_only=True,
    input_mode="embeds",
    tie_embeddings=False,
)

SMOKE_CONFIG = CONFIG.scaled(
    name="hubert-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=64,
    attn_chunk=32,
    remat=False,
)
