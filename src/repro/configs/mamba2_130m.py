"""mamba2-130m [ssm] — SSD (state-space duality), arXiv:2405.21060.

24L d_model=768 attn-free d_ff=0 vocab=50280, ssm_state=128.
Runs long_500k (recurrent state is O(1) in sequence length).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    vocab=50280,
    attention="none",
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_chunk=256,
)

SMOKE_CONFIG = CONFIG.scaled(
    name="mamba2-smoke",
    n_layers=2,
    d_model=64,
    vocab=512,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_chunk=16,
    remat=False,
)
