"""qwen3-0.6b [dense] — qk_norm + GQA, hf:Qwen/Qwen3-0.6B (family hf:Qwen/Qwen3-8B).

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936; head_dim=128
(Qwen3 decouples head_dim from d_model/n_heads). Full attention ->
long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
)

SMOKE_CONFIG = CONFIG.scaled(
    name="qwen3-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    attn_chunk=32,
    remat=False,
)
