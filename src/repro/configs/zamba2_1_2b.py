"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks,
arXiv:2411.15242.

38 Mamba2 layers, d_model=2048, ssm_state=64; ONE weight-tied transformer
block (32H GQA kv=32, d_ff=8192) applied after every 6 mamba layers
(6 applications + 2 tail mamba layers). vocab=32000.
Hybrid/sub-quadratic -> runs long_500k.

Simplification noted per DESIGN.md: Zamba2 adds per-invocation LoRA deltas
on the shared block; we weight-tie exactly (the memory-saving mechanism the
paper's arch is known for) and omit the LoRA deltas.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    shared_attn_period=6,
)

SMOKE_CONFIG = CONFIG.scaled(
    name="zamba2-smoke",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=512,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_chunk=16,
    shared_attn_period=2,
    attn_chunk=32,
    remat=False,
)
