"""Asyncio service surface for the project server (§5.1).

The core engines are synchronous and virtual-time; this package puts a
network front on them without perturbing their determinism:

  protocol — newline-delimited wire codec (requests, replies, error frames)
  server   — asyncio TCP service coalescing concurrent RPCs into per-shard
             ``rpc_batch`` waves
  loadgen  — async load generator (10k–100k simulated clients) recording
             RPC/s and tail latency for BENCH_rpc.json
"""
from .loadgen import LoadReport, run_load
from .protocol import (
    MAX_LINE,
    ErrorReply,
    JobOffer,
    PingRequest,
    PongReply,
    ProtocolError,
    StatsReply,
    StatsRequest,
    WorkReply,
    WorkRequest,
    decode_reply,
    decode_request,
    encode_reply,
    encode_request,
    reply_to_wire,
)
from .server import SchedulerService

__all__ = [
    "ErrorReply",
    "JobOffer",
    "LoadReport",
    "MAX_LINE",
    "PingRequest",
    "PongReply",
    "ProtocolError",
    "SchedulerService",
    "StatsReply",
    "StatsRequest",
    "WorkReply",
    "WorkRequest",
    "decode_reply",
    "decode_request",
    "encode_reply",
    "encode_request",
    "reply_to_wire",
    "run_load",
]
