"""Async load generator for the scheduler service.

Simulates *n_clients* volunteer hosts multiplexed over a small pool of TCP
connections (real volunteer fleets are many hosts behind few concurrent
sockets, and an OS fd table does not enjoy 100k sockets either).  Each
connection owns a reader task that resolves pipelined replies back to the
awaiting client coroutine by sequence number.

Deterministic on purpose: hosts issue identical WORK requests (host id
aside), there is no randomness, and latency measurement is the only use of
the wall clock.
"""
from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from ..core.scheduler import ResourceRequest, ScheduleRequest
from ..core.types import ResourceType
from .protocol import (
    ErrorReply,
    WorkReply,
    WorkRequest,
    decode_reply,
    encode_request,
)


@dataclass
class LoadReport:
    n_clients: int
    requests: int
    replies: int
    errors: int
    jobs_received: int
    wall_s: float
    rpcs_per_s: float
    p50_ms: float
    p95_ms: float
    p99_ms: float


@dataclass
class _Conn:
    reader: asyncio.StreamReader
    writer: asyncio.StreamWriter
    pending: Dict[int, asyncio.Future] = field(default_factory=dict)
    task: Optional[asyncio.Task] = None


def _percentile(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


async def _reader_loop(conn: _Conn) -> None:
    try:
        while True:
            raw = await conn.reader.readline()
            if not raw:
                break
            rep = decode_reply(raw.decode().rstrip("\r\n"))
            fut = conn.pending.pop(rep.seq, None)
            if fut is not None and not fut.done():
                fut.set_result(rep)
    except (ConnectionError, asyncio.CancelledError):
        pass


async def run_load(
    host: str,
    port: int,
    *,
    n_clients: int,
    requests_per_client: int = 1,
    n_conns: int = 64,
    req_runtime: float = 1.0,
    usable_disk: float = 1e12,
    host_ids: Optional[Sequence[int]] = None,
) -> LoadReport:
    """Drive the service with ``n_clients`` concurrent hosts and report
    throughput plus tail latency."""
    n_conns = max(1, min(n_conns, n_clients))
    conns: List[_Conn] = []
    for _ in range(n_conns):
        r, w = await asyncio.open_connection(host, port)
        conn = _Conn(reader=r, writer=w)
        conn.task = asyncio.create_task(_reader_loop(conn))
        conns.append(conn)

    seq_counter = 0
    latencies: List[float] = []
    counts = {"requests": 0, "replies": 0, "errors": 0, "jobs": 0}
    loop = asyncio.get_event_loop()

    async def client(i: int) -> None:
        nonlocal seq_counter
        hid = host_ids[i % len(host_ids)] if host_ids else i + 1
        conn = conns[i % n_conns]
        for _ in range(requests_per_client):
            seq_counter += 1
            seq = seq_counter
            sched = ScheduleRequest(
                host_id=hid,
                requests={
                    ResourceType.CPU: ResourceRequest(req_runtime=req_runtime)
                },
                usable_disk=usable_disk,
            )
            line = encode_request(WorkRequest(seq=seq, request=sched))
            fut = loop.create_future()
            conn.pending[seq] = fut
            counts["requests"] += 1
            t0 = time.perf_counter()
            conn.writer.write((line + "\n").encode())
            await conn.writer.drain()
            rep = await fut
            latencies.append(time.perf_counter() - t0)
            if isinstance(rep, WorkReply):
                counts["replies"] += 1
                counts["jobs"] += len(rep.jobs)
            elif isinstance(rep, ErrorReply):
                counts["errors"] += 1

    t_start = time.perf_counter()
    await asyncio.gather(*(client(i) for i in range(n_clients)))
    wall = time.perf_counter() - t_start

    for conn in conns:
        if conn.task is not None:
            conn.task.cancel()
        try:
            conn.writer.close()
        except Exception:
            pass

    latencies.sort()
    return LoadReport(
        n_clients=n_clients,
        requests=counts["requests"],
        replies=counts["replies"],
        errors=counts["errors"],
        jobs_received=counts["jobs"],
        wall_s=wall,
        rpcs_per_s=(counts["replies"] + counts["errors"]) / wall if wall > 0 else 0.0,
        p50_ms=_percentile(latencies, 0.50) * 1e3,
        p95_ms=_percentile(latencies, 0.95) * 1e3,
        p99_ms=_percentile(latencies, 0.99) * 1e3,
    )
