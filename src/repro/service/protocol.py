"""Newline-delimited wire protocol for the scheduler service.

One request or reply per line, UTF-8, ``\\n``-terminated, at most
``MAX_LINE`` bytes.  Every frame starts with a verb and a client-chosen
sequence number; replies echo the sequence number so clients may pipeline
requests over a shared connection.

Grammar (``<f>`` = ``repr()`` of a Python float, ``<esc>`` = percent-escaped
string with no reserved bytes, lists comma-joined, optional keys omitted
when empty)::

    request  = "PING" SP seq
             | "STATS" SP seq
             | "WORK" SP seq SP "host=" int SP "disk=" <f>
               ["cpu=" rt:idle:qd] ["gpu=" ...] ["tpu=" ...]
               ["done=" inst:outcome:rt:pfc:exit ("," ...)*]
               ["trickle=" inst:frac ("," ...)*]
               ["sticky=" <esc> ("," <esc>)*]
    reply    = "PONG" SP seq
             | "JOBS" SP seq SP "delay=" <f>
               ["job=" jid:iid:vid:est_rt:est_flops ("," ...)*]
               ["del=" <esc> ("," <esc>)*]
             | "STATS" SP seq ["v=" <esc>:<f> ("," ...)*]
             | "ERR" SP seq SP code SP <esc>

Floats travel as ``repr()`` so round-trips are bit-exact (``repr``/``float``
is the identity on finite doubles, and ``inf``/``nan`` parse back).  The
codec deliberately carries only the fields the dispatch path consumes;
``keyword_prefs``, ``anonymous_versions`` and the opaque ``output`` /
``stderr`` / trickle payloads are out of scope for the wire format and keep
their dataclass defaults on decode.

Malformed frames raise :class:`ProtocolError`; the service answers them
with an ``ERR`` frame instead of dropping the connection.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple, Union
from urllib.parse import quote, unquote

from ..core.scheduler import (
    CompletedResult,
    ResourceRequest,
    ScheduleReply,
    ScheduleRequest,
    TrickleUp,
)
from ..core.types import InstanceOutcome, ResourceType

MAX_LINE = 64 * 1024

# Fixed encode order for the per-resource work-request keys.
_RESOURCE_KEYS: Tuple[ResourceType, ...] = (
    ResourceType.CPU,
    ResourceType.GPU,
    ResourceType.TPU,
)


class ProtocolError(Exception):
    """A frame the codec refuses; ``code`` is a short machine token."""

    def __init__(self, code: str, message: str) -> None:
        super().__init__(f"{code}: {message}")
        self.code = code
        self.message = message


# ---------------------------------------------------------------------------
# Wire dataclasses
# ---------------------------------------------------------------------------


@dataclass
class PingRequest:
    seq: int


@dataclass
class StatsRequest:
    seq: int


@dataclass
class WorkRequest:
    seq: int
    request: ScheduleRequest


@dataclass
class PongReply:
    seq: int


@dataclass
class JobOffer:
    """One dispatched job as seen on the wire.  Replies cannot reconstruct
    the server-side ``Job``/``JobInstance`` objects, so the service flattens
    each ``DispatchedJob`` to the identifiers and estimates a client needs."""

    job_id: int
    instance_id: int
    version_id: int
    est_runtime: float
    est_flops: float


@dataclass
class WorkReply:
    seq: int
    request_delay: float = 0.0
    jobs: List[JobOffer] = field(default_factory=list)
    delete_sticky: List[str] = field(default_factory=list)


@dataclass
class StatsReply:
    seq: int
    values: Dict[str, float] = field(default_factory=dict)


@dataclass
class ErrorReply:
    seq: int
    code: str
    message: str


Request = Union[PingRequest, StatsRequest, WorkRequest]
Reply = Union[PongReply, WorkReply, StatsReply, ErrorReply]


def reply_to_wire(seq: int, reply: ScheduleReply) -> WorkReply:
    """Flatten a scheduler :class:`ScheduleReply` into its wire form."""
    return WorkReply(
        seq=seq,
        request_delay=reply.request_delay,
        jobs=[
            JobOffer(
                job_id=d.job.id,
                instance_id=d.instance.id,
                version_id=d.version.id,
                est_runtime=d.est_runtime,
                est_flops=d.est_flops,
            )
            for d in reply.jobs
        ],
        delete_sticky=list(reply.delete_sticky),
    )


# ---------------------------------------------------------------------------
# Encoding
# ---------------------------------------------------------------------------


def _ffmt(x: float) -> str:
    return repr(float(x))


def _esc(s: str) -> str:
    return quote(s, safe="")


def encode_request(req: Request) -> str:
    if isinstance(req, PingRequest):
        return f"PING {req.seq}"
    if isinstance(req, StatsRequest):
        return f"STATS {req.seq}"
    if isinstance(req, WorkRequest):
        r = req.request
        parts = [f"WORK {req.seq}", f"host={r.host_id}", f"disk={_ffmt(r.usable_disk)}"]
        for rt in _RESOURCE_KEYS:
            rr = r.requests.get(rt)
            if rr is not None:
                parts.append(
                    f"{rt.value}={_ffmt(rr.req_runtime)}:{_ffmt(rr.req_idle)}"
                    f":{_ffmt(rr.queue_dur)}"
                )
        if r.completed:
            parts.append(
                "done="
                + ",".join(
                    f"{c.instance_id}:{c.outcome.value}:{_ffmt(c.runtime)}"
                    f":{_ffmt(c.peak_flop_count)}:{c.exit_code}"
                    for c in r.completed
                )
            )
        if r.trickles:
            parts.append(
                "trickle="
                + ",".join(
                    f"{t.instance_id}:{_ffmt(t.fraction_done)}" for t in r.trickles
                )
            )
        if r.sticky_files:
            parts.append("sticky=" + ",".join(_esc(s) for s in r.sticky_files))
        return " ".join(parts)
    raise ProtocolError("bad-verb", f"cannot encode {type(req).__name__}")


def encode_reply(rep: Reply) -> str:
    if isinstance(rep, PongReply):
        return f"PONG {rep.seq}"
    if isinstance(rep, WorkReply):
        parts = [f"JOBS {rep.seq}", f"delay={_ffmt(rep.request_delay)}"]
        if rep.jobs:
            parts.append(
                "job="
                + ",".join(
                    f"{j.job_id}:{j.instance_id}:{j.version_id}"
                    f":{_ffmt(j.est_runtime)}:{_ffmt(j.est_flops)}"
                    for j in rep.jobs
                )
            )
        if rep.delete_sticky:
            parts.append("del=" + ",".join(_esc(s) for s in rep.delete_sticky))
        return " ".join(parts)
    if isinstance(rep, StatsReply):
        line = f"STATS {rep.seq}"
        if rep.values:
            line += " v=" + ",".join(
                f"{_esc(k)}:{_ffmt(v)}" for k, v in rep.values.items()
            )
        return line
    if isinstance(rep, ErrorReply):
        return f"ERR {rep.seq} {rep.code} {_esc(rep.message)}"
    raise ProtocolError("bad-verb", f"cannot encode {type(rep).__name__}")


# ---------------------------------------------------------------------------
# Decoding
# ---------------------------------------------------------------------------


def _parse_int(tok: str, what: str) -> int:
    try:
        return int(tok)
    except ValueError:
        raise ProtocolError("bad-int", f"{what}: {tok!r}") from None


def _parse_float(tok: str, what: str) -> float:
    try:
        return float(tok)
    except ValueError:
        raise ProtocolError("bad-float", f"{what}: {tok!r}") from None


def _split_frame(line: str) -> Tuple[str, int, List[str]]:
    if len(line) > MAX_LINE:
        raise ProtocolError("too-long", f"frame of {len(line)} bytes")
    toks = line.split(" ")
    if len(toks) < 2 or not toks[0]:
        raise ProtocolError("bad-frame", f"short frame: {line!r}")
    return toks[0], _parse_int(toks[1], "seq"), toks[2:]


def _kv_fields(toks: List[str], allowed: Tuple[str, ...]) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for tok in toks:
        key, sep, val = tok.partition("=")
        if not sep or key not in allowed:
            raise ProtocolError("bad-field", f"unexpected token {tok!r}")
        if key in out:
            raise ProtocolError("bad-field", f"duplicate key {key!r}")
        out[key] = val
    return out


def _parse_list(val: str, what: str) -> List[str]:
    # "k=" is a one-element list holding the empty string (encoders omit
    # the key for genuinely empty lists), so splitting is lossless; items
    # that need structure get rejected downstream by _parse_cols
    return val.split(",")


def _parse_cols(item: str, n: int, what: str) -> List[str]:
    cols = item.split(":")
    if len(cols) != n:
        raise ProtocolError("bad-field", f"{what} wants {n} columns: {item!r}")
    return cols


def decode_request(line: str) -> Request:
    verb, seq, toks = _split_frame(line)
    if verb == "PING":
        if toks:
            raise ProtocolError("bad-field", f"PING takes no fields: {toks!r}")
        return PingRequest(seq=seq)
    if verb == "STATS":
        if toks:
            raise ProtocolError("bad-field", f"STATS takes no fields: {toks!r}")
        return StatsRequest(seq=seq)
    if verb != "WORK":
        raise ProtocolError("bad-verb", f"unknown request verb {verb!r}")
    allowed = ("host", "disk") + tuple(rt.value for rt in _RESOURCE_KEYS) + (
        "done",
        "trickle",
        "sticky",
    )
    kv = _kv_fields(toks, allowed)
    if "host" not in kv or "disk" not in kv:
        raise ProtocolError("bad-field", "WORK requires host= and disk=")
    req = ScheduleRequest(
        host_id=_parse_int(kv["host"], "host"),
        usable_disk=_parse_float(kv["disk"], "disk"),
    )
    for rt in _RESOURCE_KEYS:
        if rt.value in kv:
            cols = _parse_cols(kv[rt.value], 3, rt.value)
            req.requests[rt] = ResourceRequest(
                req_runtime=_parse_float(cols[0], f"{rt.value} rt"),
                req_idle=_parse_float(cols[1], f"{rt.value} idle"),
                queue_dur=_parse_float(cols[2], f"{rt.value} qd"),
            )
    for item in _parse_list(kv["done"], "done") if "done" in kv else []:
        cols = _parse_cols(item, 5, "done")
        try:
            outcome = InstanceOutcome(cols[1])
        except ValueError:
            raise ProtocolError("bad-field", f"unknown outcome {cols[1]!r}") from None
        req.completed.append(
            CompletedResult(
                instance_id=_parse_int(cols[0], "done inst"),
                outcome=outcome,
                runtime=_parse_float(cols[2], "done rt"),
                peak_flop_count=_parse_float(cols[3], "done pfc"),
                exit_code=_parse_int(cols[4], "done exit"),
            )
        )
    for item in _parse_list(kv["trickle"], "trickle") if "trickle" in kv else []:
        cols = _parse_cols(item, 2, "trickle")
        req.trickles.append(
            TrickleUp(
                instance_id=_parse_int(cols[0], "trickle inst"),
                fraction_done=_parse_float(cols[1], "trickle frac"),
            )
        )
    if "sticky" in kv:
        req.sticky_files = tuple(
            unquote(s) for s in _parse_list(kv["sticky"], "sticky")
        )
    return WorkRequest(seq=seq, request=req)


def decode_reply(line: str) -> Reply:
    verb, seq, toks = _split_frame(line)
    if verb == "PONG":
        if toks:
            raise ProtocolError("bad-field", f"PONG takes no fields: {toks!r}")
        return PongReply(seq=seq)
    if verb == "ERR":
        if len(toks) != 2:
            raise ProtocolError("bad-field", f"ERR wants code + message: {toks!r}")
        return ErrorReply(seq=seq, code=toks[0], message=unquote(toks[1]))
    if verb == "STATS":
        kv = _kv_fields(toks, ("v",))
        rep = StatsReply(seq=seq)
        for item in _parse_list(kv["v"], "v") if "v" in kv else []:
            key, sep, val = item.rpartition(":")
            if not sep:
                raise ProtocolError("bad-field", f"v wants key:value: {item!r}")
            rep.values[unquote(key)] = _parse_float(val, "stat value")
        return rep
    if verb != "JOBS":
        raise ProtocolError("bad-verb", f"unknown reply verb {verb!r}")
    kv = _kv_fields(toks, ("delay", "job", "del"))
    if "delay" not in kv:
        raise ProtocolError("bad-field", "JOBS requires delay=")
    rep = WorkReply(seq=seq, request_delay=_parse_float(kv["delay"], "delay"))
    for item in _parse_list(kv["job"], "job") if "job" in kv else []:
        cols = _parse_cols(item, 5, "job")
        rep.jobs.append(
            JobOffer(
                job_id=_parse_int(cols[0], "job id"),
                instance_id=_parse_int(cols[1], "instance id"),
                version_id=_parse_int(cols[2], "version id"),
                est_runtime=_parse_float(cols[3], "est_runtime"),
                est_flops=_parse_float(cols[4], "est_flops"),
            )
        )
    if "del" in kv:
        rep.delete_sticky = [unquote(s) for s in _parse_list(kv["del"], "del")]
    return rep
