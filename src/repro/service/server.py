"""Asyncio scheduler service: a TCP front for :class:`ProjectServer`.

Connections speak the newline protocol from :mod:`.protocol`.  ``PING`` and
``STATS`` are answered inline; ``WORK`` frames are queued and a single
dispatcher task drains the queue in *waves* — every wave is handed to the
project as one ``rpc_batch`` call, so concurrent clients are coalesced into
the vectorized per-shard dispatch pass instead of paying one scalar cache
scan each (§5.1).  With ``coalesce=False`` the dispatcher degrades to
per-request ``rpc`` calls; that mode is the sequential baseline the RPC
bench measures against.

The core stays synchronous and deterministic: all scheduler state is
touched only from the dispatcher task, and "now" comes from an injected
``clock`` callable (virtual time by default) rather than the wall clock.
"""
from __future__ import annotations

import asyncio
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from ..core.server import ProjectServer
from .protocol import (
    MAX_LINE,
    ErrorReply,
    PingRequest,
    PongReply,
    ProtocolError,
    StatsReply,
    StatsRequest,
    WorkRequest,
    decode_request,
    encode_reply,
    reply_to_wire,
)


@dataclass
class _Pending:
    seq: int
    request: object  # ScheduleRequest
    writer: asyncio.StreamWriter


class SchedulerService:
    """Serve a :class:`ProjectServer` over TCP, coalescing RPC waves."""

    def __init__(
        self,
        project: ProjectServer,
        *,
        coalesce: bool = True,
        max_batch: int = 1024,
        refill_every: int = 512,
        clock: Optional[Callable[[], float]] = None,
        host: str = "127.0.0.1",
        port: int = 0,
    ) -> None:
        self.project = project
        self.coalesce = coalesce
        self.max_batch = max_batch
        self.refill_every = refill_every
        self.clock = clock or (lambda: 0.0)
        self.host = host
        self.port = port
        self._server: Optional[asyncio.AbstractServer] = None
        self._dispatcher: Optional[asyncio.Task] = None
        self._queue: Optional[asyncio.Queue] = None
        self._since_refill = 0
        self._stats = {
            "waves": 0,
            "requests": 0,
            "dispatched": 0,
            "errors": 0,
            "max_wave": 0,
        }

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> None:
        # The queue must be created inside the running loop (pre-3.10
        # asyncio primitives bind their loop at construction time).
        self._queue = asyncio.Queue()
        self._server = await asyncio.start_server(
            self._on_connection, self.host, self.port, limit=MAX_LINE
        )
        self.port = self._server.sockets[0].getsockname()[1]
        self._dispatcher = asyncio.create_task(self._dispatch_loop())

    async def stop(self) -> None:
        if self._dispatcher is not None:
            self._dispatcher.cancel()
            try:
                await self._dispatcher
            except asyncio.CancelledError:
                pass
            self._dispatcher = None
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    def stats(self) -> Dict[str, object]:
        out: Dict[str, object] = dict(self._stats)
        if self.project.shard_map is not None:
            out["shards"] = self.project.shard_map.utilization()
        return out

    # -- connection handling ------------------------------------------------

    def _send(self, writer: asyncio.StreamWriter, reply) -> None:
        if not writer.is_closing():
            writer.write((encode_reply(reply) + "\n").encode())

    def _flat_stats(self) -> Dict[str, float]:
        vals = {k: float(v) for k, v in self._stats.items()}
        if self.project.shard_map is not None:
            for row in self.project.shard_map.utilization():
                s = row["shard"]
                for k, v in row.items():
                    if k != "shard":
                        vals[f"shard{s}.{k}"] = float(v)
        return vals

    async def _on_connection(
        self, reader: asyncio.StreamReader, writer: asyncio.StreamWriter
    ) -> None:
        try:
            while True:
                try:
                    raw = await reader.readline()
                except (asyncio.LimitOverrunError, ValueError):
                    # Over-long frame: the stream offset is lost, so reply
                    # and drop the connection rather than resynchronize.
                    self._stats["errors"] += 1
                    self._send(writer, ErrorReply(0, "too-long", "frame too long"))
                    await writer.drain()
                    break
                if not raw:
                    break
                line = raw.decode("utf-8", errors="replace").rstrip("\r\n")
                try:
                    req = decode_request(line)
                except ProtocolError as e:
                    self._stats["errors"] += 1
                    self._send(writer, ErrorReply(0, e.code, e.message))
                    await writer.drain()
                    continue
                if isinstance(req, PingRequest):
                    self._send(writer, PongReply(req.seq))
                    await writer.drain()
                elif isinstance(req, StatsRequest):
                    self._send(writer, StatsReply(req.seq, self._flat_stats()))
                    await writer.drain()
                else:
                    assert isinstance(req, WorkRequest)
                    await self._queue.put(_Pending(req.seq, req.request, writer))
        except ConnectionError:
            pass
        finally:
            try:
                writer.close()
            except Exception:
                pass

    # -- dispatcher ---------------------------------------------------------

    async def _dispatch_loop(self) -> None:
        assert self._queue is not None
        while True:
            wave: List[_Pending] = [await self._queue.get()]
            while len(wave) < self.max_batch:
                try:
                    wave.append(self._queue.get_nowait())
                except asyncio.QueueEmpty:
                    break
            now = self.clock()
            requests = [p.request for p in wave]
            if self.coalesce and len(requests) > 1:
                replies = self.project.rpc_batch(requests, now)
            else:
                replies = [self.project.rpc(r, now) for r in requests]
            dispatched = 0
            writers = {}
            for p, rep in zip(wave, replies):
                dispatched += len(rep.jobs)
                self._send(p.writer, reply_to_wire(p.seq, rep))
                writers[id(p.writer)] = p.writer
            for w in writers.values():
                try:
                    await w.drain()
                except ConnectionError:
                    pass
            self._stats["waves"] += 1
            self._stats["requests"] += len(wave)
            self._stats["dispatched"] += dispatched
            self._stats["max_wave"] = max(self._stats["max_wave"], len(wave))
            self._since_refill += len(wave)
            if self._since_refill >= self.refill_every:
                self._since_refill = 0
                self.project.feeder.fill()
