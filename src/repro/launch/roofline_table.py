"""Render the EXPERIMENTS.md roofline table from dry-run JSONL records.

    PYTHONPATH=src python -m repro.launch.roofline_table results/dryrun_baseline.jsonl
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List

from repro.distributed.roofline import RooflineTerms


def load(path: str) -> List[Dict]:
    recs = []
    with open(path) as f:
        for line in f:
            recs.append(json.loads(line))
    return recs


def to_terms(r: Dict) -> RooflineTerms:
    return RooflineTerms(
        arch=r["arch"],
        shape=r["shape"],
        mesh=r["mesh"],
        chips=r["chips"],
        hlo_flops=r["hlo_flops"],
        hlo_bytes=r["hlo_bytes"],
        collective_bytes=r["collective_bytes"],
        model_flops=r["model_flops"],
    )


def render_table(recs: List[Dict], mesh_filter: str = "16x16") -> str:
    rows = []
    header = (
        "| arch | shape | C (s) | M (s) | X (s) | dominant | HBM GB/dev | "
        "useful | RF |"
    )
    sep = "|" + "---|" * 9
    rows.append(header)
    rows.append(sep)
    seen = set()
    for r in recs:
        if r["status"] == "skipped":
            key = (r["arch"], r["shape"])
            if key not in seen:
                seen.add(key)
                rows.append(
                    f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | — |"
                )
            continue
        if r["status"] != "ok" or not r["mesh"].startswith(mesh_filter):
            continue
        t = to_terms(r)
        gb = r.get("per_device_bytes", 0) / 1e9
        rows.append(
            f"| {t.arch} | {t.shape} | {t.compute_s:.4f} | {t.memory_s:.4f} | "
            f"{t.collective_s:.4f} | {t.dominant} | {gb:.1f} | "
            f"{t.useful_flops_fraction:.3f} | {t.roofline_fraction:.3f} |"
        )
    return "\n".join(rows)


def pick_hillclimb(recs: List[Dict]) -> None:
    ok = [r for r in recs if r["status"] == "ok" and r["mesh"].startswith("16x16")
          and r["shape"].startswith("train")]
    terms = [(to_terms(r), r) for r in ok]
    worst_rf = min(terms, key=lambda t: t[0].roofline_fraction)
    most_coll = max(terms, key=lambda t: t[0].collective_s / max(t[0].step_time_s, 1e-12))
    print("\nworst roofline fraction:", worst_rf[0].arch, worst_rf[0].shape,
          f"RF={worst_rf[0].roofline_fraction:.4f}")
    print("most collective-bound:", most_coll[0].arch, most_coll[0].shape,
          f"X/t={most_coll[0].collective_s / max(most_coll[0].step_time_s, 1e-12):.3f}")


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_baseline.jsonl"
    recs = load(path)
    print(render_table(recs))
    pick_hillclimb(recs)


if __name__ == "__main__":
    main()
