import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)
# The two lines above MUST run before any other import (jax locks the device
# count on first init). Everything below is the multi-pod dry-run driver.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct stand-ins (no allocation), print memory/cost analysis, and
derive the three roofline terms (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]

Exit code 0 only if every requested cell compiles.
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs import ARCHS, get_config
from repro.distributed.hlo_analysis import (
    cost_analysis_bytes,
    cost_analysis_flops,
    memory_analysis_dict,
    op_census,
)
from repro.distributed.hlo_costs import analyze_module
from repro.distributed.roofline import RooflineTerms
from repro.launch.mesh import make_production_mesh, mesh_name
from repro.models.config import SHAPES, cell_supported, get_shape
from repro.runtime.step_builder import build_step, model_flops_for_cell


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    verbose: bool = True,
    rules_overrides: Optional[Dict] = None,
) -> Dict[str, Any]:
    """Lower + compile one cell; returns the record for EXPERIMENTS.md."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    bundle = build_step(cfg, shape, mesh, rules_overrides=rules_overrides)
    lowered = bundle.lower()
    t_lower = time.time() - t0
    hlo_text = lowered.as_text()
    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1

    mem = memory_analysis_dict(compiled)
    try:
        opt_text = compiled.as_text()
    except Exception:
        opt_text = hlo_text
    # XLA's cost_analysis counts while (scan) bodies ONCE; analyze_module
    # parses the optimized per-device module, extracts loop trip counts, and
    # rolls up flops/bytes/collectives with multipliers. Everything below is
    # per-device x chips = whole-module totals, matching the roofline's
    # "/ (chips * bw)" convention.
    costs = analyze_module(opt_text)
    flops = costs.flops * chips
    hbm_bytes = costs.bytes * chips
    model_flops = model_flops_for_cell(cfg, shape)

    terms = RooflineTerms(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name(mesh),
        chips=chips,
        hlo_flops=flops,
        hlo_bytes=hbm_bytes,
        collective_bytes=costs.total_collective_bytes * chips,
        model_flops=model_flops,
    )
    per_dev_bytes = (
        mem.get("argument_size_in_bytes", 0)
        + mem.get("output_size_in_bytes", 0)
        - mem.get("alias_size_in_bytes", 0)
        + mem.get("temp_size_in_bytes", 0)
    )
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name(mesh),
        "status": "ok",
        "chips": chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_analysis": mem,
        "per_device_bytes": per_dev_bytes,
        "hlo_flops": flops,
        "hlo_bytes": hbm_bytes,
        "collective_bytes": costs.total_collective_bytes * chips,
        "collectives": {k: v * chips for k, v in costs.collective_bytes.items()},
        "collective_counts": dict(costs.collective_counts),
        "xla_flops_once": cost_analysis_flops(compiled) * chips,  # cross-check
        "model_flops": model_flops,
        "while_trips": dict(costs.while_trips),
        "roofline": terms.row(),
    }
    if verbose:
        coll_str = "; ".join(
            f"{k}: n={costs.collective_counts[k]:g} bytes={v*chips:,.0f}"
            for k, v in sorted(costs.collective_bytes.items())
        ) or "none"
        print(f"=== {arch} x {shape_name} @ {mesh_name(mesh)} ===")
        print(f"  lower {t_lower:.1f}s, compile {t_compile:.1f}s")
        print(f"  memory_analysis: {mem}")
        print(f"  per-device bytes: {per_dev_bytes/1e9:.3f} GB  (HBM 16 GB)")
        print(f"  hlo totals: flops={flops:.3e} bytes={hbm_bytes:.3e} trips={costs.while_trips}")
        print(f"  collectives (totals): {coll_str}")
        print(f"  roofline: {terms.render()}")
    return record


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", choices=ARCHS)
    p.add_argument("--shape", choices=[s.name for s in SHAPES])
    p.add_argument("--all", action="store_true", help="every (arch x shape)")
    p.add_argument("--multi-pod", action="store_true", help="2x16x16 mesh")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--json", help="append JSONL records here")
    args = p.parse_args()

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s.name))
    else:
        if not args.arch or not args.shape:
            p.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = 0
    records = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                rec = run_cell(arch, shape, multi_pod=mp)
            except Exception as e:
                traceback.print_exc()
                rec = {
                    "arch": arch,
                    "shape": shape,
                    "multi_pod": mp,
                    "status": "error",
                    "error": f"{type(e).__name__}: {e}",
                }
                failures += 1
            records.append(rec)
            if args.json:
                with open(args.json, "a") as f:
                    f.write(json.dumps(rec) + "\n")

    n_ok = sum(1 for r in records if r["status"] == "ok")
    n_skip = sum(1 for r in records if r["status"] == "skipped")
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {failures} failed")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
