"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state. The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
everything else sees the real device count.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's production mesh: (16,16) single pod = 256 chips,
    (2,16,16) multi-pod = 512 chips over ("pod","data","model")."""
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) > n:
        # e.g. single-pod mesh inside the 512-device dry-run process
        from jax.sharding import Mesh

        return Mesh(np.array(devices[:n]).reshape(shape), axes)
    raise RuntimeError(
        f"need {n} devices for mesh {shape}, have {len(devices)} "
        "(dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
        "before importing jax)"
    )


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary mesh over the first prod(shape) devices (tests, elastic)."""
    import jax
    from jax.sharding import Mesh

    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.array(devices[:n]).reshape(tuple(shape)), tuple(axes))


def single_device_mesh():
    """1x1 mesh over the local device (smoke tests)."""
    return make_mesh((1, 1), ("data", "model"))


def mesh_name(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape) + ":" + ",".join(mesh.axis_names)
