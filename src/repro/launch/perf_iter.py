import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Perf-iteration driver: compile one cell with config/rule overrides and
print the roofline terms + the largest collectives (the 'profile' of the
dry-run methodology). Used by the §Perf hillclimb loop.

    PYTHONPATH=src python -m repro.launch.perf_iter --arch X --shape Y \
        [--set remat_policy=dots_nb] [--set ssm_chunk=128] [--multi-pod]
"""
import argparse
import json
import sys

import jax

from repro.configs import ARCHS, get_config
from repro.distributed.hlo_analysis import collective_stats, memory_analysis_dict
from repro.distributed.hlo_costs import analyze_module
from repro.distributed.roofline import RooflineTerms
from repro.launch.mesh import make_production_mesh, mesh_name
from repro.models.config import SHAPES, get_shape
from repro.runtime.step_builder import build_step, model_flops_for_cell


def run_iteration(arch, shape_name, overrides=None, rules_overrides=None,
                  multi_pod=False, top=8, verbose=True):
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.scaled(**overrides)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    bundle = build_step(cfg, shape, mesh, rules_overrides=rules_overrides)
    compiled = bundle.lower().compile()
    text = compiled.as_text()
    costs = analyze_module(text)
    mem = memory_analysis_dict(compiled)
    per_dev = (
        mem.get("argument_size_in_bytes", 0)
        + mem.get("output_size_in_bytes", 0)
        - mem.get("alias_size_in_bytes", 0)
        + mem.get("temp_size_in_bytes", 0)
    )
    terms = RooflineTerms(
        arch=arch, shape=shape_name, mesh=mesh_name(mesh), chips=chips,
        hlo_flops=costs.flops * chips, hlo_bytes=costs.bytes * chips,
        collective_bytes=costs.total_collective_bytes * chips,
        model_flops=model_flops_for_cell(cfg, shape),
    )
    if verbose:
        print(f"--- {arch} x {shape_name} overrides={overrides} rules={rules_overrides} ---")
        print(f"  HBM/dev: {per_dev/1e9:.1f} GB   {terms.render()}")
        print(f"  collectives/dev: " + "; ".join(
            f"{k}={v/1e9:.1f}GB(n={costs.collective_counts[k]:g})"
            for k, v in sorted(costs.collective_bytes.items(), key=lambda kv: -kv[1])
        ))
        st = collective_stats(text)
        for nbytes, line in st.largest[:top]:
            print(f"    {nbytes/1e9:7.2f} GB/dev-use  {line[:130]}")
    return terms, costs, per_dev


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCHS, required=True)
    p.add_argument("--shape", choices=[s.name for s in SHAPES], required=True)
    p.add_argument("--set", action="append", default=[], help="cfg override k=v")
    p.add_argument("--multi-pod", action="store_true")
    args = p.parse_args()
    overrides = {}
    for kv in getattr(args, "set"):
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except Exception:
            pass
        overrides[k] = v
    run_iteration(args.arch, args.shape, overrides or None, multi_pod=args.multi_pod)


if __name__ == "__main__":
    main()
