"""Logical activation-sharding constraints, mesh-agnostic at the model layer.

Model code calls ``constrain(x, ("batch", "seq", None))``; whether that
becomes a real ``with_sharding_constraint`` depends on the ambient scope the
step builder installs at trace time. Without a scope (CPU smoke tests,
single-device training) it is a no-op, so the same model code serves every
environment.

GSPMD needs these at layer boundaries: with FSDP-sharded weights (embed axis
over "data") and batch-sharded activations, the contraction dimension of
every matmul is "conflicted", and unconstrained propagation can choose to
all-gather the *activations* (40 GB) instead of the *weights* (40 MB).
Pinning activations at block boundaries forces the cheap choice.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Callable, Optional, Sequence

import jax

# spec_fn(shape, logical_axes) -> sharding or None
_SCOPE: contextvars.ContextVar[Optional[Callable]] = contextvars.ContextVar(
    "logical_sharding_scope", default=None
)


@contextlib.contextmanager
def logical_sharding_scope(spec_fn: Callable[[Sequence[int], Sequence[Optional[str]]], Any]):
    token = _SCOPE.set(spec_fn)
    try:
        yield
    finally:
        _SCOPE.reset(token)


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (no-op w/o scope)."""
    spec_fn = _SCOPE.get()
    if spec_fn is None:
        return x
    if len(axes) != x.ndim:
        return x  # defensive: caller passed axes for a different rank
    sharding = spec_fn(tuple(x.shape), tuple(axes))
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)
