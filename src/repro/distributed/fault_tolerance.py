"""Fleet-level fault tolerance: heartbeats, churn, elastic mesh selection.

The paper's host-churn handling (deadline + retry, §4) covers *job*-level
faults; this module covers *fleet*-level reconfiguration for the synchronous
SPMD layer: when workers join/leave, pick the largest supported mesh from
the live worker set, restart from the last checkpoint, and rescale
per-worker microbatches so the global batch is preserved (BOINC's multi-size
jobs, §3.5, applied to elasticity).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class WorkerHealth:
    worker_id: int
    last_heartbeat: float = 0.0
    consecutive_misses: int = 0
    alive: bool = True


@dataclass
class HeartbeatMonitor:
    """Deadline-style liveness: a worker missing ``max_misses`` heartbeat
    periods is declared dead (exactly the paper's delay_bound logic applied
    at the transport layer)."""

    period: float = 10.0
    max_misses: int = 3
    workers: Dict[int, WorkerHealth] = field(default_factory=dict)

    def register(self, worker_id: int, now: float) -> None:
        self.workers[worker_id] = WorkerHealth(worker_id, last_heartbeat=now)

    def heartbeat(self, worker_id: int, now: float) -> None:
        w = self.workers.setdefault(worker_id, WorkerHealth(worker_id))
        w.last_heartbeat = now
        w.consecutive_misses = 0
        w.alive = True

    def sweep(self, now: float) -> List[int]:
        """Returns workers newly declared dead."""
        died = []
        for w in self.workers.values():
            if not w.alive:
                continue
            missed = int((now - w.last_heartbeat) / self.period)
            w.consecutive_misses = missed
            if missed >= self.max_misses:
                w.alive = False
                died.append(w.worker_id)
        return died

    def live(self) -> List[int]:
        return [w.worker_id for w in self.workers.values() if w.alive]


# ---------------------------------------------------------------------------
# Elastic mesh selection
# ---------------------------------------------------------------------------

#: supported (data, model) meshes per pod, largest first. The model axis is
#: fixed by the arch's TP requirement; elasticity happens on data/pod axes.
def candidate_meshes(
    n_chips: int, model_axis: int = 16, pods: int = 1
) -> List[Tuple[int, ...]]:
    out = []
    per_pod = n_chips // max(pods, 1)
    data = per_pod // model_axis
    # drop to the largest power-of-two data axis that fits
    d = 1 << int(math.floor(math.log2(data))) if data >= 1 else 0
    while d >= 1:
        if pods > 1:
            out.append((pods, d, model_axis))
        else:
            out.append((d, model_axis))
        d //= 2
    return out


@dataclass
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    microbatch_per_worker: int
    grad_accum_steps: int


def plan_elastic_config(
    live_chips: int,
    global_batch: int,
    model_axis: int = 16,
    pods: int = 1,
) -> Optional[ElasticPlan]:
    """Largest runnable mesh for the live chip count + batch rescale.

    Keeps the global batch constant by raising gradient-accumulation steps
    when the data axis shrinks (preserving optimization semantics across
    elasticity events)."""
    meshes = candidate_meshes(live_chips, model_axis, pods)
    for shape in meshes:
        data_ways = shape[0] * shape[1] if len(shape) == 3 else shape[0]
        if data_ways == 0:
            continue
        if global_batch % data_ways != 0:
            continue
        per = global_batch // data_ways
        # bound per-worker microbatch; accumulate if too large
        accum = 1
        while per > 64:
            if per % 2:
                break
            per //= 2
            accum *= 2
        return ElasticPlan(mesh_shape=shape, microbatch_per_worker=per, grad_accum_steps=accum)
    return None


# ---------------------------------------------------------------------------
# Straggler mitigation at the step level
# ---------------------------------------------------------------------------


@dataclass
class StragglerPolicy:
    """Deadline-based re-dispatch (§4) for step tasks: a microbatch job that
    hasn't returned within ``factor`` x the running mean step time is
    re-dispatched to the fastest idle host (§3.5 job-size matching)."""

    factor: float = 3.0
    min_samples: int = 8
    _mean: float = 0.0
    _n: int = 0

    def observe(self, runtime: float) -> None:
        self._n += 1
        self._mean += (runtime - self._mean) / self._n

    def deadline(self, now: float) -> float:
        if self._n < self.min_samples:
            return now + 3600.0
        return now + self.factor * self._mean

    @property
    def mean_runtime(self) -> float:
        return self._mean
