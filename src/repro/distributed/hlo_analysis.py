"""HLO-text analysis: collective bytes + op census for the roofline.

``compiled.cost_analysis()`` gives FLOPs and HBM bytes but NOT collective
traffic, so we parse the optimized HLO module text and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. Shapes are parsed from the HLO type annotations, e.g.

  %all-reduce.1 = f32[1024,512]{1,0} all-reduce(f32[1024,512]{1,0} %x), ...

Also counts remat recompute (duplicate fusion roots) and reports an op
census used by the perf loop ("which collective grew?").
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1,
    "u4": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "f16": 2,
    "bf16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
    "f8e4m3fn": 1,
    "f8e5m2": 1,
}

# matches e.g. f32[128,1024] or bf16[8,16,2048]{2,1,0} or f32[] (scalar)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

_COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _line_output_bytes(line: str) -> int:
    """Bytes of the op's OUTPUT (first type annotation, incl. tuples)."""
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return 0
    rhs = lhs[1]
    # output type(s) appear before the op name; take annotations up to '('
    head = rhs.split("(", 1)[0]
    total = 0
    for m in _SHAPE_RE.finditer(head):
        total += _shape_bytes(m.group(1), m.group(2))
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    count_by_op: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    largest: List[Tuple[int, str]] = field(default_factory=list)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    def summary(self) -> str:
        parts = [
            f"{op}: n={self.count_by_op[op]} bytes={self.bytes_by_op[op]:,}"
            for op in sorted(self.bytes_by_op)
        ]
        return "; ".join(parts) if parts else "none"


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum collective traffic over the (optimized) HLO module text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls:
            continue
        for op in _COLLECTIVE_OPS:
            # op name appears as `op(`, `op-start(`, or `op-done(`
            if re.search(rf"\b{op}(-start)?\(", ls):
                if f"{op}-done" in ls:
                    continue  # avoid double counting start/done pairs
                nbytes = _line_output_bytes(ls)
                stats.bytes_by_op[op] += nbytes
                stats.count_by_op[op] += 1
                stats.largest.append((nbytes, ls[:160]))
                break
    stats.largest.sort(key=lambda t: -t[0])
    stats.largest = stats.largest[:12]
    return stats


def op_census(hlo_text: str) -> Dict[str, int]:
    """Count ops by name — spotting remat duplicates and reshape storms."""
    census: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls:
            continue
        m = re.search(r"= (?:\([^)]*\) )?(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})? )?([a-z][a-z0-9-]*)\(", ls)
        if m:
            census[m.group(1)] += 1
    return dict(census)


def cost_analysis_flops(compiled) -> float:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0))


def cost_analysis_bytes(compiled) -> float:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    if "bytes accessed" in ca:
        return float(ca["bytes accessed"])
    total = 0.0
    for k, v in ca.items():
        if k.startswith("bytes accessed"):
            total += float(v)
    return total


def memory_analysis_dict(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for name in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        if hasattr(ma, name):
            out[name] = float(getattr(ma, name))
    return out
