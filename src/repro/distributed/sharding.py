"""Logical-axis sharding rules -> PartitionSpecs / NamedShardings.

One rule table covers all 10 architectures; rules are *resolved per
(config, mesh)*: a logical axis maps onto a mesh axis only when the
dimension divides evenly (e.g. kv_heads=8 cannot shard over model=16 and
falls back to replication, while 96 heads shard fine). This is what makes a
single step builder serve every (arch x shape x mesh) cell.

Parallelism provided:
  DP    batch        -> ("pod", "data")
  FSDP  param embed  -> "data"   (ZeRO-3 style gather-on-use by GSPMD)
  TP    heads/mlp/vocab -> "model"
  EP    experts      -> "model"
  SP    kv_seq       -> "model"  (decode cache ring sharding)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import ParamSpec, is_spec

# logical axis -> preferred mesh axes, in priority order
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "embed": ("data",),  # FSDP on parameters
    "kv_seq": ("model",),  # decode-cache sequence sharding
    "capacity": ("data",),  # MoE expert-capacity axis (token parallel)
    "qk_rank": (),
    "kv_rank": (),
    "head_dim": (),
    "layers": (),
    "groups": (),
    "state": (),
    # Megatron-style sequence parallelism: the residual stream between
    # blocks is sharded over "model"; attention/MLP gather it on use.
    "seq": ("model",),
    # SSD chunk axis: intra-chunk work is independent per chunk, so the
    # chunk dimension shards over "model" (SSM heads often don't divide the
    # TP degree — 24 heads on 16-way TP — but NC = S/Q does).
    "chunks": ("model",),
}


@dataclass(frozen=True)
class ShardingRules:
    mesh_axes: Tuple[str, ...]
    mesh_shape: Dict[str, int]
    rules: Dict[str, Tuple[str, ...]]

    def resolve(self, dim: int, logical: Optional[str]) -> Optional[Any]:
        """Mesh axes for one tensor dimension (None = replicate)."""
        if logical is None:
            return None
        prefs = self.rules.get(logical, ())
        chosen: List[str] = []
        remaining = dim
        for axis in prefs:
            if axis not in self.mesh_shape:
                continue
            n = self.mesh_shape[axis]
            if remaining % n == 0 and n > 1:
                chosen.append(axis)
                remaining //= n
        if not chosen:
            return None
        return tuple(chosen) if len(chosen) > 1 else chosen[0]

    def spec_for(self, shape: Sequence[int], axes: Sequence[Optional[str]]) -> P:
        assert len(shape) == len(axes), (shape, axes)
        used: set = set()
        parts: List[Any] = []
        for dim, logical in zip(shape, axes):
            r = self.resolve(dim, logical)
            # a mesh axis may appear only once in a PartitionSpec
            if r is None:
                parts.append(None)
            elif isinstance(r, tuple):
                r2 = tuple(a for a in r if a not in used)
                used.update(r2)
                parts.append(r2 if r2 else None)
            else:
                if r in used:
                    parts.append(None)
                else:
                    used.add(r)
                    parts.append(r)
        return P(*parts)


def make_rules(mesh: Mesh, overrides: Optional[Dict[str, Tuple[str, ...]]] = None) -> ShardingRules:
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    return ShardingRules(
        mesh_axes=tuple(mesh.axis_names),
        mesh_shape={a: int(n) for a, n in zip(mesh.axis_names, mesh.shape.values())}
        if isinstance(mesh.shape, dict)
        else {a: int(n) for a, n in zip(mesh.axis_names, mesh.devices.shape)},
        rules=rules,
    )


# ---------------------------------------------------------------------------
# Tree-level helpers
# ---------------------------------------------------------------------------


def param_specs(rules: ShardingRules, spec_tree: Any) -> Any:
    """PartitionSpec tree for a ParamSpec tree."""
    return jax.tree_util.tree_map(
        lambda s: rules.spec_for(s.shape, s.axes), spec_tree, is_leaf=is_spec
    )


def tree_specs_from_axes(rules: ShardingRules, sds_tree: Any, axes_tree: Any) -> Any:
    """PartitionSpec tree for a ShapeDtypeStruct tree + logical-axes tree."""
    return jax.tree_util.tree_map(
        lambda s, ax: rules.spec_for(s.shape, ax), sds_tree, axes_tree
    )


def shardings_from_specs(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_specs(rules: ShardingRules, batch_tree: Any, seq_axis: Optional[str] = None) -> Any:
    """Input-batch PartitionSpecs: leading dim is the (global) batch."""

    def one(sds: jax.ShapeDtypeStruct) -> P:
        axes: List[Optional[str]] = ["batch"] + [None] * (len(sds.shape) - 1)
        if seq_axis and len(sds.shape) >= 2:
            axes[1] = seq_axis
        return rules.spec_for(sds.shape, axes)

    return jax.tree_util.tree_map(one, batch_tree)


def opt_state_specs(rules: ShardingRules, param_spec_tree: Any, opt_template: Any) -> Any:
    """Adam moments shard exactly like their parameters."""
    from repro.optim.adamw import AdamWState

    pspecs = param_specs(rules, param_spec_tree)
    return AdamWState(count=P(), mu=pspecs, nu=pspecs)
