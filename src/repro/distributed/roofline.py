"""Three-term roofline model for TPU v5e (the assignment's §Roofline).

  compute term    = HLO_FLOPs      / (chips * peak_FLOP/s)
  memory term     = HLO_bytes      / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

Hardware constants (per the assignment): 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI. HLO_FLOPs/HLO_bytes come from
``compiled.cost_analysis()`` on the dry-run; collective_bytes from the HLO
parser. All quantities are whole-module (all chips), hence the division.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (per chip, one direction)
DCN_BW = 25e9  # bytes/s per host for the "pod" axis (cross-pod)


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float  # 6·N·D (dense) / 6·N_active·D (MoE); 2·N·D serve
    pod_collective_bytes: float = 0.0  # portion crossing the DCN "pod" axis
    notes: str = ""

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        ici = (self.collective_bytes - self.pod_collective_bytes) / (self.chips * ICI_BW)
        dcn = self.pod_collective_bytes / (max(self.chips // 256, 1) * DCN_BW)
        return ici + dcn

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat & redundancy waste."""
        if self.hlo_flops <= 0:
            return 0.0
        return self.model_flops / self.hlo_flops

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU at the roofline: useful FLOPs / (chips * peak *
        step_time). This is the §Perf score for compute-bound cells; for
        memory/collective-bound cells it is what the bottleneck allows."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS_BF16 * t)

    def row(self) -> Dict[str, str]:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "compute_s": f"{self.compute_s:.4f}",
            "memory_s": f"{self.memory_s:.4f}",
            "collective_s": f"{self.collective_s:.4f}",
            "dominant": self.dominant,
            "model/hlo_flops": f"{self.useful_flops_fraction:.3f}",
            "roofline_frac": f"{self.roofline_fraction:.3f}",
        }

    def render(self) -> str:
        r = self.row()
        return (
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:10s} "
            f"C={r['compute_s']}s M={r['memory_s']}s X={r['collective_s']}s "
            f"dom={r['dominant']:10s} useful={r['model/hlo_flops']} "
            f"RF={r['roofline_frac']}"
        )
