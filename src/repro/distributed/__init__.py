from .fault_tolerance import (
    ElasticPlan,
    HeartbeatMonitor,
    StragglerPolicy,
    candidate_meshes,
    plan_elastic_config,
)
from .hlo_analysis import (
    CollectiveStats,
    collective_stats,
    cost_analysis_bytes,
    cost_analysis_flops,
    memory_analysis_dict,
    op_census,
)
from .roofline import DCN_BW, HBM_BW, ICI_BW, PEAK_FLOPS_BF16, RooflineTerms
from .sharding import (
    DEFAULT_RULES,
    ShardingRules,
    batch_specs,
    make_rules,
    opt_state_specs,
    param_specs,
    shardings_from_specs,
    tree_specs_from_axes,
)

__all__ = [
    "CollectiveStats",
    "DCN_BW",
    "DEFAULT_RULES",
    "ElasticPlan",
    "HBM_BW",
    "HeartbeatMonitor",
    "ICI_BW",
    "PEAK_FLOPS_BF16",
    "RooflineTerms",
    "ShardingRules",
    "StragglerPolicy",
    "batch_specs",
    "candidate_meshes",
    "collective_stats",
    "cost_analysis_bytes",
    "cost_analysis_flops",
    "make_rules",
    "memory_analysis_dict",
    "op_census",
    "opt_state_specs",
    "param_specs",
    "plan_elastic_config",
    "shardings_from_specs",
    "tree_specs_from_axes",
]
