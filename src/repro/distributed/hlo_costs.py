"""Whole-module HLO cost analyzer with while-loop trip multipliers.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
scan-over-layers module (all of ours) under-reports FLOPs/bytes/collectives
by ~the layer count. This module parses the optimized per-device HLO text,
builds the computation call graph, extracts while trip counts from loop
conditions, and rolls costs up with multipliers:

  flops       — 2 * prod(dot output dims) * prod(lhs contracting dims)
                (matmul flops only: the MXU-relevant count; elementwise ops
                are excluded on purpose so useful-FLOPs ratios stay honest)
  bytes       — sum over top-level materializing ops of output+operand bytes
                (fusion internals excluded: they never touch HBM)
  collectives — per-op output bytes, by collective kind

Validated against an unrolled-scan compile in tests/test_hlo_costs.py.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*)\((.*)$"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->\s*(.+?)\s*\{")
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_CALLS_SET_RE = re.compile(r"calls=\{([^}]*)\}")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"\bs(?:32|64)\[\]\s+constant\((\d+)\)")

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)

# ops that don't materialize new HBM buffers
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _shapes_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclass
class _Op:
    name: str
    kind: str
    out_bytes: int
    out_dims: List[int]
    operands: List[str]
    line: str


@dataclass
class _Computation:
    name: str
    ops: List[_Op] = field(default_factory=list)
    defs: Dict[str, Tuple[str, List[int], int]] = field(default_factory=dict)
    # (dtype, dims, bytes) per var


@dataclass
class ModuleCosts:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    collective_counts: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    while_trips: Dict[str, int] = field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _parse_computations(text: str) -> Tuple[Dict[str, _Computation], Optional[str]]:
    comps: Dict[str, _Computation] = {}
    entry: Optional[str] = None
    cur: Optional[_Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and line.strip().endswith("{"):
            cur = _Computation(name=hdr.group(1))
            comps[cur.name] = cur
            if line.strip().startswith("ENTRY"):
                entry = cur.name
            # record parameter types
            for pm in re.finditer(r"([\w.\-]+):\s*([a-z0-9]+\[[0-9,]*\])", hdr.group(2)):
                sd = _shape_dims(pm.group(2))
                if sd:
                    cur.defs[pm.group(1)] = (sd[0], sd[1], _shapes_bytes(pm.group(2)))
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, out_type, kind, rest = m.groups()
        out_bytes = _shapes_bytes(out_type)
        sd = _shape_dims(out_type)
        out_dims = sd[1] if sd else []
        # operand names: %var tokens inside the parens (first level is fine)
        paren = rest.split(")", 1)[0]
        operands = re.findall(r"%([\w.\-]+)", paren)
        cur.ops.append(_Op(name, kind, out_bytes, out_dims, operands, line.strip()))
        cur.defs[name] = (sd[0] if sd else "", out_dims, out_bytes)
    return comps, entry


def _while_trip_count(comps: Dict[str, _Computation], cond_name: str, default: int) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return default
    consts = []
    for op in cond.ops:
        for m in _CONST_RE.finditer(op.line):
            consts.append(int(m.group(1)))
    # scan conditions compare the induction var against the trip count
    return max(consts) if consts else default


def _dot_flops(comp: _Computation, op: _Op) -> float:
    out_elems = 1
    for d in op.out_dims:
        out_elems *= d
    cm = _CONTRACT_RE.search(op.line)
    contract = 1
    if cm and op.operands:
        lhs = comp.defs.get(op.operands[0])
        if lhs is not None:
            dims = lhs[1]
            idxs = [int(x) for x in cm.group(1).split(",")] if cm.group(1) else []
            for i in idxs:
                if i < len(dims):
                    contract *= dims[i]
    return 2.0 * out_elems * contract


def analyze_module(text: str, default_trip: int = 1) -> ModuleCosts:
    comps, entry = _parse_computations(text)
    costs = ModuleCosts()
    memo: Dict[str, Tuple[float, float, Dict[str, float], Dict[str, float]]] = {}

    def operand_bytes(comp: _Computation, op: _Op) -> int:
        total = 0
        for o in op.operands:
            d = comp.defs.get(o)
            if d is not None:
                total += d[2]
        return total

    def visit(name: str, stack: Tuple[str, ...] = ()) -> Tuple[float, float, Dict[str, float], Dict[str, float]]:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return (0.0, 0.0, {}, {})
        comp = comps[name]
        fl = 0.0
        by = 0.0
        coll_b: Dict[str, float] = defaultdict(float)
        coll_n: Dict[str, float] = defaultdict(float)
        is_fused = name.startswith("fused_") or ".fused" in name or name.startswith("wide.")
        for op in comp.ops:
            if op.kind == "while":
                wm = _WHILE_RE.search(op.line)
                if wm:
                    trip = _while_trip_count(comps, wm.group(1), default_trip)
                    costs.while_trips[op.name] = trip
                    bfl, bby, bcb, bcn = visit(wm.group(2), stack + (name,))
                    fl += trip * bfl
                    by += trip * bby
                    for k, v in bcb.items():
                        coll_b[k] += trip * v
                    for k, v in bcn.items():
                        coll_n[k] += trip * v
                continue
            if op.kind == "dot":
                fl += _dot_flops(comp, op)
                by += op.out_bytes + operand_bytes(comp, op)
                continue
            if op.kind in ("fusion", "call", "custom-call", "conditional", "async-start"):
                for cs in _CALLS_SET_RE.finditer(op.line):
                    for cn in re.findall(r"%?([\w.\-]+)", cs.group(1)):
                        bfl, bby, bcb, bcn = visit(cn, stack + (name,))
                        fl += bfl
                        for k, v in bcb.items():
                            coll_b[k] += v
                        for k, v in bcn.items():
                            coll_n[k] += v
                if not _CALLS_SET_RE.search(op.line):
                    for cm_ in _CALL_ATTR_RE.finditer(op.line):
                        bfl, bby, bcb, bcn = visit(cm_.group(1), stack + (name,))
                        fl += bfl
                        for k, v in bcb.items():
                            coll_b[k] += v
                        for k, v in bcn.items():
                            coll_n[k] += v
                by += op.out_bytes + operand_bytes(comp, op)
                continue
            hit_coll = False
            for c in _COLLECTIVES:
                if op.kind == c or op.kind == c + "-start":
                    coll_b[c] += op.out_bytes
                    coll_n[c] += 1
                    by += op.out_bytes + operand_bytes(comp, op)
                    hit_coll = True
                    break
            if hit_coll:
                continue
            if op.kind in _FREE_OPS or op.kind.endswith("-done"):
                continue
            # generic materializing op at computation top level
            if not is_fused:
                by += op.out_bytes + operand_bytes(comp, op)
        out = (fl, by, dict(coll_b), dict(coll_n))
        memo[name] = out
        return out

    if entry is None:
        # fall back: largest computation
        entry = max(comps, key=lambda n: len(comps[n].ops)) if comps else ""
    fl, by, cb, cn = visit(entry)
    costs.flops = fl
    costs.bytes = by
    for k, v in cb.items():
        costs.collective_bytes[k] += v
    for k, v in cn.items():
        costs.collective_counts[k] += v
    return costs
