"""Int8 error-feedback gradient compression for cross-pod traffic.

Distributed-optimization trick for the "pod" (DCN) axis: gradients are
block-scale int8-quantized before the cross-pod reduction (4x fewer DCN
bytes); the quantization residual is carried to the next step (error
feedback, Seide et al. 2014-style), which restores convergence to near-
uncompressed quality.

Two code paths:
  * ``compress_tree`` / ``decompress_tree`` — explicit wire format (the grid
    runtime ships these payloads between hosts; BOINC's "upload compression"
    §2.2 adapted to tensors);
  * ``ef_quantize_tree`` — in-graph round-trip + residual update, used
    inside the jitted train step before the 'pod' psum.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.int8_quant.ops import int8_dequantize, int8_quantize


def ef_quantize_tree(
    grads: Any, residual: Any, interpret: bool = True
) -> Tuple[Any, Any]:
    """Quantize (grads + residual) to int8 resolution in-graph; returns
    (quantized_grads, new_residual). Shapes/dtypes preserved."""

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        # block-scale emulated inline (the Pallas kernel is the TPU path;
        # inline keeps this differentiable-free math fusable in the step)
        amax = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12)
        scale = amax / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127)
        deq = q * scale
        return deq.astype(g.dtype), (g32 - deq)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    qs = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    rs = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return qs, rs


def init_residual(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


# ---------------------------------------------------------------------------
# Wire format (host-to-coordinator payloads in the grid runtime)
# ---------------------------------------------------------------------------


def compress_tree(tree: Any, interpret: bool = True) -> Dict[str, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = []
    for leaf in leaves:
        q, s = int8_quantize(jnp.asarray(leaf), interpret=interpret)
        payload.append(
            {"q": q, "s": s, "n": leaf.size, "shape": tuple(leaf.shape), "dtype": str(leaf.dtype)}
        )
    return {"treedef": treedef, "payload": payload}


def decompress_tree(packed: Dict[str, Any], interpret: bool = True) -> Any:
    leaves = []
    for item in packed["payload"]:
        x = int8_dequantize(
            item["q"],
            item["s"],
            n=item["n"],
            shape=item["shape"],
            out_dtype=jnp.dtype(item["dtype"]),
            interpret=interpret,
        )
        leaves.append(x)
    return jax.tree_util.tree_unflatten(packed["treedef"], leaves)


def compressed_bytes(packed: Dict[str, Any]) -> int:
    return sum(i["q"].size + i["s"].size * 4 for i in packed["payload"])
