from .adamw import AdamWConfig, AdamWState, apply_updates, global_norm, init_state, lr_at
from .compression import (
    compress_tree,
    compressed_bytes,
    decompress_tree,
    ef_quantize_tree,
    init_residual,
)

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "apply_updates",
    "compress_tree",
    "compressed_bytes",
    "decompress_tree",
    "ef_quantize_tree",
    "global_norm",
    "init_residual",
    "init_state",
    "lr_at",
]
