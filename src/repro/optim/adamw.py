"""AdamW with decoupled weight decay, global-norm clipping, LR schedules.

Built here (no optax dependency per the scope rule). The optimizer state is
a pytree shaped like the params (sharded identically by the step builder),
plus a scalar count.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array  # ()
    mu: Any  # first moments (pytree like params)
    nu: Any  # second moments


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | wsd | constant
    final_lr_fraction: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "wsd":  # warmup-stable-decay: linear tail 20%
        tail = 0.2 * cfg.total_steps
        into_tail = jnp.maximum(0.0, s - (cfg.total_steps - tail))
        decay = 1.0 - (1.0 - cfg.final_lr_fraction) * jnp.minimum(1.0, into_tail / tail)
    else:  # cosine
        frac = jnp.clip(s / max(cfg.total_steps, 1), 0.0, 1.0)
        decay = cfg.final_lr_fraction + (1.0 - cfg.final_lr_fraction) * 0.5 * (
            1.0 + jnp.cos(math.pi * frac)
        )
    return cfg.lr * warm * decay


def init_state(params: Any) -> AdamWState:
    # mu and nu must be DISTINCT buffers (both are donated by the train step)
    mu = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    nu = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(count=jnp.zeros((), jnp.int32), mu=mu, nu=nu)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def apply_updates(
    cfg: AdamWConfig,
    params: Any,
    grads: Any,
    state: AdamWState,
) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    count = state.count + 1
    lr = lr_at(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = cfg.b1 * m + (1.0 - cfg.b1) * g
        v2 = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m2 / b1c
        vhat = v2 / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * (step + decay)
        return newp.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(count=count, mu=new_m, nu=new_v), metrics
