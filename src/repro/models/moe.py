"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Dispatch is sort-free scatter/gather (no (tokens, E, C) one-hot tensor):
  1. router top-k -> (token, expert, weight) triples;
  2. position-within-expert via cumulative counts;
  3. scatter token activations into an (E, C, d) buffer (drop over capacity);
  4. batched expert SwiGLU: einsum over the expert-major buffer (the expert
     axis is sharded over the "model" mesh axis — expert parallelism; GSPMD
     inserts the token all-to-all at the scatter/gather boundary);
  5. gather back and combine with routing weights; over-capacity tokens fall
     through via the residual connection.

Supports top-1 + shared expert (Llama-4 Scout style) and 128-expert top-8
(Qwen3-MoE style). FLOPs scale with *active* experts times the capacity
factor, so the roofline's MODEL_FLOPS/HLO_FLOPs stays honest.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import ParamSpec, swiglu
from repro.distributed.logical import constrain


@dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_expert: int  # per-expert FFN width
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    n_shared_experts: int = 0  # shared expert width = n_shared * d_expert
    router_aux_weight: float = 0.01
    normalize_router_weights: bool = True  # softmax over the selected top-k
    # hierarchical dispatch: positions are computed within contiguous token
    # blocks (= data shards on the production mesh), so every scatter into
    # the (E, C, d) buffer writes a capacity strip aligned with the writing
    # shard — dispatch crosses only the expert ("model") axis, the
    # fundamental EP all-to-all. 16 = the production data axis.
    dispatch_blocks: int = 16


def moe_spec(cfg: MoEConfig) -> Dict[str, ParamSpec]:
    spec = {
        "router": ParamSpec((cfg.d_model, cfg.n_experts), ("embed", "experts")),
        "w_gate": ParamSpec(
            (cfg.n_experts, cfg.d_model, cfg.d_expert), ("experts", "embed", "mlp")
        ),
        "w_up": ParamSpec(
            (cfg.n_experts, cfg.d_model, cfg.d_expert), ("experts", "embed", "mlp")
        ),
        "w_down": ParamSpec(
            (cfg.n_experts, cfg.d_expert, cfg.d_model), ("experts", "mlp", "embed")
        ),
    }
    if cfg.n_shared_experts > 0:
        ds = cfg.n_shared_experts * cfg.d_expert
        spec["shared_gate"] = ParamSpec((cfg.d_model, ds), ("embed", "mlp"))
        spec["shared_up"] = ParamSpec((cfg.d_model, ds), ("embed", "mlp"))
        spec["shared_down"] = ParamSpec((ds, cfg.d_model), ("mlp", "embed"))
    return spec


def capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(cfg.capacity_factor * cfg.top_k * n_tokens / cfg.n_experts) + 1
    # Deliberately NOT a multiple of the data-axis size: sharding the
    # capacity axis makes GSPMD replicate the (N, d) per-assignment values
    # (137 GB/layer measured) instead of reducing the buffer (10.7 GB).
    # See EXPERIMENTS.md §Perf iterations A1-A3.
    return max(8, -(-c // 8) * 8)


def moe_forward(
    params: Dict[str, jax.Array],
    x: jax.Array,  # (B, S, d)
    cfg: MoEConfig,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (B,S,d), aux_loss scalar)."""
    dt = x.dtype
    b, s, d = x.shape
    nt = b * s
    xf = x.reshape(nt, d)

    logits = jnp.einsum("td,de->te", xf, params["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, cfg.top_k)  # (nt, k)
    if cfg.normalize_router_weights:
        top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((cfg.n_experts,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (nt * cfg.top_k)
    )
    aux = cfg.router_aux_weight * cfg.n_experts * jnp.sum(me * ce)

    # flatten (token, k) assignment triples
    flat_e = top_e.reshape(-1)  # (nt*k,)
    flat_w = top_w.reshape(-1).astype(dt)
    flat_t = jnp.repeat(jnp.arange(nt), cfg.top_k)

    cap = capacity(nt, cfg)
    n = flat_e.shape[0]
    blocks = cfg.dispatch_blocks if n % cfg.dispatch_blocks == 0 else 1
    cap_block = max(8, -(-cap // blocks))
    cap = cap_block * blocks
    # hierarchical positions: each contiguous token block fills its own
    # capacity strip [b*cap_block, (b+1)*cap_block) of every expert
    pos = _position_in_expert_blocked(flat_e, cfg.n_experts, blocks)  # (n,)
    keep = pos < cap_block
    block_id = jnp.arange(n, dtype=jnp.int32) // (n // blocks)
    pos_c = block_id * cap_block + jnp.minimum(pos, cap_block - 1)
    # Dropped assignments scatter a ZERO into their strip's last slot
    # (harmless: the gather-back is masked by ``keep``); scatter-ADD keeps
    # duplicate hits at that slot from clobbering a valid one.
    val = jnp.where(keep[:, None], xf[flat_t], jnp.zeros((1, d), dt))
    buf = jnp.zeros((cfg.n_experts, cap, d), dt).at[flat_e, pos_c].add(val)
    buf = constrain(buf, ("experts", None, None))

    # expert computation (expert axis sharded over "model")
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(dt))
    h = swiglu(g, u)
    eo = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dt))
    eo = constrain(eo, ("experts", None, None))

    # gather back & weighted combine (dropped tokens contribute zero)
    per_assign = eo[flat_e, pos_c] * (flat_w * keep.astype(dt))[:, None]
    out = jnp.zeros((nt, d), dt).at[flat_t].add(per_assign)

    if cfg.n_shared_experts > 0:
        sg = jnp.einsum("td,df->tf", xf, params["shared_gate"].astype(dt))
        su = jnp.einsum("td,df->tf", xf, params["shared_up"].astype(dt))
        out = out + jnp.einsum(
            "tf,fd->td", swiglu(sg, su), params["shared_down"].astype(dt)
        )
    return out.reshape(b, s, d), aux


def _position_in_expert_blocked(
    flat_e: jax.Array, n_experts: int, blocks: int
) -> jax.Array:
    """Index of each assignment within its (block, expert) queue.

    Sort-based per block: O(N log(N/B)) with no (N, E) intermediates; all
    ops are batched over the block axis, which is data-sharded, so the whole
    position computation is shard-local on the production mesh.
    """
    n = flat_e.shape[0]
    nb = n // blocks
    e2 = flat_e.reshape(blocks, nb)
    order = jnp.argsort(e2, axis=1, stable=True)  # (B, nb)
    sorted_e = jnp.take_along_axis(e2, order, axis=1)
    counts = jnp.zeros((blocks, n_experts), jnp.int32)
    counts = counts.at[jnp.arange(blocks)[:, None], e2].add(1)
    starts = jnp.cumsum(counts, axis=1) - counts  # exclusive prefix per block
    pos_sorted = jnp.arange(nb, dtype=jnp.int32)[None, :] - jnp.take_along_axis(
        starts, sorted_e, axis=1
    )
    pos = jnp.zeros((blocks, nb), jnp.int32)
    pos = pos.at[jnp.arange(blocks)[:, None], order].set(pos_sorted)
    return pos.reshape(n)
