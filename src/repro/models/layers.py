"""Parameter descriptors and basic layers shared by all architectures.

Single source of truth for parameters: each module contributes a tree of
``ParamSpec`` descriptors; from that one tree we derive
  * materialized params        (``init_params``)
  * ShapeDtypeStruct stand-ins (``abstract_params`` — dry-run, no allocation)
  * logical-axis trees         (``axes_tree`` — consumed by sharding rules)

Logical axis names (resolved to mesh axes in ``repro.distributed.sharding``):
  batch, seq, embed, vocab, heads, kv_heads, head_dim, mlp, experts,
  layers, groups, state, qk_rank, kv_rank
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Parameter descriptors
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float = 1.0
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def _tree_map_specs(fn: Callable[[ParamSpec], Any], tree: Any) -> Any:
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def init_params(key: jax.Array, spec_tree: Any, dtype: Any = None) -> Any:
    """Materialize a spec tree into real arrays (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for i, spec in enumerate(leaves):
        dt = dtype or spec.dtype
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, dt)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, dt)
        else:
            std = spec.scale
            if spec.init == "normal" and spec.scale == 1.0:
                # fan-in scaled by default
                fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
                std = 1.0 / math.sqrt(max(fan_in, 1))
            elif spec.init == "embed":
                std = 0.02
            arr = (jax.random.normal(keys[i], spec.shape, jnp.float32) * std).astype(dt)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(spec_tree: Any, dtype: Any = None) -> Any:
    """ShapeDtypeStruct stand-ins — used by the multi-pod dry-run."""
    return _tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype or s.dtype), spec_tree
    )


def axes_tree(spec_tree: Any) -> Any:
    return _tree_map_specs(lambda s: s.axes, spec_tree)


def count_params(spec_tree: Any) -> int:
    leaves, _ = jax.tree_util.tree_flatten(spec_tree, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


def stack_layer_specs(spec_tree: Any, n_layers: int, axis_name: str = "layers") -> Any:
    """Add a leading scanned-layers dimension to every spec in the tree."""
    return _tree_map_specs(
        lambda s: ParamSpec(
            shape=(n_layers,) + s.shape,
            axes=(axis_name,) + s.axes,
            init=s.init,
            scale=s.scale,
            dtype=s.dtype,
        ),
        spec_tree,
    )


# ---------------------------------------------------------------------------
# Normalization / activation / positional layers
# ---------------------------------------------------------------------------


def rmsnorm_spec(dim: int) -> Dict[str, ParamSpec]:
    return {"scale": ParamSpec((dim,), ("embed",), init="ones")}


def rms_norm(params: Dict[str, jax.Array], x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def head_rms_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """qk-norm (Qwen3): RMS over the head_dim axis of (..., heads, head_dim)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotate (..., S, H, D) by position; positions is (..., S)."""
    dt = x.dtype
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (d/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, d/2)
    angles = angles[..., :, None, :]  # broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


# ---------------------------------------------------------------------------
# Embedding / unembedding (vocab sharded; loss never replicates logits)
# ---------------------------------------------------------------------------


def embedding_spec(vocab: int, d_model: int) -> Dict[str, ParamSpec]:
    return {"embedding": ParamSpec((vocab, d_model), ("vocab", "embed"), init="embed")}


def embed_tokens(params: Dict[str, jax.Array], tokens: jax.Array, compute_dtype: Any) -> jax.Array:
    emb = params["embedding"]
    return emb.astype(compute_dtype)[tokens]


def unembed_logits(params: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    """(B, S, d) -> (B, S, V); vocab dimension stays sharded."""
    emb = params["embedding"].astype(x.dtype)
    return jnp.einsum("bsd,vd->bsv", x, emb)


def cross_entropy_from_logits(
    logits: jax.Array,
    labels: jax.Array,
    mask: Optional[jax.Array] = None,
    valid_vocab: int = 0,
    reduce: bool = True,
) -> jax.Array:
    """Mean CE over tokens, shard-local in the vocab dimension.

    Everything is expressed as reductions over the (sharded) vocab axis —
    max, logsumexp, and a one-hot contraction for the label logit (instead
    of take_along_axis, whose gather would force GSPMD to all-gather the
    full logits). Padded vocab entries are masked with an iota compare
    (instead of a scatter). The only cross-shard traffic is (B, S)-sized
    all-reduces over 'model'.
    """
    logits = logits.astype(jnp.float32)
    viota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    if valid_vocab and valid_vocab < logits.shape[-1]:
        logits = jnp.where(viota < valid_vocab, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + jnp.squeeze(m, -1)
    onehot = viota == labels[..., None]
    ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - ll
    if mask is not None:
        nll = nll * mask
    if not reduce:
        return nll
    if mask is not None:
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU)
# ---------------------------------------------------------------------------


def mlp_spec(d_model: int, d_ff: int) -> Dict[str, ParamSpec]:
    return {
        "gate": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "up": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "down": ParamSpec((d_ff, d_model), ("mlp", "embed")),
    }


def mlp_forward(params: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    dt = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, params["gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, params["up"].astype(dt))
    h = swiglu(g, u)
    return jnp.einsum("bsf,fd->bsd", h, params["down"].astype(dt))


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------


def pad_vocab(vocab: int, multiple: int = 256) -> int:
    """Pad embedding tables so the vocab axis shards evenly (Megatron-style)."""
    return ((vocab + multiple - 1) // multiple) * multiple


def causal_mask(s_q: int, s_k: int, offset: int = 0) -> jax.Array:
    """Boolean (s_q, s_k) mask; query i attends to keys <= i + offset."""
    q = jnp.arange(s_q)[:, None] + offset
    k = jnp.arange(s_k)[None, :]
    return k <= q
