"""Model zoo: the 'apps' the BOINC grid schedules."""
from .config import SHAPES, ModelConfig, ShapeConfig, cell_supported, get_shape
from .layers import (
    ParamSpec,
    abstract_params,
    axes_tree,
    count_params,
    init_params,
)
from .transformer import (
    cache_axes,
    cache_spec,
    forward,
    init_cache,
    model_spec,
    train_loss,
)

__all__ = [
    "SHAPES",
    "ModelConfig",
    "ParamSpec",
    "ShapeConfig",
    "abstract_params",
    "axes_tree",
    "cache_axes",
    "cache_spec",
    "cell_supported",
    "count_params",
    "forward",
    "get_shape",
    "init_cache",
    "init_params",
    "model_spec",
    "train_loss",
]
