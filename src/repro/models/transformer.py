"""Unified decoder/encoder stacks for all assigned architectures.

Every stack is **scanned over layers** (jax.lax.scan with stacked per-layer
parameters) so HLO size and compile time are O(1) in depth — required to
dry-run the 94-layer MoE and 64-layer 104B configs on this build machine.

Families:
  dense / vlm / audio : [rmsnorm -> attention -> +res -> rmsnorm -> SwiGLU MLP -> +res] xL
  moe                 : same with MoE FFN (+ optional shared expert)
  ssm                 : [rmsnorm -> mamba2 -> +res] xL
  hybrid (zamba2)     : groups of mamba layers with ONE weight-tied
                        attention+MLP block applied after each group
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (
    AttnConfig,
    MLAConfig,
    gqa_cache_shape,
    gqa_forward,
    gqa_spec,
    mla_cache_shape,
    mla_forward,
    mla_spec,
)
from .config import ModelConfig
from repro.distributed.logical import constrain
from .layers import (
    ParamSpec,
    cross_entropy_from_logits,
    embed_tokens,
    embedding_spec,
    mlp_forward,
    mlp_spec,
    rms_norm,
    rmsnorm_spec,
    stack_layer_specs,
    unembed_logits,
)
from .moe import MoEConfig, moe_forward, moe_spec
from .ssm import (
    SSMConfig,
    mamba2_decode_step,
    mamba2_forward,
    mamba2_spec,
    mamba2_state_shape,
)

# ---------------------------------------------------------------------------
# Config adapters
# ---------------------------------------------------------------------------


def attn_config(cfg: ModelConfig) -> AttnConfig:
    return AttnConfig(
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads or cfg.n_heads,
        head_dim=cfg.resolved_head_dim,
        rope_theta=cfg.rope_theta,
        qk_norm=cfg.qk_norm,
        causal=cfg.causal,
        norm_eps=cfg.norm_eps,
        chunk=cfg.attn_chunk,
    )


def mla_config(cfg: ModelConfig) -> MLAConfig:
    return MLAConfig(
        n_heads=cfg.n_heads,
        q_lora_rank=cfg.q_lora_rank,
        kv_lora_rank=cfg.kv_lora_rank,
        qk_nope_dim=cfg.qk_nope_dim,
        qk_rope_dim=cfg.qk_rope_dim,
        v_head_dim=cfg.v_head_dim,
        rope_theta=cfg.rope_theta,
        norm_eps=cfg.norm_eps,
        chunk=cfg.attn_chunk,
    )


def moe_config(cfg: ModelConfig) -> MoEConfig:
    return MoEConfig(
        d_model=cfg.d_model,
        d_expert=cfg.d_expert or cfg.d_ff,
        n_experts=cfg.n_experts,
        top_k=cfg.top_k,
        capacity_factor=cfg.capacity_factor,
        n_shared_experts=cfg.n_shared_experts,
    )


def ssm_config(cfg: ModelConfig) -> SSMConfig:
    return SSMConfig(
        d_model=cfg.d_model,
        d_state=cfg.ssm_state,
        d_conv=cfg.ssm_conv,
        expand=cfg.ssm_expand,
        head_dim=cfg.ssm_head_dim,
        n_groups=cfg.ssm_groups,
        chunk=cfg.ssm_chunk,
        norm_eps=cfg.norm_eps,
    )


def hybrid_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_groups, layers_per_group, tail_layers) for hybrid stacks."""
    period = cfg.shared_attn_period
    n_groups = cfg.n_layers // period
    tail = cfg.n_layers - n_groups * period
    return n_groups, period, tail


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def _attn_spec(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.attention == "mla":
        return mla_spec(
            cfg.d_model,
            cfg.n_heads,
            cfg.q_lora_rank,
            cfg.kv_lora_rank,
            cfg.qk_nope_dim,
            cfg.qk_rope_dim,
            cfg.v_head_dim,
        )
    return gqa_spec(
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv_heads or cfg.n_heads,
        cfg.resolved_head_dim,
        qk_norm=cfg.qk_norm,
    )


def _dense_block_spec(cfg: ModelConfig) -> Dict[str, Any]:
    spec: Dict[str, Any] = {
        "attn_norm": rmsnorm_spec(cfg.d_model),
        "attn": _attn_spec(cfg),
        "mlp_norm": rmsnorm_spec(cfg.d_model),
    }
    if cfg.family == "moe":
        spec["moe"] = moe_spec(moe_config(cfg))
    else:
        spec["mlp"] = mlp_spec(cfg.d_model, cfg.d_ff)
    return spec


def _mamba_block_spec(cfg: ModelConfig) -> Dict[str, Any]:
    return {"norm": rmsnorm_spec(cfg.d_model), "mamba": mamba2_spec(ssm_config(cfg))}


def model_spec(cfg: ModelConfig) -> Dict[str, Any]:
    spec: Dict[str, Any] = {}
    if cfg.vocab:
        spec["embed"] = embedding_spec(cfg.padded_vocab, cfg.d_model)
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        spec["layers"] = stack_layer_specs(_dense_block_spec(cfg), cfg.n_layers)
    elif cfg.family == "ssm":
        spec["layers"] = stack_layer_specs(_mamba_block_spec(cfg), cfg.n_layers)
    elif cfg.family == "hybrid":
        ng, per, tail = hybrid_layout(cfg)
        spec["groups"] = stack_layer_specs(
            stack_layer_specs(_mamba_block_spec(cfg), per), ng, axis_name="groups"
        )
        if tail:
            spec["tail"] = stack_layer_specs(_mamba_block_spec(cfg), tail)
        # the weight-tied shared transformer block (Zamba2)
        spec["shared_attn"] = {
            "attn_norm": rmsnorm_spec(cfg.d_model),
            "attn": gqa_spec(
                cfg.d_model, cfg.n_heads, cfg.n_kv_heads or cfg.n_heads, cfg.resolved_head_dim
            ),
            "mlp_norm": rmsnorm_spec(cfg.d_model),
            "mlp": mlp_spec(cfg.d_model, cfg.d_ff),
        }
    else:
        raise ValueError(f"unknown family {cfg.family}")
    spec["final_norm"] = rmsnorm_spec(cfg.d_model)
    return spec


def _remat_policy(cfg: ModelConfig):
    return {
        "nothing": jax.checkpoint_policies.nothing_saveable,
        "dots_nb": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        "dots": jax.checkpoint_policies.dots_saveable,
    }[cfg.remat_policy]


# ---------------------------------------------------------------------------
# Block forwards
# ---------------------------------------------------------------------------


def _dense_block(
    lp: Dict[str, Any],
    x: jax.Array,
    cfg: ModelConfig,
    positions: Optional[jax.Array],
    cache: Optional[Dict[str, jax.Array]],
    cache_index: Optional[jax.Array],
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]], jax.Array]:
    x = constrain(x, ("batch", "seq", None))
    h = rms_norm(lp["attn_norm"], x, cfg.norm_eps)
    if cfg.attention == "mla":
        a, new_cache = mla_forward(lp["attn"], h, mla_config(cfg), positions, cache, cache_index)
    else:
        a, new_cache = gqa_forward(lp["attn"], h, attn_config(cfg), positions, cache, cache_index)
    x = x + constrain(a, ("batch", "seq", None))
    h = rms_norm(lp["mlp_norm"], x, cfg.norm_eps)
    if cfg.family == "moe":
        m, aux = moe_forward(lp["moe"], h, moe_config(cfg))
    else:
        m, aux = mlp_forward(lp["mlp"], h), jnp.zeros((), jnp.float32)
    return x + constrain(m, ("batch", "seq", None)), new_cache, aux


def _mamba_block(
    lp: Dict[str, Any],
    x: jax.Array,
    cfg: ModelConfig,
    state: Optional[Dict[str, jax.Array]],
    decode: bool,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    x = constrain(x, ("batch", "seq", None))
    h = rms_norm(lp["norm"], x, cfg.norm_eps)
    if decode:
        m, new_state = mamba2_decode_step(lp["mamba"], h, ssm_config(cfg), state)
    else:
        m, new_state = mamba2_forward(lp["mamba"], h, ssm_config(cfg), state)
    return x + constrain(m, ("batch", "seq", None)), new_state


# ---------------------------------------------------------------------------
# Stacks (scan over layers)
# ---------------------------------------------------------------------------


def _scan_dense(
    layers: Dict[str, Any],
    x: jax.Array,
    cfg: ModelConfig,
    positions: Optional[jax.Array],
    cache: Optional[Dict[str, jax.Array]],
    cache_index: Optional[jax.Array],
    train: bool,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]], jax.Array]:
    def body(carry, xs):
        h, aux = carry
        lp, lcache = xs
        h2, new_cache, a = _dense_block(lp, h, cfg, positions, lcache, cache_index)
        return (h2, aux + a), new_cache

    fn = body
    if cfg.remat and train:
        fn = jax.checkpoint(body, policy=_remat_policy(cfg))
    (x, aux), new_cache = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)), (layers, cache))
    return x, new_cache, aux


def _scan_mamba(
    layers: Dict[str, Any],
    x: jax.Array,
    cfg: ModelConfig,
    state: Optional[Dict[str, jax.Array]],
    decode: bool,
    train: bool,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    def body(h, xs):
        lp, lstate = xs
        h2, new_state = _mamba_block(lp, h, cfg, lstate, decode)
        return h2, new_state

    fn = body
    if cfg.remat and train:
        fn = jax.checkpoint(body, policy=_remat_policy(cfg))
    x, new_state = jax.lax.scan(fn, x, (layers, state))
    return x, new_state


def _hybrid_forward(
    params: Dict[str, Any],
    x: jax.Array,
    cfg: ModelConfig,
    positions: Optional[jax.Array],
    cache: Optional[Dict[str, Any]],
    cache_index: Optional[jax.Array],
    decode: bool,
    train: bool,
) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
    shared = params["shared_attn"]
    acfg = attn_config(cfg)

    def shared_block(h, attn_cache):
        a_in = rms_norm(shared["attn_norm"], h, cfg.norm_eps)
        a, new_attn_cache = gqa_forward(shared["attn"], a_in, acfg, positions, attn_cache, cache_index)
        h = h + a
        m_in = rms_norm(shared["mlp_norm"], h, cfg.norm_eps)
        return h + mlp_forward(shared["mlp"], m_in), new_attn_cache

    def group_body(h, xs):
        gp, gstate, gattn = xs
        h, new_state = _scan_mamba(gp, h, cfg, gstate, decode, train=False)
        h, new_attn = shared_block(h, gattn)
        return h, (new_state, new_attn)

    fn = group_body
    if cfg.remat and train:
        fn = jax.checkpoint(group_body, policy=_remat_policy(cfg))
    gstate = cache["groups_mamba"] if cache is not None else None
    gattn = cache["groups_attn"] if cache is not None else None
    x, (new_gstate, new_gattn) = jax.lax.scan(fn, x, (params["groups"], gstate, gattn))

    new_cache = None
    new_tail = None
    if "tail" in params:
        tstate = cache["tail"] if cache is not None else None
        x, new_tail = _scan_mamba(params["tail"], x, cfg, tstate, decode, train)
    if cache is not None:
        new_cache = {"groups_mamba": new_gstate, "groups_attn": new_gattn}
        if "tail" in params:
            new_cache["tail"] = new_tail
    return x, new_cache


# ---------------------------------------------------------------------------
# Model entry points
# ---------------------------------------------------------------------------


def forward(
    params: Dict[str, Any],
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,
    embeds: Optional[jax.Array] = None,
    cache: Optional[Dict[str, Any]] = None,
    cache_index: Optional[jax.Array] = None,
    train: bool = False,
    return_hidden: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, Any]], jax.Array]:
    """Returns (logits (B,S,V_padded) or hidden, new_cache, aux_loss)."""
    if embeds is not None:
        x = embeds.astype(cfg.dtype)
    else:
        assert tokens is not None
        x = embed_tokens(params["embed"], tokens, cfg.dtype)
    x = constrain(x, ("batch", "seq", None))
    b, s = x.shape[:2]
    base = cache_index if cache_index is not None else 0
    positions = jnp.broadcast_to(base + jnp.arange(s)[None, :], (b, s))
    aux = jnp.zeros((), jnp.float32)
    decode = cache is not None and s == 1

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        lcache = cache["layers"] if cache is not None else None
        x, new_lcache, aux = _scan_dense(
            params["layers"], x, cfg, positions, lcache, cache_index, train
        )
        new_cache = {"layers": new_lcache} if cache is not None else None
    elif cfg.family == "ssm":
        lstate = cache["layers"] if cache is not None else None
        x, new_lstate = _scan_mamba(params["layers"], x, cfg, lstate, decode, train)
        new_cache = {"layers": new_lstate} if cache is not None else None
    elif cfg.family == "hybrid":
        x, new_cache = _hybrid_forward(
            params, x, cfg, positions, cache, cache_index, decode, train
        )
    else:
        raise ValueError(cfg.family)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    x = constrain(x, ("batch", "seq", None))
    if return_hidden:
        return x, new_cache, aux
    logits = constrain(unembed_logits(params["embed"], x), ("batch", "seq", "vocab"))
    return logits, new_cache, aux


def train_loss(
    params: Dict[str, Any],
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    ce_chunk: int = 512,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token (or frame-classification) CE loss + aux.

    The loss is computed in **sequence chunks with rematerialization**: full
    (B, S, V) logits are never alive — per chunk, unembed + CE run forward
    and are recomputed in backward. For the 150k-256k-vocab archs this is
    the difference between ~4 GB and ~0.5 GB of logits-shaped f32 buffers
    per device (several copies each).
    """
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    labels = batch["labels"]
    mask = batch.get("mask")
    hidden, _, aux = forward(
        params, cfg, tokens=tokens, embeds=embeds, train=True, return_hidden=True
    )
    b, s, d = hidden.shape
    ce_chunk = cfg.ce_chunk or ce_chunk
    ones = jnp.ones((b, s), jnp.float32) if mask is None else mask.astype(jnp.float32)

    @jax.checkpoint
    def chunk_sums(x_c, labels_c, mask_c):
        logits_c = constrain(
            unembed_logits(params["embed"], x_c), ("batch", None, "vocab")
        )
        nll = cross_entropy_from_logits(
            logits_c, labels_c, mask_c, valid_vocab=cfg.vocab, reduce=False
        )
        return jnp.sum(nll), jnp.sum(mask_c)

    if s > 2 * ce_chunk and s % ce_chunk == 0:
        nc = s // ce_chunk
        hc = jnp.moveaxis(hidden.reshape(b, nc, ce_chunk, d), 1, 0)
        lc = jnp.moveaxis(labels.reshape(b, nc, ce_chunk), 1, 0)
        mc = jnp.moveaxis(ones.reshape(b, nc, ce_chunk), 1, 0)

        def body(acc, xs):
            x_c, l_c, m_c = xs
            sn, sm = chunk_sums(x_c, l_c, m_c)
            return (acc[0] + sn, acc[1] + sm), None

        (tot_nll, tot_mask), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hc, lc, mc)
        )
        ce = tot_nll / jnp.maximum(tot_mask, 1.0)
    else:
        sn, sm = chunk_sums(hidden, labels, ones)
        ce = sn / jnp.maximum(sm, 1.0)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Cache construction (abstract + concrete)
# ---------------------------------------------------------------------------


def cache_spec(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    """ShapeDtypeStruct tree for the decode cache (dry-run friendly)."""
    dt = cfg.dtype
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        if cfg.attention == "mla":
            per = mla_cache_shape(batch, max_seq, cfg.kv_lora_rank, cfg.qk_rope_dim, dt)
        else:
            per = gqa_cache_shape(
                batch, max_seq, cfg.n_kv_heads or cfg.n_heads, cfg.resolved_head_dim, dt
            )
        return {"layers": _stack_sds(per, cfg.n_layers)}
    if cfg.family == "ssm":
        per = mamba2_state_shape(batch, ssm_config(cfg), jnp.float32)
        return {"layers": _stack_sds(per, cfg.n_layers)}
    if cfg.family == "hybrid":
        ng, per_g, tail = hybrid_layout(cfg)
        mstate = mamba2_state_shape(batch, ssm_config(cfg), jnp.float32)
        attn = gqa_cache_shape(
            batch, max_seq, cfg.n_kv_heads or cfg.n_heads, cfg.resolved_head_dim, dt
        )
        out = {
            "groups_mamba": _stack_sds(_stack_sds(mstate, per_g), ng),
            "groups_attn": _stack_sds(attn, ng),
        }
        if tail:
            out["tail"] = _stack_sds(mstate, tail)
        return out
    raise ValueError(cfg.family)


def _stack_sds(tree: Any, n: int) -> Any:
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree
    )


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_spec(cfg, batch, max_seq)
    )


def cache_axes(cfg: ModelConfig) -> Dict[str, Any]:
    """Logical axes for every cache leaf, built by construction (mirrors
    cache_spec): batch -> "batch" (data-sharded), the long KV sequence axis
    -> "kv_seq" (model-sharded, ring-attention style), SSM state unsharded
    except batch."""
    attn_ax = {
        "k": ("layers", "batch", "kv_seq", None, None),
        "v": ("layers", "batch", "kv_seq", None, None),
    }
    mla_ax = {
        "c_kv": ("layers", "batch", "kv_seq", None),
        "k_pe": ("layers", "batch", "kv_seq", None),
    }
    ssm_ax = {
        "ssm": ("layers", "batch", None, None, None),
        "conv": ("layers", "batch", None, None),
    }
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        return {"layers": mla_ax if cfg.attention == "mla" else attn_ax}
    if cfg.family == "ssm":
        return {"layers": ssm_ax}
    if cfg.family == "hybrid":
        _, _, tail = hybrid_layout(cfg)
        g_ssm = {
            "ssm": ("groups", "layers", "batch", None, None, None),
            "conv": ("groups", "layers", "batch", None, None),
        }
        g_attn = {
            "k": ("groups", "batch", "kv_seq", None, None),
            "v": ("groups", "batch", "kv_seq", None, None),
        }
        out = {"groups_mamba": g_ssm, "groups_attn": g_attn}
        if tail:
            out["tail"] = ssm_ax
        return out
    raise ValueError(cfg.family)
