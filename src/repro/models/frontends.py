"""Modality frontend STUBS (per the assignment: ``[audio]``/``[vlm]`` entries
specify the transformer BACKBONE only; ``input_specs()`` provides precomputed
frame/patch embeddings).

The stubs generate deterministic embeddings with the statistics a ViT
patchifier / HuBERT conv feature encoder would produce, so smoke tests and
examples can run end-to-end without image/audio data.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def patch_embeddings(
    key: jax.Array, batch: int, seq: int, d_model: int, dtype: Any = jnp.bfloat16
) -> jax.Array:
    """Pixtral-style stub: unit-variance patch/text embeddings (B, S, d)."""
    return jax.random.normal(key, (batch, seq, d_model), jnp.float32).astype(dtype)


def frame_embeddings(
    key: jax.Array, batch: int, seq: int, d_model: int, dtype: Any = jnp.bfloat16
) -> jax.Array:
    """HuBERT-style stub: 20ms-frame conv features after projection (B, S, d)."""
    x = jax.random.normal(key, (batch, seq, d_model), jnp.float32)
    # conv feature encoders produce temporally-correlated features; a light
    # smoothing keeps the stub statistics closer to the real frontend
    x = 0.5 * x + 0.5 * jnp.roll(x, 1, axis=1)
    return x.astype(dtype)


def embed_input_spec(
    batch: int, seq: int, d_model: int, dtype: Any = jnp.bfloat16
) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, seq, d_model), dtype)
