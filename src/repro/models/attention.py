"""Attention variants: GQA (w/ qk-norm) and MLA, training + cached decode.

Training/prefill uses a **block-wise attention** formulation: an unrolled
loop over query chunks where chunk *i* only attends to its key prefix. This
keeps peak memory at (B, H, cq, S) per layer, wastes no FLOPs on the masked
upper triangle (chunks above the diagonal are never computed), and mirrors
the tiling of the Pallas ``flash_attention`` kernel (the TPU-target path).

Decode uses a pre-allocated KV cache laid out (B, S_max, KV, D) whose
sequence axis is sharded over the "model" mesh axis (ring-attention style):
per-shard partial softmax statistics are combined by GSPMD's small
all-reduces instead of ever gathering the cache.

MLA (DeepSeek-V2 / MiniCPM3) caches the compressed latent + decoupled RoPE
key and uses the *absorbed* formulation at decode time: W_uk is folded into
the query and W_uv into the output so scores are taken directly against the
(B, S, rank) latent.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import ParamSpec, apply_rope, head_rms_norm
from repro.distributed.logical import constrain

# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def gqa_spec(
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    qk_norm: bool = False,
    bias: bool = False,
) -> Dict[str, ParamSpec]:
    spec = {
        "wq": ParamSpec((d_model, n_heads, head_dim), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d_model, n_kv_heads, head_dim), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d_model, n_kv_heads, head_dim), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((n_heads, head_dim, d_model), ("heads", "head_dim", "embed")),
    }
    if qk_norm:
        spec["q_norm"] = ParamSpec((head_dim,), ("head_dim",), init="ones")
        spec["k_norm"] = ParamSpec((head_dim,), ("head_dim",), init="ones")
    return spec


def mla_spec(
    d_model: int,
    n_heads: int,
    q_lora_rank: int,
    kv_lora_rank: int,
    qk_nope_dim: int,
    qk_rope_dim: int,
    v_head_dim: int,
) -> Dict[str, ParamSpec]:
    return {
        "wq_a": ParamSpec((d_model, q_lora_rank), ("embed", "qk_rank")),
        "q_a_norm": ParamSpec((q_lora_rank,), ("qk_rank",), init="ones"),
        "wq_b": ParamSpec(
            (q_lora_rank, n_heads, qk_nope_dim + qk_rope_dim),
            ("qk_rank", "heads", "head_dim"),
        ),
        "wkv_a": ParamSpec((d_model, kv_lora_rank + qk_rope_dim), ("embed", "kv_rank")),
        "kv_a_norm": ParamSpec((kv_lora_rank,), ("kv_rank",), init="ones"),
        "wk_b": ParamSpec(
            (kv_lora_rank, n_heads, qk_nope_dim), ("kv_rank", "heads", "head_dim")
        ),
        "wv_b": ParamSpec(
            (kv_lora_rank, n_heads, v_head_dim), ("kv_rank", "heads", "head_dim")
        ),
        "wo": ParamSpec((n_heads, v_head_dim, d_model), ("heads", "head_dim", "embed")),
    }


# ---------------------------------------------------------------------------
# Block-wise softmax attention (training / prefill)
# ---------------------------------------------------------------------------


def _chunked_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,  # (B, S, KV, D)
    causal: bool,
    chunk: int = 1024,
) -> jax.Array:
    """Unrolled query-chunk attention; chunk i attends keys [0, (i+1)*cq).

    GQA is handled by expanding K/V to the full head count up front (a
    sharded gather) instead of reshaping Q to (KV, G): reshaping the head
    axis would break its "model" sharding (96 heads tiled 16 ways cannot be
    re-tiled as (8, 12) in place), whereas the expanded K/V stays
    head-sharded and costs only (B, S, H_local, D) bytes per device.
    """
    b, s, h, d = q.shape
    kv = k.shape[2]
    groups = h // kv
    scale = 1.0 / math.sqrt(d)
    if groups > 1:
        k = constrain(jnp.repeat(k, groups, axis=2), ("batch", None, "heads", None))
        v = constrain(jnp.repeat(v, groups, axis=2), ("batch", None, "heads", None))
    cq = min(chunk, s)
    n_chunks = (s + cq - 1) // cq
    outs = []
    for i in range(n_chunks):
        lo = i * cq
        hi = min(s, lo + cq)
        qc = jax.lax.slice_in_dim(q, lo, hi, axis=1)  # (B, cq, H, D)
        k_hi = hi if causal else s
        kc = jax.lax.slice_in_dim(k, 0, k_hi, axis=1)
        vc = jax.lax.slice_in_dim(v, 0, k_hi, axis=1)
        scores = jnp.einsum("bqhd,bshd->bhqs", qc, kc) * scale
        scores = scores.astype(jnp.float32)
        if causal:
            qpos = lo + jnp.arange(hi - lo)
            kpos = jnp.arange(k_hi)
            mask = kpos[None, :] <= qpos[:, None]
            scores = jnp.where(mask[None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        oc = jnp.einsum("bhqs,bshd->bqhd", w, vc)
        outs.append(oc)
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


# ---------------------------------------------------------------------------
# GQA forward (train / prefill / decode)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    qk_norm: bool = False
    causal: bool = True
    use_rope: bool = True
    norm_eps: float = 1e-6
    chunk: int = 1024


def gqa_forward(
    params: Dict[str, jax.Array],
    x: jax.Array,  # (B, S, d_model)
    cfg: AttnConfig,
    positions: Optional[jax.Array] = None,
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Returns (out, updated_cache).

    * train:              cache=None                       — full pass
    * prefill:            cache=zeros, cache_index=0       — writes [0, S)
    * decode (S == 1):    cache=state,  cache_index=t      — appends + attends
    """
    dt = x.dtype
    b, s, _ = x.shape
    # seq=None: attention needs the full sequence — this is the SP
    # all-gather boundary; heads shard over "model" instead
    q = constrain(jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt)), ("batch", None, "heads", None))
    k = constrain(jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt)), ("batch", None, "kv_heads", None))
    v = constrain(jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt)), ("batch", None, "kv_heads", None))
    if cfg.qk_norm:
        q = head_rms_norm(params["q_norm"], q, cfg.norm_eps)
        k = head_rms_norm(params["k_norm"], k, cfg.norm_eps)
    if positions is None:
        base = cache_index if cache_index is not None else 0
        positions = base + jnp.arange(s)[None, :]
        positions = jnp.broadcast_to(positions, (b, s))
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = _chunked_attention(q, k, v, cfg.causal, cfg.chunk)
        new_cache = None
    else:
        idx = cache_index if cache_index is not None else jnp.asarray(0, jnp.int32)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), idx, axis=1)
        new_cache = {"k": ck, "v": cv}
        if s == 1:
            out = _decode_attend(q, ck, cv, idx)
        else:
            # prefill: attend within the fresh segment only
            out = _chunked_attention(q, k, v, cfg.causal, cfg.chunk)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt)), new_cache


def _decode_attend(q: jax.Array, ck: jax.Array, cv: jax.Array, idx: jax.Array) -> jax.Array:
    """Single-token attention over the cache (seq axis may be sharded)."""
    b, one, h, d = q.shape
    kv = ck.shape[2]
    groups = h // kv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, kv, groups, d)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, ck) * scale
    scores = scores.astype(jnp.float32)
    smax = ck.shape[1]
    valid = jnp.arange(smax)[None, None, None, :] <= idx
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", w, cv)
    return out.reshape(b, 1, h, d)


def gqa_cache_shape(
    batch: int, max_seq: int, n_kv_heads: int, head_dim: int, dtype: Any = jnp.bfloat16
) -> Dict[str, jax.ShapeDtypeStruct]:
    shp = (batch, max_seq, n_kv_heads, head_dim)
    return {
        "k": jax.ShapeDtypeStruct(shp, dtype),
        "v": jax.ShapeDtypeStruct(shp, dtype),
    }


# ---------------------------------------------------------------------------
# MLA forward
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MLAConfig:
    n_heads: int
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    chunk: int = 1024


def _mla_qkv(params, x, cfg: MLAConfig, positions):
    from .layers import rms_norm

    dt = x.dtype
    cq = jnp.einsum("bsd,dr->bsr", x, params["wq_a"].astype(dt))
    cq = rms_norm({"scale": params["q_a_norm"]}, cq, cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["wq_b"].astype(dt))
    q_nope, q_pe = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim :]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"].astype(dt))
    c_kv = ckv_full[..., : cfg.kv_lora_rank]
    k_pe = ckv_full[..., cfg.kv_lora_rank :][:, :, None, :]  # (B,S,1,rope)
    c_kv = rms_norm({"scale": params["kv_a_norm"]}, c_kv, cfg.norm_eps)
    k_pe = apply_rope(k_pe, positions, cfg.rope_theta)
    return q_nope, q_pe, c_kv, k_pe


def mla_forward(
    params: Dict[str, jax.Array],
    x: jax.Array,
    cfg: MLAConfig,
    positions: Optional[jax.Array] = None,
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    dt = x.dtype
    b, s, _ = x.shape
    if positions is None:
        base = cache_index if cache_index is not None else 0
        positions = base + jnp.arange(s)[None, :]
        positions = jnp.broadcast_to(positions, (b, s))
    q_nope, q_pe, c_kv, k_pe = _mla_qkv(params, x, cfg, positions)

    if cache is not None:
        idx = cache_index if cache_index is not None else jnp.asarray(0, jnp.int32)
        cc = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), idx, axis=1
        )
        cp = jax.lax.dynamic_update_slice_in_dim(
            cache["k_pe"], k_pe[:, :, 0, :].astype(cache["k_pe"].dtype), idx, axis=1
        )
        new_cache = {"c_kv": cc, "k_pe": cp}
        if s == 1:
            out = _mla_decode_absorbed(params, q_nope, q_pe, cc, cp, idx, cfg)
            return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt)), new_cache
    else:
        new_cache = None

    # train / prefill: expand latent to per-head K/V, run chunked attention
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wk_b"].astype(dt))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["wv_b"].astype(dt))
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe, k_nope[..., :1].shape[:-1] + (cfg.qk_rope_dim,))], axis=-1)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    # pad V up to qk dim so we can reuse the chunked kernel, then slice back
    out = _chunked_attention(q, k, _pad_last(v, q.shape[-1]), causal=True, chunk=cfg.chunk)
    out = out[..., : cfg.v_head_dim]
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt)), new_cache


def _pad_last(x: jax.Array, to: int) -> jax.Array:
    pad = to - x.shape[-1]
    if pad <= 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])


def _mla_decode_absorbed(params, q_nope, q_pe, c_kv, k_pe, idx, cfg: MLAConfig) -> jax.Array:
    """Absorbed MLA decode: scores directly against the latent cache."""
    dt = q_nope.dtype
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    # fold W_uk into the query: (B,1,H,nope) x (rank,H,nope) -> (B,H,rank)
    q_lat = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], params["wk_b"].astype(dt))
    scores = jnp.einsum("bhr,bsr->bhs", q_lat, c_kv)
    scores += jnp.einsum("bhk,bsk->bhs", q_pe[:, 0], k_pe)
    scores = (scores * scale).astype(jnp.float32)
    smax = c_kv.shape[1]
    valid = jnp.arange(smax)[None, None, :] <= idx
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(dt)
    o_lat = jnp.einsum("bhs,bsr->bhr", w, c_kv)
    out = jnp.einsum("bhr,rhk->bhk", o_lat, params["wv_b"].astype(dt))  # absorb W_uv
    return out[:, None]


def mla_cache_shape(
    batch: int, max_seq: int, kv_lora_rank: int, qk_rope_dim: int, dtype: Any = jnp.bfloat16
) -> Dict[str, jax.ShapeDtypeStruct]:
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, max_seq, kv_lora_rank), dtype),
        "k_pe": jax.ShapeDtypeStruct((batch, max_seq, qk_rope_dim), dtype),
    }
