"""Unified model configuration covering all assigned architecture families.

One ``ModelConfig`` describes dense / MoE / SSM / hybrid / encoder-only
stacks; the block pattern is derived from the family. FLOPs estimators feed
both the roofline analysis (MODEL_FLOPS = 6·N·D dense, 6·N_active·D MoE) and
the BOINC job-size estimates (``est_flop_count``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

import jax.numpy as jnp

from .layers import pad_vocab


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab: int = 0
    head_dim: int = 0  # 0 -> d_model // n_heads
    # attention
    attention: str = "gqa"  # gqa | mla | none
    qk_norm: bool = False
    rope_theta: float = 10000.0
    attn_chunk: int = 1024
    # MLA
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # SSM
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 256
    # hybrid (zamba2): one weight-tied attention block every `period` layers
    shared_attn_period: int = 0
    # encoder-only (no causal mask, no decode)
    encoder_only: bool = False
    # input modality: "tokens" or "embeds" (frontend stub supplies embeddings)
    input_mode: str = "tokens"
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16  # compute dtype
    param_dtype: Any = jnp.float32
    remat: bool = True
    # remat policy: "nothing" (recompute all — smallest memory),
    # "dots_nb" (save weight-stationary dots), "dots" (save all dots)
    remat_policy: str = "nothing"
    ce_chunk: int = 512  # sequence-chunked cross-entropy granularity

    # ---- derived ----

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads:
            return self.d_model // self.n_heads
        return 0

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab) if self.vocab else 0

    @property
    def causal(self) -> bool:
        return not self.encoder_only

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic archs run the long_500k cell (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return not self.encoder_only

    def scaled(self, **overrides: Any) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)

    # ---- parameter / FLOP accounting ----

    def param_count(self) -> int:
        from .transformer import model_spec
        from .layers import count_params

        return count_params(model_spec(self))

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared experts only)."""
        total = self.param_count()
        if self.n_experts and self.top_k:
            per_expert = 3 * self.d_model * self.d_expert
            inactive = (self.n_experts - self.top_k) * per_expert * self.n_layers
            return total - inactive
        return total

    def train_flops_per_token(self) -> float:
        """MODEL_FLOPS/token for a train step: 6·N_active (fwd+bwd)."""
        return 6.0 * self.active_param_count()

    def decode_flops_per_token(self, context: int = 0) -> float:
        """2·N_active plus attention score/value FLOPs against the context."""
        f = 2.0 * self.active_param_count()
        if self.attention == "gqa" and self.n_heads:
            f += 4.0 * self.n_heads * self.resolved_head_dim * context
        elif self.attention == "mla":
            f += 4.0 * self.n_heads * (self.kv_lora_rank + self.qk_rope_dim) * context
        return f


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name}")


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; reason if not (DESIGN §4)."""
    if shape.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only architecture has no decode step"
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full quadratic attention; long_500k skipped per assignment"
    return True, ""
