"""Mamba-2 (SSD, state-space duality) blocks — arXiv:2405.21060.

The chunked SSD algorithm: within a chunk the recurrence is computed in its
"dual" quadratic-attention form (matmul-friendly — MXU on TPU); across
chunks a small scan carries the (H, P, N) state. The intra-chunk einsums are
the compute hot spot and have a Pallas kernel (`repro.kernels.ssd_scan`);
this module is the pure-jnp implementation used for CPU tests and the
dry-run lowering.

Decode keeps a per-layer recurrent state (B, H, P, N) + conv tail
(B, d_conv-1, d_xBC) — O(1) in sequence length, which is what makes the
``long_500k`` cells runnable for the SSM archs.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import ParamSpec, rms_norm
from repro.distributed.logical import constrain


@dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256
    norm_eps: float = 1e-6
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def d_xbc(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def mamba2_spec(cfg: SSMConfig) -> Dict[str, ParamSpec]:
    d_in_proj = 2 * cfg.d_inner + 2 * cfg.n_groups * cfg.d_state + cfg.n_heads
    return {
        "in_proj": ParamSpec((cfg.d_model, d_in_proj), ("embed", "mlp")),
        "conv_w": ParamSpec((cfg.d_conv, cfg.d_xbc), (None, "mlp")),
        "conv_b": ParamSpec((cfg.d_xbc,), ("mlp",), init="zeros"),
        "A_log": ParamSpec((cfg.n_heads,), ("heads",), init="zeros"),
        "dt_bias": ParamSpec((cfg.n_heads,), ("heads",), init="zeros"),
        "D": ParamSpec((cfg.n_heads,), ("heads",), init="ones"),
        "norm": ParamSpec((cfg.d_inner,), ("mlp",), init="ones"),
        "out_proj": ParamSpec((cfg.d_inner, cfg.d_model), ("mlp", "embed")),
    }


# ---------------------------------------------------------------------------
# Chunked SSD core
# ---------------------------------------------------------------------------


def _segsum(a: jax.Array) -> jax.Array:
    """L[i, j] = sum(a[j+1..i]) for i >= j, -inf above diagonal.

    a: (..., Q) -> (..., Q, Q)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.arange(q)[:, None] >= jnp.arange(q)[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) — post-softplus
    A: jax.Array,  # (H,) — negative
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    chunk: int,
    initial_state: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s_orig, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    q = min(chunk, s_orig)
    # pad to a chunk multiple: dt=0 on padding => decay 1 and zero state
    # contribution, so padded steps are exact no-ops on the recurrence
    pad = (-s_orig) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s = s_orig + pad
    nc = s // q
    rep = h // g  # heads per group

    # shard the independent chunk axis over "model": every (B,NC,Q,*) and
    # (B,NC,H,Q,Q) intermediate below — the dominant HBM traffic of the
    # SSD dual form — becomes 1/TP-sized per device; only the small
    # inter-chunk state scan crosses chunk shards
    xc = constrain(x.reshape(b, nc, q, h, p), ("batch", "chunks", None, None, None))
    dtc = constrain(dt.reshape(b, nc, q, h), ("batch", "chunks", None, None))
    Bc = constrain(Bm.reshape(b, nc, q, g, n), ("batch", "chunks", None, None, None))
    Cc = constrain(Cm.reshape(b, nc, q, g, n), ("batch", "chunks", None, None, None))
    a = dtc * A[None, None, None, :]  # (B,NC,Q,H)

    a_hbcq = jnp.moveaxis(a, -1, 2)  # (B,NC,H,Q)
    L = jnp.exp(_segsum(a_hbcq))  # (B,NC,H,Q,Q)
    cum_a = jnp.cumsum(a_hbcq, axis=-1)  # (B,NC,H,Q)
    total_a = cum_a[..., -1]  # (B,NC,H)

    # intra-chunk (dual quadratic form)
    cb = jnp.einsum("bcqgn,bcsgn->bcgqs", Cc, Bc)  # (B,NC,G,Q,Q)
    cb = constrain(jnp.repeat(cb, rep, axis=2), ("batch", "chunks", None, None, None))
    scores = cb * L * jnp.moveaxis(dtc, -1, 2)[..., None, :]  # dt_j on keys
    y_intra = jnp.einsum("bchqs,bcshp->bcqhp", scores.astype(x.dtype), xc)

    # chunk states: S_c = sum_j exp(total - cum_j) dt_j B_j (x) x_j
    decay_state = jnp.exp(total_a[..., None] - cum_a)  # (B,NC,H,Q)
    dtx = xc * (dtc * jnp.moveaxis(decay_state, 2, -1))[..., None]  # (B,NC,Q,H,P)
    Bh = jnp.repeat(Bc, rep, axis=3)  # (B,NC,Q,H,N)  (G -> H)
    chunk_states = constrain(
        jnp.einsum("bcqhn,bcqhp->bchpn", Bh, dtx),
        ("batch", "chunks", None, None, None),
    )

    # inter-chunk scan (kept in f32: the state is the numerically sensitive
    # part of SSD; matches the reference implementation's fp32 states)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)
    initial_state = initial_state.astype(jnp.float32)
    decay_chunk = jnp.exp(total_a)  # (B,NC,H)

    def step(carry, inp):
        s_prev = carry
        dc, cs = inp  # (B,H), (B,H,P,N)
        s_new = s_prev * dc[..., None, None] + cs.astype(jnp.float32)
        return s_new, s_prev

    dc_t = jnp.moveaxis(decay_chunk, 1, 0)  # (NC,B,H)
    cs_t = jnp.moveaxis(chunk_states, 1, 0)  # (NC,B,H,P,N)
    final_state, prev_states = jax.lax.scan(step, initial_state, (dc_t, cs_t))
    prev_states = constrain(
        jnp.moveaxis(prev_states, 0, 1), ("batch", "chunks", None, None, None)
    )  # (B,NC,H,P,N)

    # inter-chunk contribution: C_i * exp(cum_i) * state_{c-1}
    decay_in = jnp.exp(cum_a)  # (B,NC,H,Q)
    Ch = jnp.repeat(Cc, rep, axis=3)  # (B,NC,Q,H,N)
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", Ch, prev_states.astype(x.dtype))
    y_inter = y_inter * jnp.moveaxis(decay_in, 2, -1)[..., None]

    y = constrain(y_intra + y_inter, ("batch", "chunks", None, None, None))
    y = y.reshape(b, s, h, p).astype(x.dtype)
    if pad:
        y = y[:, :s_orig]
    return y, final_state


# ---------------------------------------------------------------------------
# Full Mamba-2 block
# ---------------------------------------------------------------------------


def _causal_conv(xbc: jax.Array, w: jax.Array, bias: jax.Array, tail: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. xbc: (B,S,C); w: (K,C). Returns (y, new_tail).

    ``tail`` (the cache leaf) keeps its own storage dtype; compute happens in
    the activation dtype.
    """
    k = w.shape[0]
    tail_dtype = xbc.dtype if tail is None else tail.dtype
    if tail is None:
        tail_c = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        tail_c = tail.astype(xbc.dtype)
    xp = jnp.concatenate([tail_c, xbc], axis=1)
    new_tail = xp[:, -(k - 1) :, :] if k > 1 else tail_c[:, :0, :]
    ys = sum(
        xp[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return jax.nn.silu(ys + bias[None, None, :]), new_tail.astype(tail_dtype)


def mamba2_forward(
    params: Dict[str, jax.Array],
    x: jax.Array,  # (B, S, d_model)
    cfg: SSMConfig,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Sequence-mode forward. ``state`` carries {ssm (B,H,P,N), conv (B,K-1,C)}
    for chunked prefill / streaming; None for plain training."""
    dt_ = x.dtype
    b, s, _ = x.shape
    h, p, n, g = cfg.n_heads, cfg.head_dim, cfg.d_state, cfg.n_groups

    zxbcdt = constrain(
        jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt_)),
        ("batch", None, "mlp"),
    )
    z, xbc, dt_raw = jnp.split(
        zxbcdt, [cfg.d_inner, cfg.d_inner + cfg.d_xbc], axis=-1
    )
    conv_tail = state["conv"] if state is not None else None
    xbc, new_tail = _causal_conv(xbc, params["conv_w"].astype(dt_), params["conv_b"].astype(dt_), conv_tail)
    xs, Bm, Cm = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + g * n], axis=-1)
    xs = xs.reshape(b, s, h, p)
    Bm = Bm.reshape(b, s, g, n)
    Cm = Cm.reshape(b, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    dt = jnp.clip(dt, cfg.dt_min, cfg.dt_max).astype(jnp.float32)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    init = state["ssm"] if state is not None else None
    y, final_state = ssd_chunked(xs, dt, A, Bm, Cm, cfg.chunk, init)
    y = y + xs * params["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(b, s, cfg.d_inner)
    y = rms_norm({"scale": params["norm"]}, y, cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_))
    new_state = {"ssm": final_state, "conv": new_tail} if state is not None else None
    return out, new_state


def mamba2_decode_step(
    params: Dict[str, jax.Array],
    x: jax.Array,  # (B, 1, d_model)
    cfg: SSMConfig,
    state: Dict[str, jax.Array],
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """O(1) recurrent decode: s' = exp(dt A) s + dt B (x) x; y = C s + D x."""
    dt_ = x.dtype
    b = x.shape[0]
    h, p, n, g = cfg.n_heads, cfg.head_dim, cfg.d_state, cfg.n_groups
    rep = h // g

    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt_))
    z, xbc, dt_raw = jnp.split(zxbcdt, [cfg.d_inner, cfg.d_inner + cfg.d_xbc], axis=-1)
    xbc, new_tail = _causal_conv(
        xbc, params["conv_w"].astype(dt_), params["conv_b"].astype(dt_), state["conv"]
    )
    xs, Bm, Cm = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + g * n], axis=-1)
    xs = xs.reshape(b, h, p)
    Bm = jnp.repeat(Bm.reshape(b, g, n), rep, axis=1)  # (B,H,N)
    Cm = jnp.repeat(Cm.reshape(b, g, n), rep, axis=1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    dt = jnp.clip(dt, cfg.dt_min, cfg.dt_max)  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A[None, :])  # (B,H)

    s_prev = state["ssm"].astype(jnp.float32)
    upd = (dt[..., None] * xs.astype(jnp.float32))[..., :, None] * Bm.astype(jnp.float32)[:, :, None, :]
    s_new = s_prev * decay[..., None, None] + upd  # (B,H,P,N)
    y = jnp.einsum("bhn,bhpn->bhp", Cm.astype(jnp.float32), s_new)
    y = y.astype(dt_) + xs * params["D"].astype(dt_)[None, :, None]
    y = y.reshape(b, 1, cfg.d_inner)
    y = rms_norm({"scale": params["norm"]}, y, cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_))
    return out, {"ssm": s_new.astype(state["ssm"].dtype), "conv": new_tail}


def mamba2_state_shape(
    batch: int, cfg: SSMConfig, dtype: Any = jnp.float32
) -> Dict[str, jax.ShapeDtypeStruct]:
    return {
        "ssm": jax.ShapeDtypeStruct((batch, cfg.n_heads, cfg.head_dim, cfg.d_state), dtype),
        "conv": jax.ShapeDtypeStruct((batch, cfg.d_conv - 1, cfg.d_xbc), dtype),
    }
