"""Checkpoint/restart following the paper's application-checkpoint protocol
(§3.6): the runtime *requests* a checkpoint every ``period`` steps; the
training step completes its "outer loop" (the step boundary — our masked
section: never mid-dispatch), writes atomically, and acknowledges. The
client/coordinator knows which step is durable and never re-schedules work
below it; restart resumes from the latest manifest.

Storage is dependency-free: one .npz per pytree ("shard files") + a JSON
manifest with step, config hash, and per-file checksums (the paper's file
immutability + hash validation, §2.2/§3.10). Writes go to a temp name then
rename (atomic on POSIX).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> List[Tuple[str, np.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, np.asarray(leaf)))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _checksum(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


@dataclass
class Checkpointer:
    directory: str
    keep: int = 3

    def __post_init__(self) -> None:
        os.makedirs(self.directory, exist_ok=True)

    # ------------------------------------------------------------------

    def save(self, step: int, trees: Dict[str, Any], meta: Optional[Dict] = None) -> str:
        """Atomically write {name: pytree} at ``step``; returns ckpt dir."""
        final = os.path.join(self.directory, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest: Dict[str, Any] = {
            "step": step,
            "time": time.time(),
            "files": {},
            "meta": meta or {},
        }
        for name, tree in trees.items():
            arrays = dict(_flatten_with_paths(tree))
            fpath = os.path.join(tmp, f"{name}.npz")
            np.savez(fpath, **arrays)
            manifest["files"][name] = {
                "file": f"{name}.npz",
                "sha256": _checksum(fpath),
                "n_arrays": len(arrays),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    # ------------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        steps = self._steps()
        return steps[-1] if steps else None

    def restore(
        self, templates: Dict[str, Any], step: Optional[int] = None
    ) -> Tuple[int, Dict[str, Any]]:
        """Restore {name: pytree} using ``templates`` for structure/dtypes.
        Verifies checksums (hash validation of downloaded files, §2.2)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        d = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        out: Dict[str, Any] = {}
        for name, template in templates.items():
            entry = manifest["files"][name]
            fpath = os.path.join(d, entry["file"])
            if _checksum(fpath) != entry["sha256"]:
                raise IOError(f"checksum mismatch for {fpath}")
            data = np.load(fpath)
            flat, treedef = jax.tree_util.tree_flatten_with_path(template)
            leaves = []
            for path, leaf in flat:
                key = "/".join(_path_str(p) for p in path)
                arr = data[key]
                leaves.append(arr.astype(np.asarray(leaf).dtype))
            out[name] = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(template), leaves
            )
        return manifest["step"], out

    # ------------------------------------------------------------------

    def _steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def _gc(self) -> None:
        steps = self._steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"), ignore_errors=True)


@dataclass
class CheckpointPolicy:
    """The client-side checkpoint request cadence (§3.6)."""

    period_steps: int = 50
    last_requested: int = -1
    last_acked: int = -1

    def should_checkpoint(self, step: int) -> bool:
        return step > 0 and step % self.period_steps == 0

    def ack(self, step: int) -> None:
        self.last_acked = step
