from .checkpointer import Checkpointer, CheckpointPolicy

__all__ = ["Checkpointer", "CheckpointPolicy"]
