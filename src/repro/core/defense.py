"""Defense-in-depth replica placement (§3.4).

Three cooperating server-side mechanisms close the clique quorum-defeat
hole the adversarial scenario matrix pinned (ROADMAP item 4):

1. **Work-spreading** — an agreement-statistics tracker fed from the
   validation finalize path maintains pairwise co-validation counts and
   per-host won/lost decision counts. Hosts that keep losing decisions
   (judged INVALID more often than they validate) and habitually agree
   with each other form *suspicion clusters*; dispatch never sends two
   replicas of one job to hosts in the same cluster. When the eligible
   fleet is too small to satisfy the constraint it is *relaxed* (counted,
   never deadlocked).
2. **Homogeneous redundancy** — jobs are pinned to the `hr_class` of
   their first-dispatched replica (``core/types.hr_class``; enforced by
   the scalar `_score` check and the fused HR mask column in
   `core/batch_dispatch.py`). The layer adds a *census guard*: a job is
   only pinned when its class holds at least `min_quorum` live hosts, so
   tiny classes cannot strand a job short of quorum.
3. **Host punishment** — a per-(host, app-version) daily quota (the
   paper's ``max_jobs_per_day``): halved on INVALID/error outcomes,
   incremented on VALID, reset each (virtual) day. Punished hosts are
   additionally deferred through a per-host `ExponentialBackoff` whose
   failure/success registrations ride the same validation events.

Parity contract: the layer is fed from call sites that are provably
identical across the scalar oracle and the vectorized engines — the
shared ``Scheduler._dispatch`` / ``_slow_check`` choke points on the
dispatch side, and the validation finalize path on the outcome side
(scalar ``_post_validation_updates`` inline; batch mode defers the same
(valid, invalid) host/version pairs into ``ValidationPlan.defense_events``
and replays them sequentially in ``_finalize_plan``). It consumes **no
shared RNG stream**: backoffs use their own per-host seeded generators,
so engine/oracle RNG-state identity survives.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Callable, Dict, Iterable, List, Optional, Set, Tuple

import numpy as np

from .backoff import ExponentialBackoff
from .types import (
    App,
    AppVersion,
    HRLevel,
    Host,
    InstanceOutcome,
    InstanceState,
    Job,
    hr_class,
)

if TYPE_CHECKING:  # pragma: no cover
    from .store import JobStore

__all__ = ["DefensePolicy", "DefenseLayer"]


@dataclass(frozen=True)
class DefensePolicy:
    """Knobs for the defense layer (frozen: embeddable in `ScenarioSpec`)."""

    # Homogeneous redundancy granularity applied to the project's apps.
    hr_level: HRLevel = HRLevel.COARSE
    # Host punishment (§3.4 max_jobs_per_day analogue). The quota starts
    # generous — it is a punishment device, not a throttle: honest hosts
    # never feel it, while a repeat offender is halved to quota_min within
    # a handful of INVALID decisions.
    quota_init: float = 32.0
    quota_min: float = 1.0
    quota_max: float = 64.0
    day_seconds: float = 86400.0
    # Punishment deferral: per-host exponential backoff bumped on every
    # INVALID/error outcome, reset on VALID. Zero jitter by default so
    # golden scenario bounds stay exactly reproducible.
    backoff_min: float = 1800.0
    backoff_max: float = 4 * 3600.0
    backoff_jitter: float = 0.0
    # Work-spreading: a host is *suspicious* once it lost >= suspect_lost
    # finalized decisions and has not validated at least suspect_ratio
    # times as often as it lost. The ratio keeps merely-flaky honest hosts
    # (a few percent INVALID, validating constantly) out of clusters while
    # catching colluders, who split their decisions between wins inside
    # the clique and losses against honest pairs — roughly 1:1, nowhere
    # near the exoneration ratio. Suspicious hosts sharing >=
    # spread_min_agree agreements cluster together.
    suspect_lost: int = 1
    suspect_ratio: float = 4.0
    spread_min_agree: int = 1
    # Accomplice rule: a host that never looks suspicious on its own (HR
    # pinning can pair a colluder exclusively with its partner, so it never
    # loses) still joins a cluster when one suspicious member accounts for
    # at least this fraction of its lifetime validations. Honest hosts
    # spread their wins across many partners and stay well under it.
    accomplice_frac: float = 0.5
    # Above this fleet size the relaxation scan assumes an eligible host
    # exists (honest large fleets have no clusters; the scan is O(hosts)).
    spread_scan_cap: int = 4096


@dataclass
class DefenseLayer:
    """Mutable defense state for one project server.

    All tables are purged per host via :meth:`forget_host` alongside the
    estimator/reputation purges, so churned identities leak nothing.
    """

    policy: DefensePolicy
    store: "JobStore"

    # -- host punishment: dense interned (host, app-version) quota table --
    _host_idx: Dict[int, int] = field(default_factory=dict)
    _ver_idx: Dict[int, int] = field(default_factory=dict)
    quota: np.ndarray = field(default_factory=lambda: np.zeros((0, 0)))
    sent: np.ndarray = field(default_factory=lambda: np.zeros((0, 0), dtype=np.int64))
    day: np.ndarray = field(default_factory=lambda: np.zeros((0, 0), dtype=np.int64))
    _backoff: Dict[int, ExponentialBackoff] = field(default_factory=dict)

    # -- work-spreading: agreement statistics + suspicion clusters --
    _agree: Dict[int, Dict[int, int]] = field(default_factory=dict)
    _lost: Dict[int, int] = field(default_factory=dict)
    _validated: Dict[int, int] = field(default_factory=dict)
    _cluster_of: Dict[int, int] = field(default_factory=dict)
    _clusters_dirty: bool = False

    # -- homogeneous redundancy: class census + interned class ids --
    _hr_of_host: Dict[int, Tuple] = field(default_factory=dict)
    _hr_census: Dict[Tuple, int] = field(default_factory=dict)
    _hr_ids: Dict[Tuple, int] = field(default_factory=dict)

    # -- effectiveness counters (exported into ScenarioResult reports) --
    # per-host denial attribution: which mechanism blocked which host
    denied_quota_by: Dict[int, int] = field(default_factory=dict)
    denied_spread_by: Dict[int, int] = field(default_factory=dict)
    deferred_by: Dict[int, int] = field(default_factory=dict)
    cancelled_by: Dict[int, int] = field(default_factory=dict)
    quota_denials: int = 0
    quota_deferrals: int = 0
    spread_denials: int = 0
    spread_relaxations: int = 0
    spread_cancellations: int = 0
    hr_pins: int = 0
    hr_pin_blocked: int = 0
    hr_relaxations: int = 0
    dispatches: int = 0

    # invalidates the persistent vectorized dispatch snapshot after an HR
    # unpin mutates job.hr_class behind its back (wired to Feeder.invalidate
    # by the server; the scalar oracle path ignores cache generations)
    invalidate_dispatch: Optional[Callable[[], None]] = None

    # ------------------------------------------------------------------
    # registration / churn
    # ------------------------------------------------------------------

    def on_host_added(self, host: Host) -> None:
        cls = hr_class(host, self.policy.hr_level)
        self._hr_of_host[host.id] = cls
        self._hr_census[cls] = self._hr_census.get(cls, 0) + 1

    def forget_host(self, host_id: int) -> None:
        """Purge every per-host trace (churn/Sybil: rejoin leaks nothing)."""
        cls = self._hr_of_host.pop(host_id, None)
        if cls is not None:
            n = self._hr_census.get(cls, 0) - 1
            if n > 0:
                self._hr_census[cls] = n
            else:
                self._hr_census.pop(cls, None)
        self._lost.pop(host_id, None)
        self._validated.pop(host_id, None)
        self._backoff.pop(host_id, None)
        self.denied_quota_by.pop(host_id, None)
        self.denied_spread_by.pop(host_id, None)
        self.deferred_by.pop(host_id, None)
        self.cancelled_by.pop(host_id, None)
        row = self._agree.pop(host_id, None)
        if row:
            for other in row:
                peers = self._agree.get(other)
                if peers is not None:
                    peers.pop(host_id, None)
        hr = self._host_idx.get(host_id)
        if hr is not None:
            # reset the row to the fresh-host default; the dense slot stays
            # mapped so a same-id rejoin starts from a clean slate
            self.quota[hr, :] = self.policy.quota_init
            self.sent[hr, :] = 0
            self.day[hr, :] = -1
        if host_id in self._cluster_of:
            self._clusters_dirty = True

    # ------------------------------------------------------------------
    # homogeneous redundancy
    # ------------------------------------------------------------------

    def hr_id_of(self, host: Host) -> int:
        """Interned integer id of the host's HR class (world column)."""
        cls = self._hr_of_host.get(host.id)
        if cls is None:
            cls = hr_class(host, self.policy.hr_level)
        return self._intern_hr(cls)

    def _intern_hr(self, cls: Tuple) -> int:
        hid = self._hr_ids.get(cls)
        if hid is None:
            hid = len(self._hr_ids)
            self._hr_ids[cls] = hid
        return hid

    def can_pin(self, host: Host, app: App, job: Job) -> bool:
        """Census guard: only pin a job to a class with enough live hosts
        to reach quorum (otherwise leave it unpinned — logged, not fatal)."""
        need = max(job.min_quorum, 1)
        if app.hr_level == self.policy.hr_level:
            cls = self._hr_of_host.get(host.id)
            if cls is None:
                cls = hr_class(host, app.hr_level)
            n = self._hr_census.get(cls, 0)
        else:  # census maintained at the policy level only; rare mismatch
            cls = hr_class(host, app.hr_level)
            n = sum(1 for h in self.store.hosts.values() if hr_class(h, app.hr_level) == cls)
        if n >= need:
            self.hr_pins += 1
            return True
        self.hr_pin_blocked += 1
        return False

    def tick_sweep(self, now: float, instance: int = 0, n_instances: int = 1) -> None:
        """Per-transitioner-tick enforcement sweep (shared choke point).

        Runs after the tick's validation finalize, when both validation
        engines hold identical store state, so every decision below is
        engine-identical: (1) abort in-flight co-placements inside a
        suspicion cluster (the reactive arm of work-spreading — dispatch
        checks cannot claw back replicas that were placed before the
        cluster formed), then (2) unpin HR-stuck retries.
        """
        self.cancel_clustered_inflight(now, instance, n_instances)
        self.relax_stuck_hr(instance, n_instances)

    def cancel_clustered_inflight(
        self, now: float, instance: int = 0, n_instances: int = 1
    ) -> None:
        """Server-side abort (§4 job cancellation) of same-cluster replicas.

        The clique's damage is done in the initial placement burst: hosts
        buffer work long before the first validation returns, so by the
        time agreement statistics identify a cluster, the co-placed wrong
        pairs are already in flight. For every job with >= 2 IN_PROGRESS
        replicas on hosts of one cluster, all but the first are aborted
        (OVER/ABANDONED, like a detach) and the transitioner re-issues
        them under the now-active spread constraint. A late report from
        the aborted host is ignored by the scheduler report path. Each
        abort burns one of the job's error slots, so cancellation stops
        while enough slots remain for real failures (never drives a job
        to MAX_ERROR failure)."""
        if self._clusters_dirty:
            self._rebuild_clusters()
        if not self._cluster_of:
            return
        by_job: Dict[int, List] = {}
        for inst in self.store.in_progress_instances():
            if inst.job_id % n_instances != instance:
                continue
            if inst.host_id is None:
                continue
            cl = self._cluster_of.get(inst.host_id)
            if cl is not None:
                by_job.setdefault(inst.job_id, []).append((cl, inst))
        for jid, entries in by_job.items():
            if len(entries) < 2:
                continue
            job = self.store.jobs.get(jid)
            if job is None:
                continue
            n_err = sum(
                1
                for i in self.store.job_instances(jid)
                if i.state == InstanceState.OVER
                and i.outcome
                in (
                    InstanceOutcome.CLIENT_ERROR,
                    InstanceOutcome.NO_REPLY,
                    InstanceOutcome.ABANDONED,
                    InstanceOutcome.VALIDATE_ERROR,
                )
            )
            budget = job.max_error_instances - n_err - 1
            seen_cluster: Set[int] = set()
            for cl, inst in entries:  # ascending instance id (store order)
                if cl not in seen_cluster:
                    seen_cluster.add(cl)  # first replica in the cluster stays
                    continue
                if budget <= 0:
                    break
                inst.state = InstanceState.OVER
                inst.outcome = InstanceOutcome.ABANDONED
                job.transition_flag = True
                self.spread_cancellations += 1
                if inst.host_id is not None:
                    self.cancelled_by[inst.host_id] = (
                        self.cancelled_by.get(inst.host_id, 0) + 1
                    )
                budget -= 1

    def relax_stuck_hr(self, instance: int = 0, n_instances: int = 1) -> None:
        """Unpin jobs whose HR class can no longer serve a waiting replica.

        A pinned job with an UNSENT instance is *stuck* when every live
        host of its class already holds an instance of it (one instance
        per host, §6.4) — a retry created after an error/INVALID in a
        small class would otherwise wait forever. Unpinning (logged, like
        the spread relaxation) trades comparability for liveness; the
        census guard makes this rare. Runs from the transitioner tick
        (sharded like the flagged-job pass) so both validation engines see
        identical post-finalize store state when the decision is taken.
        """
        unpinned = False
        for jid in sorted(self.store.unsent_job_ids()):
            if jid % n_instances != instance:
                continue
            job = self.store.jobs.get(jid)
            if job is None or job.hr_class is None:
                continue
            app = self.store.apps.get(job.app_name)
            if app is None or app.hr_level != self.policy.hr_level:
                continue
            n_class = self._hr_census.get(job.hr_class, 0)
            holders = self.store.hosts_with_instance(jid)
            in_class = sum(
                1 for h in holders if self._hr_of_host.get(h) == job.hr_class
            )
            if n_class <= in_class:
                job.hr_class = None
                self.hr_relaxations += 1
                unpinned = True
        if unpinned and self.invalidate_dispatch is not None:
            # the vectorized dispatch snapshot caches hr_id per slot; force
            # a rebuild so it re-reads the cleared pins (scalar parity)
            self.invalidate_dispatch()

    # ------------------------------------------------------------------
    # dispatch-side enforcement
    # ------------------------------------------------------------------

    def check_dispatch(self, job: Job, host: Host, version: AppVersion, now: float) -> bool:
        """Slow-check extension: punishment deferral, daily quota, spread."""
        hid = host.id
        bo = self._backoff.get(hid)
        if bo is not None and not bo.ready(now):
            self.quota_deferrals += 1
            self.deferred_by[hid] = self.deferred_by.get(hid, 0) + 1
            return False
        hr, vr = self._cell(hid, version.id, now)
        if self.sent[hr, vr] >= self.quota[hr, vr]:
            self.quota_denials += 1
            self.denied_quota_by[hid] = self.denied_quota_by.get(hid, 0) + 1
            return False
        cl = self.cluster_of(hid)
        if cl is not None:
            holders = self.store.hosts_with_instance(job.id)
            clash = any(h != hid and self._cluster_of.get(h) == cl for h in holders)
            if clash:
                if self._eligible_exists(job, holders):
                    self.spread_denials += 1
                    self.denied_spread_by[hid] = self.denied_spread_by.get(hid, 0) + 1
                    return False
                # eligible fleet too small: relax rather than deadlock
                self.spread_relaxations += 1
        return True

    def on_dispatch(self, job: Job, app: App, host: Host, version: AppVersion, now: float) -> None:
        hr, vr = self._cell(host.id, version.id, now)
        self.sent[hr, vr] += 1
        self.dispatches += 1

    def _eligible_exists(self, job: Job, holders: Set[int]) -> bool:
        """Is there any other host this replica could go to instead?

        Membership checks only (not quota/backoff — those are transient):
        a non-holder host outside every holder's cluster, in the job's HR
        class when pinned. Scanning is O(hosts); beyond ``spread_scan_cap``
        hosts we assume eligibility (clusters are tiny relative to such
        fleets) and keep the constraint strict.
        """
        hosts = self.store.hosts
        if len(hosts) > self.policy.spread_scan_cap:
            return True
        holder_clusters = {self._cluster_of[h] for h in holders if h in self._cluster_of}
        app = self.store.apps.get(job.app_name)
        level = app.hr_level if app is not None else HRLevel.NONE
        for h_id, h in hosts.items():
            if h_id in holders:
                continue
            if self._cluster_of.get(h_id) in holder_clusters:
                continue
            if level != HRLevel.NONE and job.hr_class is not None:
                if hr_class(h, level) != job.hr_class:
                    continue
            return True
        return False

    # ------------------------------------------------------------------
    # validation-side feedback (identical scalar / deferred-batch feed)
    # ------------------------------------------------------------------

    def on_validation(
        self,
        valid: List[Tuple[int, int]],
        invalid: List[Tuple[int, int]],
        now: float,
    ) -> None:
        """One finalized decision: (host, app-version) pairs judged VALID /
        INVALID. Called inline on the scalar path and replayed from
        ``ValidationPlan.defense_events`` in the same order on the batch
        path — bit-equal counters by construction."""
        p = self.policy
        for hid, vid in valid:
            hr, vr = self._cell_nodate(hid, vid)
            q = self.quota[hr, vr] + 1.0
            self.quota[hr, vr] = q if q < p.quota_max else p.quota_max
            self._validated[hid] = self._validated.get(hid, 0) + 1
            bo = self._backoff.get(hid)
            if bo is not None:
                bo.register_success()
        if len(valid) >= 2:
            hosts = [h for h, _ in valid]
            for i in range(len(hosts)):
                for j in range(i + 1, len(hosts)):
                    self._bump_agree(hosts[i], hosts[j])
            self._clusters_dirty = True
        judged = bool(valid)  # only count losses against actual validators
        for hid, vid in invalid:
            self._punish(hid, vid, now)
            if judged:
                self._lost[hid] = self._lost.get(hid, 0) + 1
                self._clusters_dirty = True
        if len(invalid) >= 2:
            # Colluders outvoted by an honest quorum still *agreed with each
            # other* — co-INVALID results in one decision are an agreement
            # signal too. (Independently flaky hosts can also land here, but
            # they validate far more than they lose, never turn suspicious,
            # and so the edges stay inert for them.) This is what lets
            # clusters form from the clique's losses instead of needing it
            # to win quorums first.
            hosts = [h for h, _ in invalid]
            for i in range(len(hosts)):
                for j in range(i + 1, len(hosts)):
                    self._bump_agree(hosts[i], hosts[j])
            self._clusters_dirty = True

    def on_error(self, host_id: int, app_version_id: int, now: float) -> None:
        """Non-validation failure (compute error, crash, deadline miss)."""
        self._punish(host_id, app_version_id, now)

    def _punish(self, hid: int, vid: int, now: float) -> None:
        p = self.policy
        hr, vr = self._cell_nodate(hid, vid)
        q = self.quota[hr, vr] * 0.5
        self.quota[hr, vr] = q if q > p.quota_min else p.quota_min
        bo = self._backoff.get(hid)
        if bo is None:
            bo = ExponentialBackoff(
                min_interval=p.backoff_min,
                max_interval=p.backoff_max,
                jitter=p.backoff_jitter,
                seed=hid,
            )
            self._backoff[hid] = bo
        bo.register_failure(now)

    def _bump_agree(self, a: int, b: int) -> None:
        self._agree.setdefault(a, {})[b] = self._agree.get(a, {}).get(b, 0) + 1
        self._agree.setdefault(b, {})[a] = self._agree.get(b, {}).get(a, 0) + 1

    # ------------------------------------------------------------------
    # suspicion clusters
    # ------------------------------------------------------------------

    def cluster_of(self, host_id: int) -> Optional[int]:
        if self._clusters_dirty:
            self._rebuild_clusters()
        return self._cluster_of.get(host_id)

    def clusters(self) -> Dict[int, int]:
        """host_id -> cluster id (smallest member); components of size >= 2."""
        if self._clusters_dirty:
            self._rebuild_clusters()
        return dict(self._cluster_of)

    def _suspicious(self, hid: int) -> bool:
        lost = self._lost.get(hid, 0)
        if lost < self.policy.suspect_lost:
            return False
        return self._validated.get(hid, 0) < lost * self.policy.suspect_ratio

    def _rebuild_clusters(self) -> None:
        p = self.policy
        sus = {h for h in self._lost if self._suspicious(h)}
        cluster_of: Dict[int, int] = {}
        seen: Set[int] = set()
        for h in sorted(sus):
            if h in seen:
                continue
            comp = [h]
            seen.add(h)
            stack = [h]
            while stack:
                # Only suspicious nodes expand the frontier; accomplices
                # (below) join as leaves so one shared partner cannot
                # chain two unrelated honest hosts into a cluster.
                x = stack.pop()
                for y, c in sorted(self._agree.get(x, {}).items()):
                    if c < p.spread_min_agree or y in seen:
                        continue
                    if y in sus:
                        seen.add(y)
                        comp.append(y)
                        stack.append(y)
                    elif c >= p.accomplice_frac * self._validated.get(y, 0):
                        seen.add(y)
                        comp.append(y)
            if len(comp) >= 2:
                cid = min(comp)
                for x in comp:
                    cluster_of[x] = cid
        self._cluster_of = cluster_of
        self._clusters_dirty = False

    # ------------------------------------------------------------------
    # quota table plumbing (dense interned rows, à la AdaptiveReplication)
    # ------------------------------------------------------------------

    def _cell(self, hid: int, vid: int, now: float) -> Tuple[int, int]:
        """(row, col) with the daily send counter reset applied."""
        hr, vr = self._cell_nodate(hid, vid)
        d = int(now // self.policy.day_seconds)
        if self.day[hr, vr] != d:
            self.day[hr, vr] = d
            self.sent[hr, vr] = 0
        return hr, vr

    def _cell_nodate(self, hid: int, vid: int) -> Tuple[int, int]:
        hr = self._host_idx.get(hid)
        if hr is None:
            hr = len(self._host_idx)
            self._host_idx[hid] = hr
            if hr >= self.quota.shape[0]:
                self._grow(rows=max(self.quota.shape[0] * 2, hr + 1, 16))
        vr = self._ver_idx.get(vid)
        if vr is None:
            vr = len(self._ver_idx)
            self._ver_idx[vid] = vr
            if vr >= self.quota.shape[1]:
                self._grow(cols=max(self.quota.shape[1] * 2, vr + 1, 4))
        return hr, vr

    def _grow(self, rows: Optional[int] = None, cols: Optional[int] = None) -> None:
        r = rows if rows is not None else self.quota.shape[0]
        c = cols if cols is not None else self.quota.shape[1]
        q = np.full((r, c), self.policy.quota_init, dtype=np.float64)
        s = np.zeros((r, c), dtype=np.int64)
        d = np.full((r, c), -1, dtype=np.int64)
        r0, c0 = self.quota.shape
        q[:r0, :c0] = self.quota
        s[:r0, :c0] = self.sent
        d[:r0, :c0] = self.day
        self.quota, self.sent, self.day = q, s, d

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------

    def quota_of(self, host_id: int, app_version_id: int) -> float:
        hr = self._host_idx.get(host_id)
        vr = self._ver_idx.get(app_version_id)
        if hr is None or vr is None:
            return self.policy.quota_init
        return float(self.quota[hr, vr])

    def counters(self) -> Dict[str, int]:
        if self._clusters_dirty:
            self._rebuild_clusters()
        sizes: Dict[int, int] = {}
        for cid in self._cluster_of.values():
            sizes[cid] = sizes.get(cid, 0) + 1
        return {
            "quota_denials": self.quota_denials,
            "quota_deferrals": self.quota_deferrals,
            "spread_denials": self.spread_denials,
            "spread_relaxations": self.spread_relaxations,
            "spread_cancellations": self.spread_cancellations,
            "hr_pins": self.hr_pins,
            "hr_pin_blocked": self.hr_pin_blocked,
            "hr_relaxations": self.hr_relaxations,
            "dispatches": self.dispatches,
            "n_clusters": len(sizes),
            "cluster_sizes": sorted(sizes.values(), reverse=True),
            "suspicious_hosts": sorted(h for h in self._lost if self._suspicious(h)),
        }
