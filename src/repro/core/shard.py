"""Shard-aware federated dispatch (§5.1 scale-out).

The paper scales a BOINC server by running multiple scheduler instances
against one shared-memory job cache. This module adds the partitioning
layer that makes those instances *federated* rather than merely concurrent:

  * a stable **host→shard affinity** — every host is served by exactly one
    scheduler instance (``shard_of``), pinned overrides allowed — so a
    coalesced ``rpc_batch`` runs one vectorized ``handle_batch`` pass per
    shard instead of falling back to sequential per-request dispatch;
  * a **slot-ownership map** over the feeder cache — position ``i`` belongs
    to shard ``i % n_shards`` until migrated — giving each shard its own
    cache slice and therefore its own persistent
    :class:`~repro.core.batch_dispatch.BatchDispatchEngine` snapshot (keyed
    off the existing ``Feeder.version`` contract);
  * deterministic **work migration**: a starved shard (fewer live slots
    than ``ShardPolicy.low_watermark``) steals the lowest-index live slots
    from donor shards in ring order until it reaches
    ``ShardPolicy.refill_target``, never drawing a donor below the
    watermark. Every migration reassigns ownership and bumps the feeder's
    cache generation so all shard snapshots rebuild against the new map.

Parity contract: single-shard configs never construct a ShardMap, so they
stay bit-identical to the unsharded goldens; multi-shard assignment
equivalence is pinned by ``tests/test_shard_dispatch.py`` (union of
per-shard assignments == sequential affinity-routed dispatch under a pinned
affinity map equal to round-robin order).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class ShardPolicy:
    """Work-migration knobs. ``low_watermark=0`` disables migration (no
    shard is ever considered starved) — the parity tests use that to keep
    sequential and batched twins byte-for-byte comparable."""

    low_watermark: int = 4  # a shard is starved below this many live slots
    refill_target: int = 8  # steal until the starved shard holds this many
    max_moves: int = 64  # per-rebalance cap on stolen slots


@dataclass
class ShardStats:
    """Per-shard utilization counters (reported by the service layer and
    the RPC benchmark's per-shard utilization rows)."""

    requests: int = 0
    dispatched: int = 0
    migrations_in: int = 0
    migrations_out: int = 0


@dataclass
class ShardMap:
    """Host→shard affinity + feeder-cache slot ownership + migration."""

    n_shards: int
    cache_size: int
    # pinned host_id → shard overrides; unlisted hosts use host_id % n_shards
    affinity: Optional[Dict[int, int]] = None
    policy: ShardPolicy = field(default_factory=ShardPolicy)
    # slot position -> owning shard; initialized round-robin so every shard
    # gets an interleaved slice of whatever the feeder interleaves
    owner: np.ndarray = field(init=False, repr=False)
    stats: List[ShardStats] = field(init=False, repr=False)
    _owned: Dict[int, List[int]] = field(default_factory=dict, init=False, repr=False)

    def __post_init__(self) -> None:
        assert self.n_shards >= 1
        self.owner = np.arange(self.cache_size, dtype=np.int64) % self.n_shards
        self.stats = [ShardStats() for _ in range(self.n_shards)]

    # ------------------------------------------------------------------
    # affinity
    # ------------------------------------------------------------------

    def shard_of(self, host_id: int) -> int:
        """Stable host→shard affinity: pinned override, else modulo."""
        if self.affinity is not None:
            pinned = self.affinity.get(host_id)
            if pinned is not None:
                return pinned % self.n_shards
        return host_id % self.n_shards

    def forget_host(self, host_id: int) -> None:
        """Churn purge: drop the host's pinned affinity override (the
        modulo fallback is stateless). A host that rejoins under the same
        id is served by ``host_id % n_shards`` unless re-pinned."""
        if self.affinity is not None:
            self.affinity.pop(host_id, None)

    # ------------------------------------------------------------------
    # slot ownership
    # ------------------------------------------------------------------

    def owned_positions(self, shard: int) -> List[int]:
        """Ascending cache positions owned by ``shard`` (cached; the cache
        is dropped whenever migration rewrites the ownership map)."""
        cached = self._owned.get(shard)
        if cached is None:
            cached = np.flatnonzero(self.owner == shard).tolist()
            self._owned[shard] = cached
        return cached

    def live_count(self, feeder, shard: int) -> int:
        """Live (resident, not taken) slots currently owned by ``shard``.
        Between feeder fills every resident slot references a dispatchable
        instance (the feeder clears stale slots on fill), so this is the
        shard's dispatchable supply."""
        slots = feeder.slots
        return sum(
            1
            for p in self.owned_positions(shard)
            if slots[p] is not None and not slots[p].taken
        )

    # ------------------------------------------------------------------
    # work migration
    # ------------------------------------------------------------------

    def rebalance(self, feeder, shard: int) -> int:
        """Deterministic work migration for a starved shard.

        If ``shard`` holds fewer than ``policy.low_watermark`` live slots,
        steal the lowest-index live slots from donors in ring order
        (``shard+1, shard+2, …`` mod n) until it holds
        ``policy.refill_target`` (or ``policy.max_moves`` / donors run
        dry); donors are never drawn below the watermark. Returns the
        number of slots moved; any move bumps the feeder's cache
        generation so every shard's persistent engine snapshot rebuilds
        against the new ownership map.
        """
        pol = self.policy
        if pol.low_watermark <= 0 or self.n_shards < 2:
            return 0
        my_live = self.live_count(feeder, shard)
        if my_live >= pol.low_watermark:
            return 0
        slots = feeder.slots
        moved = 0
        for step in range(1, self.n_shards):
            if my_live >= pol.refill_target or moved >= pol.max_moves:
                break
            donor = (shard + step) % self.n_shards
            donor_live = [
                p
                for p in self.owned_positions(donor)
                if slots[p] is not None and not slots[p].taken
            ]
            while (
                my_live < pol.refill_target
                and moved < pol.max_moves
                and len(donor_live) > pol.low_watermark
            ):
                p = donor_live.pop(0)  # lowest-index live donor slot
                self.owner[p] = shard
                moved += 1
                my_live += 1
                self.stats[shard].migrations_in += 1
                self.stats[donor].migrations_out += 1
        if moved:
            self._owned.clear()
            feeder.invalidate()
        return moved

    # ------------------------------------------------------------------
    # utilization
    # ------------------------------------------------------------------

    def note(self, shard: int, requests: int = 0, dispatched: int = 0) -> None:
        st = self.stats[shard]
        st.requests += requests
        st.dispatched += dispatched

    def utilization(self) -> List[Dict[str, int]]:
        """Per-shard counters + current slot ownership, for the service
        layer's ``stats()`` and ``BENCH_rpc.json``'s utilization rows."""
        counts = np.bincount(self.owner, minlength=self.n_shards)
        return [
            {
                "shard": k,
                "requests": st.requests,
                "dispatched": st.dispatched,
                "migrations_in": st.migrations_in,
                "migrations_out": st.migrations_out,
                "owned_slots": int(counts[k]),
            }
            for k, st in enumerate(self.stats)
        ]
