"""Server-side job dispatch (§5.1 server architecture, §6.3–6.4 policy).

Architecture (§5.1): scheduler instances never scan the DB for dispatchable
work; a shared-memory **job cache** of ~1000 unsent instances is replenished
by a **feeder** daemon. The scheduler scans the cache (random start point to
reduce lock conflict), scores candidates, re-checks under a mutex ("fast
check"), then against the DB ("slow check"), and builds the reply. This is
what lets one server dispatch hundreds of jobs per second [paper ref 17] —
reproduced in ``benchmarks/bench_dispatch.py``.

Policy (§6.4): GPUs handled first; app-version selection by max
``proj_flops`` among (platform, plan-class, HR)-compatible versions; score =
weighted sum of keyword match, submitter allocation balance, skipped-before,
locality, size-quantile match; fast checks = disk / deadline-feasibility /
duplicate-in-reply; slow checks = one-instance-per-volunteer / job errored /
HR class.

Two dispatch engines implement the policy: the scalar per-request path here
(``handle_request``, the reference oracle) and the vectorized batch path
(``handle_batch`` + ``batch_dispatch.BatchDispatchEngine``), which scores
all cache slots × a batch of hosts in fused NumPy passes. The two are
result-identical (see ``tests/test_batch_dispatch.py``).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .adaptive import AdaptiveReplication
from .allocation import LinearBoundedAllocator
from .defense import DefenseLayer
from .estimation import RuntimeEstimator
from .keywords import KeywordPrefs, keyword_score
from .shard import ShardMap
from .store import JobStore
from .types import (
    App,
    AppVersion,
    HRLevel,
    Host,
    InstanceOutcome,
    InstanceState,
    Job,
    JobInstance,
    ResourceType,
    hr_class,
)

# ---------------------------------------------------------------------------
# RPC messages (§6.2, §6.4)
# ---------------------------------------------------------------------------


@dataclass
class ResourceRequest:
    """Per-processing-resource work request (§6.2)."""

    req_runtime: float = 0.0  # buffer shortfall, scaled seconds
    req_idle: float = 0.0  # idle instance count
    queue_dur: float = 0.0  # remaining scaled runtime of queued jobs


@dataclass
class CompletedResult:
    """A finished instance reported by the client."""

    instance_id: int
    outcome: InstanceOutcome
    runtime: float = 0.0
    peak_flop_count: float = 0.0
    output: Any = None
    exit_code: int = 0
    stderr: str = ""


@dataclass
class TrickleUp:
    """Partial-progress message from a running app (§3.5): conveyed
    immediately and handled by project-specific logic — e.g. partial credit
    for long jobs, or streamed training metrics in the grid runtime."""

    instance_id: int
    fraction_done: float
    payload: Any = None


@dataclass
class ScheduleRequest:
    host_id: int
    requests: Dict[ResourceType, ResourceRequest] = field(default_factory=dict)
    completed: List[CompletedResult] = field(default_factory=list)
    trickles: List[TrickleUp] = field(default_factory=list)
    sticky_files: Tuple[str, ...] = ()
    usable_disk: float = 1e12
    keyword_prefs: KeywordPrefs = field(default_factory=KeywordPrefs)
    # anonymous platform (§3.2): client-supplied app versions
    anonymous_versions: List[AppVersion] = field(default_factory=list)


@dataclass
class DispatchedJob:
    job: Job
    instance: JobInstance
    version: AppVersion
    est_flops: float  # server's FLOPS estimate for the program (§6.4)
    est_runtime: float


@dataclass
class ScheduleReply:
    jobs: List[DispatchedJob] = field(default_factory=list)
    delete_sticky: List[str] = field(default_factory=list)
    request_delay: float = 0.0


@dataclass
class Candidate:
    """One scored (cache slot, job, app version) dispatch candidate.

    Produced either by the scalar cache scan (``Scheduler._candidate_list``)
    or by the vectorized batch engine (``batch_dispatch``). The batch engine
    precomputes ``est_rt``/``scaled_rt`` in one fused pass; the scalar path
    leaves them ``None`` and the dispatch tail computes them lazily.
    """

    score: float
    slot: CacheSlot
    job: Job
    version: AppVersion
    usage: Dict[ResourceType, float]
    est_rt: Optional[float] = None
    scaled_rt: Optional[float] = None
    index: int = -1  # engine slot position (batch path only)


# ---------------------------------------------------------------------------
# Feeder + shared-memory job cache (§5.1)
# ---------------------------------------------------------------------------


@dataclass
class CacheSlot:
    instance_id: int
    job_id: int
    app_name: str
    taken: bool = False
    skipped: int = 0  # times passed over by a scheduler scan (§6.4 score)


@dataclass
class Feeder:
    """Replenishes the job cache from the store (§5.1), interleaving apps
    and size classes so all categories stay represented."""

    store: JobStore
    cache_size: int = 1024
    slots: List[Optional[CacheSlot]] = field(default_factory=list)
    # instance_id -> slot position, so the dispatch tail's clear_slot is
    # O(1) instead of a full cache scan per dispatched job
    _slot_idx: Dict[int, int] = field(default_factory=dict, repr=False)
    # cache-content generation, for the persistent vectorized dispatch
    # snapshot: bumped whenever slot contents change *outside* the dispatch
    # tail (a fill, or an explicit invalidate). Dispatch-tail mutations are
    # reported to the engine as events instead, so they do not invalidate.
    version: int = 0
    # persistent BatchDispatchEngine snapshots (built lazily by the
    # scheduler's vector-dispatch path), keyed by shard: ``None`` for the
    # unsharded shared-cache snapshot, shard index for the per-shard cache
    # slices of the federated dispatch path (core/shard.py). All snapshots
    # share this cache's generation counter, so one ``invalidate`` rebuilds
    # every shard's slice.
    _engines: Dict[Optional[int], object] = field(default_factory=dict, repr=False)

    def __post_init__(self) -> None:
        if not self.slots:
            self.slots = [None] * self.cache_size

    def invalidate(self) -> None:
        """Force the persistent dispatch snapshot to rebuild. Any code that
        mutates cache slots or the scoring fields of cached jobs outside the
        dispatch tail must call this (the feeder's own ``fill`` does)."""
        self.version += 1

    def fill(self) -> int:
        """One feeder pass; returns slots filled. Stale slots (instances no
        longer UNSENT) that cannot be refilled are cleared, so between
        fills every resident slot references a dispatchable instance — the
        persistent engine's validity arrays rely on this."""
        in_cache = {s.instance_id for s in self.slots if s is not None}
        stale = [i for i, s in enumerate(self.slots) if s is not None and self._stale(s)]
        vacancies = [i for i, s in enumerate(self.slots) if s is None or self._stale(s)]
        if not vacancies:
            return 0
        per_app: Dict[str, List[JobInstance]] = {}
        for app_name in self.store.apps:
            # exclude in-cache ids *inside* the queue walk: with a backlog
            # larger than the cache, the oldest UNSENT rows are exactly the
            # cached ones, and filtering after the limit would starve refills
            per_app[app_name] = self.store.unsent_instances(
                app_name, limit=len(vacancies), exclude=in_cache
            )
        filled = 0
        app_names = [a for a in per_app if per_app[a]]
        ai = 0
        for slot_idx in vacancies:
            while app_names and not per_app[app_names[ai % len(app_names)]]:
                app_names.pop(ai % len(app_names))
            if not app_names:
                break
            app_name = app_names[ai % len(app_names)]
            inst = per_app[app_name].pop(0)
            old = self.slots[slot_idx]
            if old is not None:
                self._slot_idx.pop(old.instance_id, None)
            self.slots[slot_idx] = CacheSlot(
                instance_id=inst.id, job_id=inst.job_id, app_name=app_name
            )
            self._slot_idx[inst.id] = slot_idx
            in_cache.add(inst.id)
            filled += 1
            ai += 1
        cleared = 0
        for i in stale:
            s = self.slots[i]
            if s is not None and self._stale(s):
                self._slot_idx.pop(s.instance_id, None)
                self.slots[i] = None
                cleared += 1
        if filled or cleared:
            self.invalidate()
        return filled

    def _stale(self, slot: CacheSlot) -> bool:
        inst = self.store.instances.get(slot.instance_id)
        return inst is None or inst.state != InstanceState.UNSENT

    def clear_slot(self, instance_id: int) -> None:
        # no ``invalidate()`` here: the only caller is the dispatch tail,
        # which reports the mutation to the persistent engine as a
        # ("dispatch", candidate) event instead
        i = self._slot_idx.pop(instance_id, None)
        if i is not None:
            s = self.slots[i]
            if s is not None and s.instance_id == instance_id:
                self.slots[i] = None


# ---------------------------------------------------------------------------
# Scheduler (§6.4)
# ---------------------------------------------------------------------------

_RESOURCE_ORDER = (ResourceType.TPU, ResourceType.GPU, ResourceType.CPU)  # GPUs first (§6.4)

# score weights (§6.4 "weighted sum of several factors")
W_KEYWORD = 10.0
W_BALANCE = 1.0
W_SKIPPED = 5.0
W_LOCALITY = 20.0
W_SIZE_MATCH = 8.0
W_PRIORITY = 1.0


@dataclass
class SchedulerMetrics:
    requests: int = 0
    dispatched: int = 0
    reported: int = 0
    fast_check_rejects: int = 0
    slow_check_rejects: int = 0
    cache_misses: int = 0


@dataclass
class Scheduler:
    store: JobStore
    feeder: Feeder
    estimator: RuntimeEstimator
    allocator: Optional[LinearBoundedAllocator] = None
    adaptive: Optional[AdaptiveReplication] = None
    seed: int = 0
    # route *every* request — including singleton RPCs — through the
    # vectorized dispatch engine, against a persistent cache snapshot that
    # is maintained incrementally (dispatch-tail events) and rebuilt only
    # when the feeder's cache generation changes. Bit-identical to the
    # scalar scan (tests/test_batch_dispatch.py); False keeps the scalar
    # O(slots²) reference path as the oracle.
    vector_dispatch: bool = False
    # execution backend handed to BatchDispatchEngine ("numpy" | "jax");
    # "jax" runs the dense mask/score passes as staged jits, bit-identical
    # to the NumPy engine (4th parity axis in core/scenarios.run_parity)
    engine_backend: str = "numpy"
    # defense layer (§3.4 work-spreading / HR census / host punishment);
    # enforced in the shared slow-check + dispatch choke points, so the
    # scalar and vectorized tails stay result-identical
    defense: Optional["DefenseLayer"] = None
    # federated dispatch (core/shard.py): when set, this instance serves
    # only hosts whose affinity maps to ``shard`` and scans only the cache
    # positions that shard owns — the scalar scan and the engine snapshot
    # are both restricted to the slice, keeping them bit-identical to each
    # other. None = the classic shared-cache instance (full scan).
    shard_map: Optional[ShardMap] = None
    shard: int = 0
    metrics: SchedulerMetrics = field(default_factory=SchedulerMetrics)
    _rng: random.Random = field(default=None, repr=False)  # type: ignore[assignment]

    def __post_init__(self) -> None:
        self._rng = random.Random(self.seed)

    # ------------------------------------------------------------------

    def _persistent_engine(self):
        """The shared persistent dispatch snapshot, rebuilt on cache-content
        generation changes (feeder fills / explicit invalidations)."""
        from .batch_dispatch import BatchDispatchEngine  # deferred: avoids cycle

        feeder = self.feeder
        key = self.shard if self.shard_map is not None else None
        engine = feeder._engines.get(key)
        if (
            engine is None
            or engine.version != feeder.version
            or engine.backend != self.engine_backend
        ):
            # the constructor stamps the snapshot with feeder.version
            engine = BatchDispatchEngine(self.store, feeder,
                                         backend=self.engine_backend,
                                         shard_map=self.shard_map,
                                         shard=key)
            feeder._engines[key] = engine
        return engine

    def handle_request(self, req: ScheduleRequest, now: float) -> ScheduleReply:
        if self.vector_dispatch:
            return self._handle_one(req, now, engine=self._persistent_engine())
        reply = self._handle_one(req, now, engine=None)
        # scalar dispatch mutates slots without emitting engine events: any
        # persistent snapshot other schedulers hold is now stale
        if self.feeder._engines:
            self.feeder.invalidate()
        return reply

    def handle_batch(self, reqs: Sequence[ScheduleRequest], now: float) -> List[ScheduleReply]:
        """Dispatch a batch of scheduler RPCs against one cache snapshot.

        Semantically identical to N sequential :meth:`handle_request` calls
        (same RNG consumption, same assignments, same metrics — asserted by
        ``tests/test_batch_dispatch.py``), but candidate scoring runs as one
        vectorized slots×host pass per request instead of the scalar
        O(slots²) scan. Requests are processed in order; the shared dispatch
        tail reports every slot mutation back to the engine as an event so
        later requests in the batch observe taken slots, skip-count bumps,
        and HR / homogeneous-app-version locks exactly as they would under
        sequential execution. With ``vector_dispatch`` the batch runs
        against the persistent snapshot; otherwise a fresh snapshot is built
        per call (the original PR 1 behavior, kept as the oracle).
        """
        from .batch_dispatch import BatchDispatchEngine  # deferred: avoids cycle

        if self.vector_dispatch:
            engine = self._persistent_engine()
            return [self._handle_one(req, now, engine=engine) for req in reqs]
        engine = BatchDispatchEngine(self.store, self.feeder,
                                     backend=self.engine_backend,
                                     shard_map=self.shard_map,
                                     shard=self.shard if self.shard_map is not None else None)
        replies = [self._handle_one(req, now, engine=engine) for req in reqs]
        if self.feeder._engines:
            self.feeder.invalidate()  # slot mutations bypassed the snapshot
        return replies

    def _handle_one(self, req: ScheduleRequest, now: float, engine) -> ScheduleReply:
        """One scheduler RPC; candidates come from the scalar cache scan or,
        when ``engine`` is given, from the vectorized batch engine."""
        self.metrics.requests += 1
        host = self.store.hosts.get(req.host_id)
        reply = ScheduleReply()
        if host is None:
            reply.request_delay = 3600.0
            return reply

        self._process_completed(req, host, now)

        disk_left = req.usable_disk
        if disk_left < 0:
            # over limit: direct the client to delete sticky files (§3.10)
            reply.delete_sticky = list(req.sticky_files)
            return reply

        for rtype in _RESOURCE_ORDER:
            rreq = req.requests.get(rtype)
            if rreq is None or (rreq.req_runtime <= 0 and rreq.req_idle <= 0):
                continue
            if engine is None:
                disk_left = self._dispatch_resource(
                    host, req, rtype, rreq, reply, disk_left, now
                )
                continue
            # same RNG draw as the scalar scan's random start point
            start = self._rng.randrange(engine.n) if engine.n else 0
            disk_left = self._dispatch_resource_vec(
                engine, host, req, rtype, rreq, reply, disk_left, now, start
            )
        return reply

    # ------------------------------------------------------------------

    def _process_completed(self, req: ScheduleRequest, host: Host, now: float) -> None:
        """Report path: completed instances update the DB + estimators."""
        for c in req.completed:
            inst = self.store.instances.get(c.instance_id)
            if inst is None or inst.state == InstanceState.OVER:
                continue
            inst.state = InstanceState.OVER
            inst.outcome = c.outcome
            inst.received_time = now
            inst.runtime = c.runtime
            inst.peak_flop_count = c.peak_flop_count
            inst.output = c.output
            inst.exit_code = c.exit_code
            inst.stderr = c.stderr
            self.metrics.reported += 1
            job = self.store.jobs.get(inst.job_id)
            if job is not None:
                job.transition_flag = True
                version = self.store.app_versions.get(inst.app_version_id or -1)
                if version is not None and c.outcome == InstanceOutcome.SUCCESS:
                    self.estimator.record(host, version, job, c.runtime)
                if self.adaptive is not None and c.outcome != InstanceOutcome.SUCCESS \
                        and inst.app_version_id is not None:
                    self.adaptive.on_invalid(host.id, inst.app_version_id)
                if self.defense is not None and c.outcome != InstanceOutcome.SUCCESS \
                        and inst.app_version_id is not None:
                    self.defense.on_error(host.id, inst.app_version_id, now)
                # debit the submitter's allocation balance (§3.9)
                if self.allocator is not None and c.runtime > 0:
                    self.allocator.debit(job.submitter, c.runtime, now)

    # ------------------------------------------------------------------

    def _dispatch_resource(
        self,
        host: Host,
        req: ScheduleRequest,
        rtype: ResourceType,
        rreq: ResourceRequest,
        reply: ScheduleReply,
        disk_left: float,
        now: float,
        candidates: Optional[Sequence[Candidate]] = None,
        events: Optional[List[Tuple[str, Candidate]]] = None,
    ) -> float:
        """Dispatch tail shared by the scalar and batch paths.

        ``candidates`` may be any iterable in descending-score order; when
        omitted, the scalar cache scan produces it. ``events`` (batch path)
        collects slot-state mutations for the engine's incremental arrays.
        """
        if candidates is None:
            candidates = self._candidate_list(host, req, rtype, now)
        queue_dur = rreq.queue_dur
        req_runtime = rreq.req_runtime
        req_idle = rreq.req_idle
        sending_jobs = {d.job.id for d in reply.jobs}

        for cand in candidates:
            slot, job, version, usage = cand.slot, cand.job, cand.version, cand.usage
            inst = self.store.instances.get(slot.instance_id)
            # fast check (§6.4): still unsent? (another scheduler may have taken it)
            if inst is None or inst.state != InstanceState.UNSENT or slot.taken:
                self.metrics.cache_misses += 1
                if events is not None and slot.taken:
                    events.append(("taken", cand))
                continue
            est_rt = (
                cand.est_rt
                if cand.est_rt is not None
                else self.estimator.est_runtime(job, host, version)
            )
            scaled_rt = (
                cand.scaled_rt
                if cand.scaled_rt is not None
                else self._scale_runtime(est_rt, host, rtype)
            )
            if job.disk_bytes > disk_left:
                self.metrics.fast_check_rejects += 1
                slot.skipped += 1
                if events is not None:
                    events.append(("skip", cand))
                continue
            if queue_dur + scaled_rt > job.delay_bound:
                # probably won't make the deadline (§6.4 fast check b)
                self.metrics.fast_check_rejects += 1
                slot.skipped += 1
                if events is not None:
                    events.append(("skip", cand))
                continue
            if job.id in sending_jobs:
                self.metrics.fast_check_rejects += 1
                continue

            slot.taken = True
            # slow check (§6.4): DB-level conditions
            if not self._slow_check(job, host, version, now):
                slot.taken = False
                self.metrics.slow_check_rejects += 1
                slot.skipped += 1
                if events is not None:
                    events.append(("skip", cand))
                continue

            self._dispatch(job, inst, host, version, now, reply, est_rt)
            sending_jobs.add(job.id)
            self.feeder.clear_slot(inst.id)
            if events is not None:
                events.append(("dispatch", cand))
            disk_left -= job.disk_bytes
            queue_dur += scaled_rt
            req_runtime -= scaled_rt
            req_idle -= usage.get(rtype, 0.0)
            if req_runtime <= 0 and req_idle <= 0:
                break
        return disk_left

    def _dispatch_resource_vec(
        self,
        engine,
        host: Host,
        req: ScheduleRequest,
        rtype: ResourceType,
        rreq: ResourceRequest,
        reply: ScheduleReply,
        disk_left: float,
        now: float,
        start: int,
    ) -> float:
        """Array-driven dispatch tail for the vectorized engine: identical
        checks, order, metrics, and slot mutations to
        :meth:`_dispatch_resource` over ``engine.candidates``, but the
        fast-check rejections — the overwhelming bulk of the visited
        candidates — are classified as whole array prefixes (``engine.valid``
        is exact, see the engine's build-time staleness probe) and skip-bumped
        through ``engine.bulk_skip`` instead of per-candidate Python."""
        rows = engine.candidate_rows(self, host, req, rtype, start, now)
        if rows is None:
            return disk_left
        pos, gidx, _scores, est, scaled, choices, disk_c, delay_c = rows
        queue_dur = rreq.queue_dur
        req_runtime = rreq.req_runtime
        req_idle = rreq.req_idle
        sending_jobs = {d.job.id for d in reply.jobs}
        metrics = self.metrics
        slots = engine.slots
        insts = self.store.instances
        jobs = self.store.jobs
        unsent = InstanceState.UNSENT
        n = len(pos)
        k = 0

        def bulk_reject(a: int, b: int) -> None:
            """Candidates [a, b) all failed a disk/deadline fast check: the
            valid ones get the skip-bump (fast_check_rejects), the rest are
            cache misses — exactly the scalar per-candidate classification."""
            if a >= b:
                return
            seg = pos[a:b]
            v = engine.valid[seg]
            bump = seg[v]
            miss = len(seg) - len(bump)
            if miss:
                metrics.cache_misses += miss
            if len(bump):
                metrics.fast_check_rejects += len(bump)
                engine.bulk_skip(bump)

        while k < n:
            # vectorized fast checks (§6.4 a/b) over the remaining ranked
            # candidates at the *current* disk/queue budget — the budget
            # only changes on a dispatch, so the prefix scan is exact
            ok = (disk_c[k:] <= disk_left) & (queue_dur + scaled[k:] <= delay_c[k:])
            hits = np.flatnonzero(ok)
            if hits.size == 0:
                bulk_reject(k, n)
                break
            m = k + int(hits[0])
            bulk_reject(k, m)
            k = m
            p = int(pos[k])
            slot = slots[p]
            inst = insts.get(slot.instance_id)
            # fast check (§6.4): still unsent? (another scheduler may have taken it)
            if inst is None or inst.state != unsent or slot.taken:
                metrics.cache_misses += 1
                if slot.taken:
                    engine.valid[p] = False
                k += 1
                continue
            job = jobs.get(slot.job_id)
            if job is None:
                k += 1
                continue  # purged after snapshot build: scalar scan skips it
            if job.id in sending_jobs:
                metrics.fast_check_rejects += 1
                k += 1
                continue

            choice = choices[int(gidx[k])]
            slot.taken = True
            # slow check (§6.4): DB-level conditions
            if not self._slow_check(job, host, choice.version, now):
                slot.taken = False
                metrics.slow_check_rejects += 1
                slot.skipped += 1
                engine.apply_skip(p, job, slot)
                k += 1
                continue

            scaled_rt = scaled[k]
            self._dispatch(job, inst, host, choice.version, now, reply, float(est[k]))
            sending_jobs.add(job.id)
            self.feeder.clear_slot(inst.id)
            engine.apply_dispatch(p, job)
            disk_left -= job.disk_bytes
            queue_dur += scaled_rt
            req_runtime -= scaled_rt
            req_idle -= choice.usage.get(rtype, 0.0)
            k += 1
            if req_runtime <= 0 and req_idle <= 0:
                break
        return disk_left

    # ------------------------------------------------------------------

    def _candidate_list(
        self, host: Host, req: ScheduleRequest, rtype: ResourceType, now: float
    ) -> List[Candidate]:
        """Scan the job cache from a random start; score candidates (§6.4).

        Under federated dispatch the scan is restricted to the cache
        positions this scheduler's shard owns — the same rotated visiting
        order over a masked slice, mirroring the engine snapshot's
        build-time ownership mask."""
        slots = self.feeder.slots
        n = len(slots)
        start = self._rng.randrange(n) if n else 0
        owner = self.shard_map.owner if self.shard_map is not None else None
        out: List[Candidate] = []
        seen_jobs = set()
        for k in range(n):
            idx = (start + k) % n
            if owner is not None and owner[idx] != self.shard:
                continue
            slot = slots[idx]
            if slot is None or slot.taken:
                continue
            job = self.store.jobs.get(slot.job_id)
            if job is None or slot.job_id in seen_jobs:
                continue
            app = self.store.apps[job.app_name]
            if job.target_host is not None and job.target_host != host.id:
                continue  # targeted jobs (§3.5)
            version, usage = self._select_version(app, job, host, req, rtype)
            if version is None:
                continue
            score = self._score(job, app, host, req, version, rtype, now)
            if score is None:
                continue
            seen_jobs.add(slot.job_id)
            out.append(Candidate(score=score, slot=slot, job=job, version=version, usage=usage))
        out.sort(key=lambda c: -c.score)
        return out

    # ------------------------------------------------------------------

    def _select_version(
        self,
        app: App,
        job: Job,
        host: Host,
        req: ScheduleRequest,
        rtype: ResourceType,
    ) -> Tuple[Optional[AppVersion], Dict[ResourceType, float]]:
        """Best app version for (job, host, resource) by proj_flops (§6.4)."""
        pool = list(app.latest_versions())
        if req.anonymous_versions:
            # anonymous platform (§3.2): client-built versions take part
            pool += [v for v in req.anonymous_versions if v.app_name == app.name]
        best: Optional[AppVersion] = None
        best_usage: Dict[ResourceType, float] = {}
        best_pf = -1.0
        for v in pool:
            if job.pinned_version_num is not None and v.version_num != job.pinned_version_num:
                continue  # version pinning (§3.5)
            if job.hav_version_id is not None and v.id != job.hav_version_id:
                continue  # homogeneous app version (§3.4)
            if not host.supports_platform(v.platform):
                continue
            ev = v.plan_class.evaluate(host)
            if ev is None:
                continue
            usage, _ = ev
            if usage.get(rtype, 0.0) <= 0.0:
                continue  # version doesn't use this resource
            pf = self.estimator.proj_flops(host, v)
            if pf > best_pf:
                best, best_usage, best_pf = v, usage, pf
        return best, best_usage

    # ------------------------------------------------------------------

    def _score(
        self,
        job: Job,
        app: App,
        host: Host,
        req: ScheduleRequest,
        version: AppVersion,
        rtype: ResourceType,
        now: float,
    ) -> Optional[float]:
        # HR constraint: job locked to an equivalence class (§3.4)
        if app.hr_level != HRLevel.NONE and job.hr_class is not None:
            if hr_class(host, app.hr_level) != job.hr_class:
                return None
        kscore = keyword_score(job.keywords, req.keyword_prefs)
        if kscore is None:
            return None  # "no" keyword: never send (§2.4)
        score = W_KEYWORD * kscore
        if self.allocator is not None:
            score += W_BALANCE * self.allocator.priority(job.submitter, now)
        score += W_PRIORITY * job.priority
        # skipped-before boost: hard-to-send jobs go while they can (§6.4).
        # Under federated dispatch the lookup is slice-local (first owned
        # slot of the job) — skip counts are per-shard state, matching the
        # engine snapshot's slice-local ``skips`` array.
        slot_skips = 0
        slots = self.feeder.slots
        if self.shard_map is None:
            for s in slots:
                if s is not None and s.job_id == job.id:
                    slot_skips = s.skipped
                    break
        else:
            for p in self.shard_map.owned_positions(self.shard):
                s = slots[p]
                if s is not None and s.job_id == job.id:
                    slot_skips = s.skipped
                    break
        score += W_SKIPPED * min(slot_skips, 5)
        # locality scheduling (§3.5): prefer jobs whose files are resident
        if app.uses_locality and job.input_files:
            resident = len(set(job.input_files) & set(req.sticky_files))
            score += W_LOCALITY * (resident / len(job.input_files))
        # multi-size jobs (§3.5): match job size class to host speed quantile
        if app.multi_size and app.n_size_classes > 1:
            all_pf = [st.mean for st in self.estimator.version.values() if st.n > 0]
            pop = [1.0 / m for m in all_pf if m > 0]
            q = self.estimator.size_quantile(host, version, app.n_size_classes, pop)
            if q == job.size_class:
                score += W_SIZE_MATCH
        return score

    # ------------------------------------------------------------------

    def _slow_check(
        self,
        job: Job,
        host: Host,
        version: Optional[AppVersion] = None,
        now: float = 0.0,
    ) -> bool:
        if job.state.value != "active":
            return False  # errored out since we considered it
        if self.store.host_has_instance_of_job(host.id, job.id):
            return False  # one instance per volunteer (§6.4)
        if self.defense is not None and version is not None:
            # defense layer (§3.4): punishment deferral, daily quota,
            # work-spreading suspicion clusters
            return self.defense.check_dispatch(job, host, version, now)
        return True

    # ------------------------------------------------------------------

    def _dispatch(
        self,
        job: Job,
        inst: JobInstance,
        host: Host,
        version: AppVersion,
        now: float,
        reply: ScheduleReply,
        est_rt: float,
    ) -> None:
        app = self.store.apps[job.app_name]
        inst.state = InstanceState.IN_PROGRESS
        inst.host_id = host.id
        inst.app_version_id = version.id
        inst.sent_time = now
        inst.deadline = now + job.delay_bound
        # lock HR class / app version on first dispatch (§3.4). With the
        # defense layer active, the census guard skips the pin when the
        # class holds too few hosts to reach quorum (logged, not fatal) —
        # the batch engine folds the lock from job.hr_class afterwards, so
        # the guard propagates to the fused HR mask automatically.
        if app.hr_level != HRLevel.NONE and job.hr_class is None:
            if self.defense is None or self.defense.can_pin(host, app, job):
                job.hr_class = hr_class(host, app.hr_level)
        if app.homogeneous_app_version and job.hav_version_id is None:
            job.hav_version_id = version.id
        # adaptive replication decision (§3.4): replicate this host's job?
        if app.adaptive_replication and job.min_quorum <= 1:
            if self.adaptive is not None and self.adaptive.should_replicate(host.id, version.id):
                job.min_quorum = app.min_quorum
                job.init_ninstances = max(job.init_ninstances, app.min_quorum)
                job.transition_flag = True  # transitioner creates the replica
        self.metrics.dispatched += 1
        if self.defense is not None:
            self.defense.on_dispatch(job, app, host, version, now)
        reply.jobs.append(
            DispatchedJob(
                job=job,
                instance=inst,
                version=version,
                est_flops=self.estimator.proj_flops(host, version),
                est_runtime=est_rt,
            )
        )

    # ------------------------------------------------------------------

    @staticmethod
    def _scale_runtime(raw: float, host: Host, rtype: ResourceType) -> float:
        """Raw -> scaled runtime using availability (§6)."""
        res = host.resources.get(rtype)
        avail = (res.availability if res else 1.0) * host.on_fraction
        if avail <= 0:
            return float("inf")
        return raw / avail
