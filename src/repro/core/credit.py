"""The credit system (§7).

One unit of credit = one day of a 1-GFLOPS (Whetstone) CPU. For a completed
instance J:

  PFC(J) = sum_r runtime(J) * usage(r) * peak_flops(r)

Claimed credit is PFC times two normalization factors:

  * version normalization: avg-PFC of the most efficient version divided by
    this version's avg-PFC (credit is independent of version efficiency);
  * host normalization: the app version's avg-PFC divided by this
    (host, version)'s avg-PFC (credit is independent of host efficiency).

Granted credit is an outlier-robust weighted average over the instances of a
replicated job, granted equally to all instances. Cross-project credit sums a
volunteer's credit over projects via stable cross-project IDs (CPIDs).
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

from .estimation import OnlineStats
from .types import AppVersion, Host, Job, JobInstance, ResourceType

SECONDS_PER_DAY = 86400.0
GFLOP = 1e9
#: FLOPs per credit unit: one day at 1 GFLOPS.
COBBLESTONE_SCALE = SECONDS_PER_DAY * GFLOP


def peak_flop_count(
    runtime: float, usage: Dict[ResourceType, float], host: Host
) -> float:
    """PFC(J) (§7)."""
    pfc = 0.0
    for rtype, amount in usage.items():
        res = host.resources.get(rtype)
        if res is not None:
            pfc += runtime * amount * res.peak_flops
    return pfc


@dataclass
class CreditSystem:
    """Adaptive credit with version & host normalization (§7)."""

    min_samples: int = 3
    # statistics of PFC(J)/est_flop_count(J)
    version_stats: Dict[int, OnlineStats] = field(default_factory=dict)
    # deliberately retained across host removal: straggler instances that
    # report after their host departed still need the (host, version)
    # normalization history for fair credit (§7; see the rationale on
    # ProjectServer.remove_host).
    host_version_stats: Dict[Tuple[int, int], OnlineStats] = field(default_factory=dict)  # reprolint: ignore[purge-complete]
    # totals (per host / volunteer / team), plus exponentially-weighted recent
    total: Dict[str, float] = field(default_factory=dict)
    recent: Dict[str, float] = field(default_factory=dict)
    recent_tau: float = 7 * 86400.0  # half-life-ish decay constant
    _recent_t: Dict[str, float] = field(default_factory=dict)

    # ---- statistics ----

    def record(self, instance: JobInstance, job: Job) -> None:
        if job.est_flop_count <= 0 or instance.peak_flop_count <= 0:
            return
        x = instance.peak_flop_count / job.est_flop_count
        assert instance.app_version_id is not None and instance.host_id is not None
        self.version_stats.setdefault(instance.app_version_id, OnlineStats()).add(x)
        self.host_version_stats.setdefault(
            (instance.host_id, instance.app_version_id), OnlineStats()
        ).add(x)

    def _version_norm(self, app_version_id: int, peer_version_ids: Iterable[int]) -> float:
        """Most-efficient-version avg-PFC / this version's avg-PFC."""
        mine = self.version_stats.get(app_version_id)
        if mine is None or mine.n < self.min_samples or mine.mean <= 0:
            return 1.0
        best = mine.mean
        for vid in peer_version_ids:
            st = self.version_stats.get(vid)
            if st is not None and st.n >= self.min_samples and 0 < st.mean < best:
                best = st.mean
        return best / mine.mean

    def _host_norm(self, host_id: int, app_version_id: int) -> float:
        hv = self.host_version_stats.get((host_id, app_version_id))
        v = self.version_stats.get(app_version_id)
        if (
            hv is None or v is None
            or hv.n < self.min_samples or v.n < self.min_samples
            or hv.mean <= 0 or v.mean <= 0
        ):
            return 1.0
        return v.mean / hv.mean

    # ---- claiming & granting ----

    def claimed_credit(
        self,
        instance: JobInstance,
        peer_version_ids: Iterable[int] = (),
    ) -> float:
        assert instance.app_version_id is not None and instance.host_id is not None
        pfc = instance.peak_flop_count
        pfc *= self._version_norm(instance.app_version_id, peer_version_ids)
        pfc *= self._host_norm(instance.host_id, instance.app_version_id)
        return pfc / COBBLESTONE_SCALE

    @staticmethod
    def grant_amount(claimed: List[float]) -> float:
        """Outlier-robust combination of claimed credits (§7): drop the
        high/low extremes when >2 claims, then average.

        A claim of exactly zero is legitimate (a valid instance whose PFC
        happened to be zero — e.g. a non-CPU-intensive app) and belongs in
        the trim set; only *negative* values are unset/error sentinels and
        are excluded. (The old ``c > 0`` filter silently dropped zero
        claims from the trim, skewing the average upward, and fell through
        to the empty-claims 0.0 fallback when every claim was zero.)
        """
        vals = sorted(c for c in claimed if c >= 0.0)
        if not vals:
            return 0.0
        if len(vals) > 2:
            vals = vals[1:-1]
        return sum(vals) / len(vals)

    def ingest_batch(
        self,
        entries: List[Tuple[Job, List[JobInstance], List[int]]],
    ) -> List[float]:
        """Batched stats ingestion for the batch validation engine (§7).

        For every ``(job, valid_instances, peer_version_ids)`` entry — in
        order — records the PFC sample and computes claimed credit for each
        instance (setting ``instance.claimed_credit``), then returns the
        per-job grant amounts. The float operations and their order are
        *identical* to the scalar ``record()`` / ``claimed_credit()`` /
        ``grant_amount()`` sequence, so engine and oracle grant bit-equal
        credit; the batching win is hoisted lookups and no per-call
        ``setdefault`` allocations across the tick's whole validated set.
        """
        vstats = self.version_stats
        hvstats = self.host_version_stats
        ms = self.min_samples
        grants: List[float] = []
        # scanning a version's own entry can never lower ``best`` below its
        # own mean, so the peer scan only needs the *other* versions
        others_cache: Dict[Tuple[int, Optional[int]], List[int]] = {}
        for job, valid, peers in entries:
            est = job.est_flop_count
            claims: List[float] = []
            for inst in valid:
                pfc = inst.peak_flop_count
                vid = inst.app_version_id
                hid = inst.host_id
                vstat = vstats.get(vid)
                hkey = (hid, vid)
                hstat = hvstats.get(hkey)
                if est > 0 and pfc > 0:
                    x = pfc / est
                    if vstat is None:
                        vstat = vstats[vid] = OnlineStats()
                    vstat.n = n = vstat.n + 1
                    delta = x - vstat.mean
                    vstat.mean += delta / n
                    vstat._m2 += delta * (x - vstat.mean)
                    if hstat is None:
                        hstat = hvstats[hkey] = OnlineStats()
                    hstat.n = n = hstat.n + 1
                    delta = x - hstat.mean
                    hstat.mean += delta / n
                    hstat._m2 += delta * (x - hstat.mean)
                # inlined claimed_credit (same op order: pfc*vn, *hn, /scale)
                c = pfc
                if vstat is not None and vstat.n >= ms and vstat.mean > 0:
                    okey = (id(peers), vid)
                    others = others_cache.get(okey)
                    if others is None:
                        others = others_cache[okey] = [p for p in peers if p != vid]
                    best = vstat.mean
                    for pid in others:
                        stp = vstats.get(pid)
                        if stp is not None and stp.n >= ms and 0 < stp.mean < best:
                            best = stp.mean
                    c *= best / vstat.mean
                if not (
                    hstat is None or vstat is None
                    or hstat.n < ms or vstat.n < ms
                    or hstat.mean <= 0 or vstat.mean <= 0
                ):
                    c *= vstat.mean / hstat.mean
                c = c / COBBLESTONE_SCALE
                inst.__dict__["claimed_credit"] = c  # untracked field
                claims.append(c)
            grants.append(self.grant_amount(claims))
        return grants

    def grant_many(self, by_key: Dict[str, List[float]], now: float) -> None:
        """Replay one tick's grants grouped per key, in per-key event order.

        Float-identical to calling :meth:`grant` once per amount at the
        same ``now``: only a key's own sequence touches its accumulators,
        so grouping by key cannot change any result — the first grant
        applies the decay, the rest add (``now == last`` after the first).
        """
        total = self.total
        recent = self.recent
        recent_t = self._recent_t
        for key, amounts in by_key.items():
            t = total.get(key, 0.0)
            last = recent_t.get(key)
            prev = recent.get(key, 0.0)
            if last is not None and now > last:
                prev *= math.exp(-(now - last) / self.recent_tau)
            for a in amounts:
                t += a
                prev += a
            total[key] = t
            recent[key] = prev
            recent_t[key] = now

    def grant(self, key: str, amount: float, now: float = 0.0) -> None:
        """Credit a host/volunteer/team accounting key."""
        self.total[key] = self.total.get(key, 0.0) + amount
        # exponentially-weighted recent average credit (per §7)
        last = self._recent_t.get(key)
        prev = self.recent.get(key, 0.0)
        if last is not None and now > last:
            decay = math.exp(-(now - last) / self.recent_tau)
            prev *= decay
        self.recent[key] = prev + amount
        self._recent_t[key] = now


# ---------------------------------------------------------------------------
# Cross-project credit (§7)
# ---------------------------------------------------------------------------


def volunteer_cpid(email: str) -> str:
    """Cross-project volunteer ID: based on email but can't be inverted."""
    return hashlib.sha256(("boinc-cpid:" + email.strip().lower()).encode()).hexdigest()[:32]


def host_cpid_consensus(candidate_cpids: Iterable[str]) -> str:
    """Consensus host CPID across projects: deterministic least element."""
    cands = sorted(set(candidate_cpids))
    if not cands:
        raise ValueError("no candidate CPIDs")
    return cands[0]


def collate_cross_project(
    exports: Dict[str, Dict[str, float]]
) -> Dict[str, float]:
    """Combine per-project exported credit keyed by CPID (3rd-party stats
    sites, §7): exports[project][cpid] -> credit."""
    out: Dict[str, float] = {}
    for per_project in exports.values():
        for cpid, credit in per_project.items():
            out[cpid] = out.get(cpid, 0.0) + credit
    return out
