"""Vectorized batch-dispatch engine (§5.1, §6.4).

The paper's headline server-scaling claim — hundreds of dispatches per
second from one machine — rests on scoring candidates out of a shared-memory
job cache rather than the DB. The scalar ``Scheduler._candidate_list`` /
``_score`` path reproduces the *policy* faithfully but pays O(slots²) Python
per request (the skipped-count lookup rescans the cache per scored slot),
which caps the dispatch benchmark and the EmBOINC-style simulator (§9) far
below the populations where volunteer computing pays off.

This module materializes the feeder's cache into struct-of-arrays form once
per batch of requesting hosts and computes the §6.4 score for all cache
slots × one host as fused NumPy passes:

  * static per-slot arrays: size class, est. FLOP count, disk bound, delay
    bound, priority, submitter index, keyword-set index, HR-class id,
    pinned/homogeneous-version ids, target host;
  * per-host vector passes: eligibility masks (slot valid, targeted-job,
    HR-class, keyword veto), the weighted score sum, deadline/disk
    feasibility inputs (est. and availability-scaled runtimes), and a
    stable descending-score ordering (the top-k gather: the dispatch tail
    consumes candidates lazily and stops once the request is satisfied).

Scoring is bit-exact with the scalar path: every per-element operation
mirrors ``Scheduler._score`` in IEEE-754 order, group-level computations
(app-version selection, size quantiles, submitter balances, keyword scores)
call the *same* scalar helpers once per distinct group instead of once per
slot, and the dispatch tail reports slot mutations back via ``apply`` so
later requests in a batch observe taken slots, skip bumps, and HR /
homogeneous-app-version locks exactly as under sequential execution.
``tests/test_batch_dispatch.py`` asserts assignment- and metrics-level
parity with N sequential ``handle_request`` calls.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from . import jax_backend
from .keywords import keyword_score
from .scheduler import (
    Candidate,
    Feeder,
    ScheduleRequest,
    Scheduler,
    W_BALANCE,
    W_KEYWORD,
    W_LOCALITY,
    W_PRIORITY,
    W_SIZE_MATCH,
    W_SKIPPED,
)
from .store import JobStore
from .types import (
    AppVersion,
    HRLevel,
    Host,
    InstanceState,
    Job,
    ResourceType,
    hr_class,
)


@dataclass
class _GroupChoice:
    """Resolved app-version choice for one (app, pin, hav) slot group."""

    version: Optional[AppVersion]
    usage: Dict[ResourceType, float]
    pf: float  # proj_flops(host, version)
    size_q: int  # host's size-class quantile for the app, -1 if n/a


class BatchDispatchEngine:
    """Struct-of-arrays snapshot of the feeder cache + per-host vector scoring.

    Built once per ``Scheduler.handle_batch`` call; array positions are the
    feeder's slot positions, so the scalar scan's rotated ordering (random
    start point, §5.1) is reproduced by index arithmetic. Mutations made by
    the dispatch tail are folded back in via :meth:`apply`.
    """

    def __init__(self, store: JobStore, feeder: Feeder,
                 backend: str = "numpy",
                 shard_map=None, shard: Optional[int] = None) -> None:
        self.store = store
        self.feeder = feeder
        # federated dispatch (core/shard.py): when given, the snapshot only
        # materializes the cache positions ``shard`` owns — the validity
        # mask, the per-job slot lists and the skip bookkeeping all become
        # slice-local, mirroring the scalar scan's ownership filter. Array
        # length stays the full cache size so the rotated-scan index
        # arithmetic (and the scheduler's RNG draw over ``engine.n``) is
        # unchanged.
        self.shard_map = shard_map
        self.shard = shard
        # execution backend for the dense mask/score passes; "jax" routes
        # them through core.jax_backend's staged jits (bit-identical to
        # the NumPy path — 4th parity axis), sparse tails stay host-side
        self.backend = jax_backend.resolve_backend(backend)
        # cache-content generation this snapshot was built at; the
        # scheduler's persistent-dispatch path rebuilds when it trails
        # ``feeder.version`` (dispatch-tail mutations arrive as events and
        # do not bump the generation)
        self.version = feeder.version
        slots = feeder.slots
        n = len(slots)
        self.n = n
        self.slots = list(slots)  # live CacheSlot refs, frozen positions

        self.app_names: List[str] = list(store.apps)
        self._app_index = {a: i for i, a in enumerate(self.app_names)}
        self.apps = [store.apps[a] for a in self.app_names]

        self.valid = np.zeros(n, dtype=bool)
        self.job_id = np.full(n, -1, dtype=np.int64)
        self.app_idx = np.zeros(n, dtype=np.int64)
        self.est_flop = np.zeros(n, dtype=np.float64)
        self.disk = np.zeros(n, dtype=np.float64)
        self.delay = np.zeros(n, dtype=np.float64)
        self.prio = np.zeros(n, dtype=np.float64)
        self.size_class = np.zeros(n, dtype=np.int64)
        self.target = np.full(n, -1, dtype=np.int64)
        self.pin = np.full(n, -1, dtype=np.int64)
        self.hav = np.full(n, -1, dtype=np.int64)
        self.hr_id = np.full(n, -1, dtype=np.int64)
        self.sub_idx = np.zeros(n, dtype=np.int64)
        self.kw_idx = np.zeros(n, dtype=np.int64)
        self.skips = np.zeros(n, dtype=np.float64)
        self.loc_mask = np.zeros(n, dtype=bool)  # locality app + input files
        self.input_files: List[Tuple[str, ...]] = [()] * n

        self._hr_ids: Dict[Tuple, int] = {}
        self._submitters: List[str] = []
        sub_ids: Dict[str, int] = {}
        self._kw_tuples: List[Tuple[str, ...]] = []
        kw_ids: Dict[Tuple[str, ...], int] = {}
        # job id -> ordered feeder positions still occupied by its slots
        # (taken slots included: the scalar skip lookup counts them, §6.4)
        self._job_slots: Dict[int, List[int]] = {}

        owner = shard_map.owner if shard_map is not None else None
        for i, slot in enumerate(slots):
            if slot is None:
                continue
            if owner is not None and owner[i] != shard:
                continue
            job = store.jobs.get(slot.job_id)
            if job is None:
                continue
            self._job_slots.setdefault(job.id, []).append(i)
            if slot.taken:
                continue
            inst = store.instances.get(slot.instance_id)
            if inst is None or inst.state != InstanceState.UNSENT:
                # stale slot (instance cancelled/timed out since the feeder
                # cached it): exclude it so ``valid`` is exact — the bulk
                # reject classification (cache-miss vs skip-bump) relies on
                # it. The feeder clears stale slots on every fill, so this
                # probe only matters for engines built mid-staleness.
                continue
            app = store.apps[job.app_name]
            self.valid[i] = True
            self.job_id[i] = job.id
            self.app_idx[i] = self._app_index[job.app_name]
            self.est_flop[i] = job.est_flop_count
            self.disk[i] = job.disk_bytes
            self.delay[i] = job.delay_bound
            self.prio[i] = job.priority
            self.size_class[i] = job.size_class
            if job.target_host is not None:
                self.target[i] = job.target_host
            if job.pinned_version_num is not None:
                self.pin[i] = job.pinned_version_num
            if job.hav_version_id is not None:
                self.hav[i] = job.hav_version_id
            if app.hr_level != HRLevel.NONE and job.hr_class is not None:
                self.hr_id[i] = self._intern_hr(job.hr_class)
            if job.submitter not in sub_ids:
                sub_ids[job.submitter] = len(self._submitters)
                self._submitters.append(job.submitter)
            self.sub_idx[i] = sub_ids[job.submitter]
            if job.keywords not in kw_ids:
                kw_ids[job.keywords] = len(self._kw_tuples)
                self._kw_tuples.append(job.keywords)
            self.kw_idx[i] = kw_ids[job.keywords]
            if app.uses_locality and job.input_files:
                self.loc_mask[i] = True
                self.input_files[i] = job.input_files

        # skip-bookkeeping arrays for the bulk-reject path: whether a
        # position is its job's first cached slot, and how many slots the
        # job holds (single-slot jobs — the common case — take a pure
        # array-increment fast path in bulk_skip)
        self.skip_first = np.zeros(n, dtype=bool)
        self.job_nslots = np.zeros(n, dtype=np.int64)
        for jid, positions in self._job_slots.items():
            first = slots[positions[0]]
            if first is not None:
                for p in positions:
                    self.skips[p] = first.skipped
            self.skip_first[positions[0]] = True
            for p in positions:
                self.job_nslots[p] = len(positions)

    # ------------------------------------------------------------------

    def _intern_hr(self, cls: Tuple) -> int:
        if cls not in self._hr_ids:
            self._hr_ids[cls] = len(self._hr_ids)
        return self._hr_ids[cls]

    # ------------------------------------------------------------------
    # per-host candidate generation
    # ------------------------------------------------------------------

    def candidates(
        self,
        sched: Scheduler,
        host: Host,
        req: ScheduleRequest,
        rtype: ResourceType,
        start: int,
        now: float,
    ) -> Iterator[Candidate]:
        """Vectorized equivalent of ``Scheduler._candidate_list``.

        Returns a lazy iterator of :class:`Candidate` in stable descending
        score order — identical contents and order to the scalar scan
        starting at ``start``, with ``est_rt``/``scaled_rt`` precomputed.
        """
        rows = self.candidate_rows(sched, host, req, rtype, start, now)
        if rows is None:
            return iter(())
        pos, gidx, scores, est, scaled, choices, _, _ = rows
        return self._emit(pos, gidx, scores, est, scaled, choices)

    def candidate_rows(
        self,
        sched: Scheduler,
        host: Host,
        req: ScheduleRequest,
        rtype: ResourceType,
        start: int,
        now: float,
    ):
        """The scoring pass behind :meth:`candidates`, returning the ranked
        candidate *arrays* ``(pos, group, scores, est, scaled, choices)``
        in descending-score order — the array-driven dispatch tail
        (``Scheduler._dispatch_resource_vec``) walks these directly instead
        of materializing a :class:`Candidate` per visited slot."""
        n = self.n
        if n == 0:
            return None

        # rotated scan order, then first eligible slot per job (the scalar
        # scan's seen_jobs dedupe keeps the first valid slot it encounters)
        rot = np.arange(start, start + n) % n
        if self.backend == "jax":
            elig = jax_backend.dispatch_elig(self.valid, self.target, start, host.id)
        else:
            elig = self.valid[rot] & ((self.target[rot] < 0) | (self.target[rot] == host.id))
        pos = rot[elig]
        if pos.size == 0:
            return None
        _, first = np.unique(self.job_id[pos], return_index=True)
        reps = pos[np.sort(first)]

        # group-level app-version selection: version choice depends only on
        # (app, pinned version, hav lock) for a given host/request/resource
        pin_r = self.pin[reps]
        hav_r = self.hav[reps]
        if (pin_r == -1).all() and (hav_r == -1).all():
            # common case (no pinning / hav locks): group key is the app
            # index alone — a plain 1-D unique, far cheaper than axis=0
            uniq1, gfirst, inv = np.unique(
                self.app_idx[reps], return_index=True, return_inverse=True
            )
            n_groups = len(uniq1)
        else:
            trip = np.stack([self.app_idx[reps], pin_r, hav_r], axis=1)
            uniq, gfirst, inv = np.unique(
                trip, axis=0, return_index=True, return_inverse=True
            )
            n_groups = uniq.shape[0]
        inv = inv.reshape(-1)
        choices: List[_GroupChoice] = []
        for g in range(n_groups):
            rep_pos = int(reps[gfirst[g]])
            app = self.apps[int(self.app_idx[rep_pos])]
            rep_job = self.store.jobs.get(int(self.job_id[rep_pos]))
            if rep_job is None:
                # rep job purged since the (persistent) snapshot was built:
                # fall back to any live member — the version choice depends
                # only on the group's shared (pin, hav) fields. _emit drops
                # the purged slots themselves.
                for alt in reps[inv == g]:
                    rep_job = self.store.jobs.get(int(self.job_id[int(alt)]))
                    if rep_job is not None:
                        break
                if rep_job is None:
                    choices.append(_GroupChoice(None, {}, 0.0, -1))
                    continue
            version, usage = sched._select_version(app, rep_job, host, req, rtype)
            if version is None:
                choices.append(_GroupChoice(None, {}, 0.0, -1))
                continue
            pf = sched.estimator.proj_flops(host, version)
            size_q = -1
            if app.multi_size and app.n_size_classes > 1:
                # same population computation as the scalar _score, once per
                # group instead of once per slot
                all_pf = [st.mean for st in sched.estimator.version.values() if st.n > 0]
                pop = [1.0 / m for m in all_pf if m > 0]
                size_q = sched.estimator.size_quantile(host, version, app.n_size_classes, pop)
            choices.append(_GroupChoice(version, usage, pf, size_q))
        g_ok = np.array([c.version is not None for c in choices], dtype=bool)
        g_pf = np.array([c.pf for c in choices], dtype=np.float64)
        g_q = np.array([c.size_q for c in choices], dtype=np.int64)

        # HR-class mask (§3.4): host's equivalence class per app, computed once
        host_hr = np.full(len(self.apps), -2, dtype=np.int64)
        for ai in np.unique(self.app_idx[reps]):
            app = self.apps[int(ai)]
            if app.hr_level != HRLevel.NONE:
                host_hr[ai] = self._intern_hr(hr_class(host, app.hr_level))
        hr_rep = self.hr_id[reps]
        host_hr_rep = host_hr[self.app_idx[reps]]

        # keyword score per distinct keyword set (§2.4): "no" keyword vetoes
        kw_val = np.zeros(len(self._kw_tuples), dtype=np.float64)
        kw_ok = np.ones(len(self._kw_tuples), dtype=bool)
        for t in np.unique(self.kw_idx[reps]):
            v = keyword_score(self._kw_tuples[int(t)], req.keyword_prefs)
            if v is None:
                kw_ok[t] = False
            else:
                kw_val[t] = v
        kvec_all = kw_val[self.kw_idx[reps]]
        kok = kw_ok[self.kw_idx[reps]]

        if self.backend == "jax":
            mask = jax_backend.dispatch_group_mask(g_ok[inv], hr_rep, host_hr_rep, kok)
        else:
            hr_ok = (hr_rep == -1) | (hr_rep == host_hr_rep)
            mask = g_ok[inv] & hr_ok & kok
        if not mask.any():
            return None
        r = reps[mask]
        g_r = inv[mask]

        bal_r = None
        if sched.allocator is not None:
            bal = np.zeros(len(self._submitters), dtype=np.float64)
            for s in np.unique(self.sub_idx[r]):
                bal[s] = sched.allocator.priority(self._submitters[int(s)], now)
            bal_r = bal[self.sub_idx[r]]
        pf_r = g_pf[g_r]
        res = host.resources.get(rtype)
        avail = (res.availability if res else 1.0) * host.on_fraction

        if self.backend == "jax":
            # dense base score + runtime estimates on device; the staged
            # jits reproduce the NumPy accumulation order bit-for-bit
            scores, est, scaled = jax_backend.dispatch_scores(
                kvec_all[mask], bal_r, self.prio[r], self.skips[r],
                self.est_flop[r], pf_r, avail,
                (W_KEYWORD, W_BALANCE, W_PRIORITY, W_SKIPPED),
            )
        else:
            # §6.4 weighted score sum — same IEEE op order as Scheduler._score
            scores = W_KEYWORD * kvec_all[mask]
            if bal_r is not None:
                scores += W_BALANCE * bal_r
            scores += W_PRIORITY * self.prio[r]
            scores += W_SKIPPED * np.minimum(self.skips[r], 5.0)
            # fast-check inputs, vectorized: est runtime and availability-
            # scaled runtime for the whole candidate set in two array ops
            est = np.full(r.shape, np.inf, dtype=np.float64)
            pos_pf = pf_r > 0.0
            est[pos_pf] = self.est_flop[r][pos_pf] / pf_r[pos_pf]
            if avail <= 0:
                scaled = np.full(r.shape, np.inf, dtype=np.float64)
            else:
                scaled = est / avail

        # sparse locality / size-match adjustments stay host-side on both
        # backends (set intersections per row; identical += statements)
        loc_idx = np.nonzero(self.loc_mask[r])[0]
        if loc_idx.size:
            sticky = set(req.sticky_files)
            for i in loc_idx:
                files = self.input_files[int(r[i])]
                resident = len(set(files) & sticky)
                scores[i] += W_LOCALITY * (resident / len(files))
        q_r = g_q[g_r]
        size_hit = (q_r >= 0) & (self.size_class[r] == q_r)
        if size_hit.any():
            scores[size_hit] += W_SIZE_MATCH

        order = np.argsort(-scores, kind="stable")
        pos = r[order]
        return (
            pos, g_r[order], scores[order], est[order], scaled[order],
            choices, self.disk[pos], self.delay[pos],
        )

    def _emit(
        self,
        pos: np.ndarray,
        gidx: np.ndarray,
        scores: np.ndarray,
        est: np.ndarray,
        scaled: np.ndarray,
        choices: List[_GroupChoice],
    ) -> Iterator[Candidate]:
        """Lazy top-k gather: the dispatch tail stops as soon as the request
        is satisfied, so Candidate objects are only built for visited rows."""
        jobs = self.store.jobs
        for k in range(len(pos)):
            p = int(pos[k])
            job = jobs.get(int(self.job_id[p]))
            if job is None:
                continue  # purged after snapshot build: scalar scan skips it
            choice = choices[int(gidx[k])]
            yield Candidate(
                score=float(scores[k]),
                slot=self.slots[p],
                job=job,
                version=choice.version,  # type: ignore[arg-type]
                usage=choice.usage,
                est_rt=float(est[k]),
                scaled_rt=float(scaled[k]),
                index=p,
            )

    # ------------------------------------------------------------------
    # incremental state maintenance
    # ------------------------------------------------------------------

    def apply(self, events: Sequence[Tuple[str, Candidate]]) -> None:
        """Fold dispatch-tail slot mutations back into the arrays so the next
        request in the batch scores against current state (sequential parity).
        """
        for kind, cand in events:
            p = cand.index
            if p < 0:
                continue
            if kind == "skip":
                self.apply_skip(p, cand.job, cand.slot)
            elif kind == "dispatch":
                self.apply_dispatch(p, cand.job)
            elif kind == "taken":
                self.valid[p] = False

    def apply_skip(self, p: int, job: Job, slot) -> None:
        positions = self._job_slots.get(job.id)
        if positions and positions[0] == p:
            skipped = slot.skipped
            for q in positions:
                self.skips[q] = skipped

    def bulk_skip(self, bump: np.ndarray) -> None:
        """Vectorized skip-bump for a rejected-candidate prefix: increments
        every slot's counter and folds the score-relevant ``skips`` columns
        in one array op for single-slot jobs (multi-slot jobs take the
        sibling-update path). Equivalent to ``apply_skip`` per position."""
        slots = self.slots
        for p in bump.tolist():
            slots[p].skipped += 1
        first = bump[self.skip_first[bump]]
        if len(first) == 0:
            return
        single = self.job_nslots[first] == 1
        self.skips[first[single]] += 1.0
        for p in first[~single].tolist():
            positions = self._job_slots.get(int(self.job_id[p]))
            if positions and positions[0] == p:
                skipped = slots[p].skipped
                for q in positions:
                    self.skips[q] = skipped

    def apply_dispatch(self, p: int, job: Job) -> None:
        self.valid[p] = False
        positions = self._job_slots.get(job.id)
        if positions is not None:
            # the feeder cleared this slot: it no longer counts for
            # the first-slot-of-job skip lookup
            try:
                positions.remove(p)
            except ValueError:
                pass
            self.skip_first[p] = False
            if positions:
                first = self.slots[positions[0]]
                for q in positions:
                    self.skips[q] = first.skipped if first else 0.0
                    self.job_nslots[q] = len(positions)
                self.skip_first[positions[0]] = True
        self.apply_job_locks(job)
        # HR-class / homogeneous-version locks are *job*-level state checked
        # at score time, so a dispatch on this shard must also propagate
        # them into every sibling shard's live snapshot — a stale sibling
        # mask could otherwise send the job outside its locked class before
        # the next cache-generation rebuild.
        if self.shard_map is not None:
            for sib in self.feeder._engines.values():
                if sib is not self and sib.version == self.version:
                    sib.apply_job_locks(job)

    def apply_job_locks(self, job: Job) -> None:
        """Fold ``job``'s HR-class / homogeneous-app-version locks into this
        snapshot's mask arrays (for the job's slots this snapshot holds)."""
        positions = self._job_slots.get(job.id)
        if not positions:
            return
        app = self.store.apps.get(job.app_name)
        if app is None:
            return
        if app.hr_level != HRLevel.NONE and job.hr_class is not None:
            hid = self._intern_hr(job.hr_class)
            for q in positions:
                self.hr_id[q] = hid
        if job.hav_version_id is not None:
            for q in positions:
                self.hav[q] = job.hav_version_id
