"""The BOINC client: resource scheduling and work fetch (§6.1–6.2).

Three interacting policies (Fig. 4): the server's job selection is in
``scheduler.py``; this module implements the client half:

* **Resource scheduling** (§6.1): run a *maximal feasible* set of queued
  jobs. Weighted round-robin by project scheduling priority (linear-bounded
  model), overridden by earliest-deadline-first for jobs the WRR simulation
  predicts will miss their deadlines.
* **Work fetch** (§6.2): per-resource buffer watermarks B_LO/B_HI; the WRR
  simulation yields each resource's **shortfall** and idle-instance count;
  requests go to the highest-priority project with a fetchable resource, and
  piggyback on report RPCs.

The client is driven in virtual time by ``simulator.py`` (EmBOINC-style) or
in wall time by the grid runtime.

This module is the *scalar reference oracle*: ``batch_client.py`` runs the
same WRR simulation, run-set selection, and work-fetch test for a whole
host population in fused NumPy passes, bit-exact with this path
(``tests/test_batch_client.py``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .allocation import LinearBoundedAllocator
from .backoff import ExponentialBackoff
from .scheduler import ResourceRequest
from .types import ResourceType

# ---------------------------------------------------------------------------
# Client-side job & project state
# ---------------------------------------------------------------------------


class RunState:
    UNSTARTED = "unstarted"
    RUNNING = "running"
    PREEMPTED_IN_MEMORY = "preempted_in_memory"
    PREEMPTED = "preempted"
    DONE = "done"


@dataclass
class ClientJob:
    """A job instance as the client sees it (§6.1)."""

    instance_id: int
    job_id: int
    project: str
    app_name: str
    usage: Dict[ResourceType, float]
    est_flops: float  # server estimate of program FLOPS on this host (§6.4)
    est_flop_count: float  # job size estimate (§3.3)
    deadline: float
    est_wss: float = 0.0  # RAM working set (§6.1)
    received_time: float = 0.0  # when the client got the job (reporting, §6.2)
    fraction_done: float = 0.0
    fraction_done_exact: bool = False
    runtime: float = 0.0  # scaled runtime so far
    state: str = RunState.UNSTARTED
    checkpoint_time: float = 0.0  # last checkpoint (virtual time)
    slice_start: float = 0.0
    non_cpu_intensive: bool = False
    deadline_miss: bool = False  # set by WRR simulation

    def static_estimate(self) -> float:
        """Whole-job runtime from size / speed (§6.1)."""
        if self.est_flops <= 0:
            return float("inf")
        return self.est_flop_count / self.est_flops

    def remaining_estimate(self) -> float:
        """Blend static & dynamic estimates by fraction done (§6.1)."""
        static_total = self.static_estimate()
        fd = float(self.fraction_done)
        if fd <= 0.0:
            return static_total
        dynamic_total = self.runtime / fd
        if self.fraction_done_exact:
            total = dynamic_total
        else:
            total = fd * dynamic_total + (1.0 - fd) * static_total
        return max(0.0, total - self.runtime)

    @property
    def uses_gpu(self) -> bool:
        return any(
            r in (ResourceType.GPU, ResourceType.TPU) and u > 0
            for r, u in self.usage.items()
        )

    def cpu_usage(self) -> float:
        return self.usage.get(ResourceType.CPU, 0.0)


@dataclass
class ClientResource:
    rtype: ResourceType
    ninstances: int
    peak_flops: float
    availability: float = 1.0  # measured long-term availability (§6)


@dataclass
class ProjectAttachment:
    name: str
    resource_share: float = 100.0
    suspended: bool = False
    # which resource types the project has app versions for (affects fetchable)
    resource_types: Tuple[ResourceType, ...] = (ResourceType.CPU,)
    backoff: Dict[ResourceType, ExponentialBackoff] = field(default_factory=dict)
    dont_request_work: bool = False
    report_immediately: bool = False

    def backoff_for(self, rtype: ResourceType) -> ExponentialBackoff:
        if rtype not in self.backoff:
            self.backoff[rtype] = ExponentialBackoff()
        return self.backoff[rtype]


@dataclass
class ClientPrefs:
    buffer_lo_days: float = 0.1  # B_LO (§6.2)
    buffer_hi_days: float = 0.5  # B_HI
    cpu_throttle: float = 1.0  # duty cycle (§2.4); 1.0 = no throttling
    ram_limit_fraction: float = 0.9
    time_slice: float = 3600.0  # §6.1 default 1 hour

    @property
    def b_lo(self) -> float:
        return self.buffer_lo_days * 86400.0

    @property
    def b_hi(self) -> float:
        return self.buffer_hi_days * 86400.0


# ---------------------------------------------------------------------------
# WRR simulation (§6.1, Fig. 5)
# ---------------------------------------------------------------------------


@dataclass
class WRRResult:
    deadline_misses: List[int]  # instance_ids predicted to miss under WRR
    shortfall: Dict[ResourceType, float]
    idle_instances: Dict[ResourceType, float]
    queue_dur: Dict[ResourceType, float]
    saturated_until: Dict[ResourceType, float]


def wrr_simulate(
    jobs: Sequence[ClientJob],
    resources: Dict[ResourceType, ClientResource],
    project_priority: Dict[str, float],
    prefs: ClientPrefs,
    now: float,
    ram_bytes: float = float("inf"),
) -> WRRResult:
    """Simulate WRR execution of the queue to predict deadline misses and
    per-resource shortfall (fluid-instance approximation of Fig. 5)."""
    remaining = {j.instance_id: max(j.remaining_estimate(), 1e-9) for j in jobs if j.state != RunState.DONE}
    horizon = prefs.b_hi
    # fluid busy-time accounting per resource
    busy: Dict[ResourceType, float] = {r: 0.0 for r in resources}
    queue_dur: Dict[ResourceType, float] = {r: 0.0 for r in resources}
    misses: List[int] = []
    # WRR ordering: projects by priority, FIFO inside a project (queue order
    # — NOT deadline order: the simulation must mirror what WRR would
    # actually run so that deadline misses are predicted correctly)
    live = [j for j in jobs if j.state != RunState.DONE]
    order = sorted(
        range(len(live)),
        key=lambda i: (-project_priority.get(live[i].project, 0.0), i),
    )
    order = [live[i] for i in order]
    for j in order:
        for r, u in j.usage.items():
            if r in queue_dur and u > 0:
                queue_dur[r] += remaining[j.instance_id]

    t = 0.0
    pending = list(order)
    max_events = 10_000
    for _ in range(max_events):
        if not pending:
            break
        # greedy maximal set in WRR order (§6.1)
        cap = {r: float(res.ninstances) for r, res in resources.items()}
        ram_left = ram_bytes
        running: List[ClientJob] = []
        for j in pending:
            ok = all(cap.get(r, 0.0) >= u - 1e-12 for r, u in j.usage.items() if u > 0)
            if ok and j.est_wss <= ram_left:
                for r, u in j.usage.items():
                    if r in cap:
                        cap[r] -= u
                ram_left -= j.est_wss
                running.append(j)
        if not running:
            break
        dt = min(remaining[j.instance_id] for j in running)
        dt = max(dt, 1e-9)
        for r, res in resources.items():
            used = sum(j.usage.get(r, 0.0) for j in running)
            # only count busy time inside the horizon for shortfall
            within = max(0.0, min(horizon - t, dt))
            busy[r] += min(used, res.ninstances) * within
        t += dt
        done_now = []
        for j in running:
            remaining[j.instance_id] -= dt
            if remaining[j.instance_id] <= 1e-9:
                done_now.append(j)
                if now + t > j.deadline:
                    misses.append(j.instance_id)
        if done_now:
            # drop by instance id: O(pending) per event, and immune to
            # dataclass __eq__ conflating distinct jobs with equal fields
            done_ids = {j.instance_id for j in done_now}
            pending = [j for j in pending if j.instance_id not in done_ids]

    # any jobs never scheduled (infeasible) count as misses
    for j in pending:
        if now + t + remaining[j.instance_id] > j.deadline:
            if j.instance_id not in misses:
                misses.append(j.instance_id)

    shortfall: Dict[ResourceType, float] = {}
    idle: Dict[ResourceType, float] = {}
    saturated: Dict[ResourceType, float] = {}
    for r, res in resources.items():
        cap_time = horizon * res.ninstances
        shortfall[r] = max(0.0, cap_time - busy[r])
        # idle right now: instances unused by the first running set
        used0 = 0.0
        cap0 = {rr: float(rs.ninstances) for rr, rs in resources.items()}
        ram_left = ram_bytes
        for j in order:
            ok = all(cap0.get(rr, 0.0) >= u - 1e-12 for rr, u in j.usage.items() if u > 0)
            if ok and j.est_wss <= ram_left:
                for rr, u in j.usage.items():
                    if rr in cap0:
                        cap0[rr] -= u
                ram_left -= j.est_wss
        idle[r] = max(0.0, cap0.get(r, 0.0))
        saturated[r] = busy[r] / max(res.ninstances, 1)
    return WRRResult(misses, shortfall, idle, queue_dur, saturated)


# ---------------------------------------------------------------------------
# The client
# ---------------------------------------------------------------------------


@dataclass
class WorkRequest:
    project: str
    requests: Dict[ResourceType, ResourceRequest]


@dataclass
class Client:
    host_id: int
    resources: Dict[ResourceType, ClientResource]
    prefs: ClientPrefs = field(default_factory=ClientPrefs)
    projects: Dict[str, ProjectAttachment] = field(default_factory=dict)
    jobs: List[ClientJob] = field(default_factory=list)
    ram_bytes: float = 8e9
    rec: LinearBoundedAllocator = field(default_factory=lambda: LinearBoundedAllocator(default_cap=86400.0 * 10))
    completed: List[ClientJob] = field(default_factory=list)
    reported_pending: List[ClientJob] = field(default_factory=list)
    running: List[ClientJob] = field(default_factory=list)
    n_usable_cpus: int = 0

    # -- attachment --

    def attach(self, project: ProjectAttachment, now: float = 0.0) -> None:
        self.projects[project.name] = project
        self._resplit_shares(now)

    def detach(self, name: str, now: float = 0.0) -> None:
        """Account-manager-driven detach (§2.3): abandon that project's jobs
        and purge every trace of it — queued/running jobs, unreported and
        reported-pending results, and its REC allocator account (leaving the
        row would keep accruing balance and skew the remaining projects'
        relative priorities)."""
        self.projects.pop(name, None)
        self.jobs = [j for j in self.jobs if j.project != name]
        self.running = [j for j in self.running if j.project != name]
        self.completed = [j for j in self.completed if j.project != name]
        self.reported_pending = [j for j in self.reported_pending if j.project != name]
        self.rec.accounts.pop(name, None)
        self._resplit_shares(now)

    def _resplit_shares(self, now: float) -> None:
        """Priority accrues with resource share (linear-bounded, §6.1): the
        attached projects split the total share between them."""
        total_share = sum(p.resource_share for p in self.projects.values())
        for name, p in self.projects.items():
            self.rec.ensure(name, now).rate = p.resource_share / max(total_share, 1e-9)

    def project_priorities(self, now: float) -> Dict[str, float]:
        return {name: self.rec.priority(name, now) for name in self.projects}

    # -- resource scheduling (§6.1) --

    def schedule(self, now: float) -> List[ClientJob]:
        """Choose and return the set of jobs to run (maximal feasible).

        Decomposed so the vectorized population engine
        (``batch_client.BatchClientEngine``) can reuse the mutation steps:
        WRR miss prediction → ``_select_run_set`` (ordering + greedy) →
        ``_apply_run_set`` (run/preempt transitions).
        """
        queued = [j for j in self.jobs if j.state != RunState.DONE]
        if not queued:
            self.running = []
            return []
        prio = self.project_priorities(now)
        sim = wrr_simulate(queued, self.resources, prio, self.prefs, now, self.ram_bytes)
        self._set_miss_flags(queued, set(sim.deadline_misses))
        chosen = self._select_run_set(queued, prio, now)
        return self._apply_run_set(chosen, now)

    # class attr (not a dataclass field): True forces the first sweep, after
    # which it tracks whether any queued job carries a deadline-miss flag
    _any_miss_flags = True

    def _set_miss_flags(self, queued: Sequence[ClientJob], miss_set: set) -> None:
        if not miss_set and not self._any_miss_flags:
            return  # no predicted misses and every flag already False
        any_f = False
        for j in queued:
            f = j.instance_id in miss_set
            j.deadline_miss = f
            any_f = any_f or f
        self._any_miss_flags = any_f

    def _select_run_set(
        self, queued: Sequence[ClientJob], prio: Dict[str, float], now: float
    ) -> List[ClientJob]:
        """§6.1 ordering + greedy maximal feasible set (scalar reference)."""

        def order_key(j: ClientJob):
            in_slice = j.state == RunState.RUNNING and (now - j.slice_start) < self.prefs.time_slice
            unchk = j.state == RunState.RUNNING and j.checkpoint_time <= j.slice_start
            return (
                0 if j.deadline_miss else 1,  # (a) EDF for misses
                j.deadline if j.deadline_miss else 0.0,
                0 if j.uses_gpu else 1,  # (b) GPU before CPU
                0 if (in_slice or unchk) else 1,  # (c) mid-slice / not checkpointed
                -j.cpu_usage(),  # (d) more CPUs first
                -prio.get(j.project, 0.0),  # (e) project priority
            )

        ordered = sorted(queued, key=order_key)
        # greedy maximal feasible set (§6.1 definition)
        ncpu = self.n_usable_cpus or self.resources.get(
            ResourceType.CPU, ClientResource(ResourceType.CPU, 1, 1e9)
        ).ninstances
        cap = {r: float(res.ninstances) for r, res in self.resources.items()}
        cpu_sum_cpu_jobs = 0.0
        cpu_sum_all = 0.0
        ram_left = self.ram_bytes * self.prefs.ram_limit_fraction
        chosen: List[ClientJob] = []
        for j in ordered:
            cu = j.cpu_usage()
            feasible = True
            for r, u in j.usage.items():
                if r == ResourceType.CPU:
                    continue
                if cap.get(r, 0.0) < u - 1e-12:
                    feasible = False
            if not j.uses_gpu and cpu_sum_cpu_jobs + cu > ncpu + 1e-12:
                feasible = False
            if cpu_sum_all + cu > ncpu + 1 + 1e-12:
                feasible = False
            if j.est_wss > ram_left:
                feasible = False
            if j.non_cpu_intensive:
                feasible = True  # always run non-CPU-intensive apps (§3.5)
            if not feasible:
                continue
            for r, u in j.usage.items():
                if r != ResourceType.CPU and r in cap:
                    cap[r] -= u
            if not j.uses_gpu:
                cpu_sum_cpu_jobs += cu
            cpu_sum_all += cu
            ram_left -= j.est_wss
            chosen.append(j)
        return chosen

    def _apply_run_set(self, chosen: List[ClientJob], now: float) -> List[ClientJob]:
        """Apply run/preempt transitions for a computed run set."""
        chosen_ids = {j.instance_id for j in chosen}
        for j in self.running:
            if j.instance_id not in chosen_ids and j.state == RunState.RUNNING:
                # preempt; keep in memory if RAM allows (modelled simply)
                j.state = RunState.PREEMPTED
        for j in chosen:
            if j.state != RunState.RUNNING:
                j.state = RunState.RUNNING
                j.slice_start = now
        self.running = chosen
        return chosen

    # -- execution accounting (driven by the simulator / runtime) --

    def debit_usage(self, job: ClientJob, dt: float, now: float) -> None:
        """Charge ``dt`` seconds of *executed* time on ``job`` to its
        project's REC account (§6.1) — the accounting formula shared by
        ``advance`` (which passes throttle-scaled time, §2.4) and the
        simulator's execution path (which runs jobs at full speed and so
        passes raw dt)."""
        self.rec.debit(job.project, dt * max(sum(job.usage.values()), 1.0), now)

    def advance(self, dt: float, now: float) -> List[ClientJob]:
        """Advance running jobs by scaled time ``dt``; returns completions."""
        done: List[ClientJob] = []
        for j in self.running:
            if j.state != RunState.RUNNING:
                continue
            eff_dt = dt * self.prefs.cpu_throttle  # CPU throttling (§2.4)
            j.runtime += eff_dt
            total = j.static_estimate()
            if total <= 0 or math.isinf(total):
                continue
            j.fraction_done = min(1.0, j.runtime / total)
            self.debit_usage(j, eff_dt, now)
            if j.fraction_done >= 1.0:
                j.state = RunState.DONE
                done.append(j)
        if done:
            done_ids = {j.instance_id for j in done}
            self.jobs = [j for j in self.jobs if j.instance_id not in done_ids]
            self.running = [j for j in self.running if j.instance_id not in done_ids]
            self.completed.extend(done)
        return done

    def checkpoint_tick(self, now: float, period: float = 300.0) -> None:
        """Client asks running apps to checkpoint every few minutes (§3.6)."""
        for j in self.running:
            if now - j.checkpoint_time >= period:
                j.checkpoint_time = now

    # -- work fetch (§6.2) --

    def needs_work(
        self, now: float, sim: Optional[WRRResult] = None
    ) -> Dict[ResourceType, ResourceRequest]:
        if sim is None:
            queued = [j for j in self.jobs if j.state != RunState.DONE]
            prio = self.project_priorities(now)
            sim = wrr_simulate(queued, self.resources, prio, self.prefs, now, self.ram_bytes)
        return self._requests_from_sim(sim)

    def _requests_from_sim(self, sim: WRRResult) -> Dict[ResourceType, ResourceRequest]:
        """Buffer-watermark test (§6.2) over a WRR simulation result."""
        out: Dict[ResourceType, ResourceRequest] = {}
        for r, res in self.resources.items():
            needs = sim.saturated_until.get(r, 0.0) < self.prefs.b_lo
            if needs:
                out[r] = ResourceRequest(
                    req_runtime=sim.shortfall.get(r, 0.0),
                    req_idle=sim.idle_instances.get(r, 0.0),
                    queue_dur=sim.queue_dur.get(r, 0.0),
                )
        return out

    def fetchable(self, project: ProjectAttachment, rtype: ResourceType, now: float) -> bool:
        if project.suspended or project.dont_request_work:
            return False
        if rtype not in project.resource_types:
            return False
        if not project.backoff_for(rtype).ready(now):
            return False
        return True

    def choose_fetch_project(
        self, now: float, needs: Optional[Dict[ResourceType, ResourceRequest]] = None
    ) -> Optional[WorkRequest]:
        """The work-fetch policy (§6.2): highest-priority project with a
        fetchable resource that needs replenishment. ``needs`` may be
        precomputed (the batched engine runs one fused WRR pass per tick)."""
        if needs is None:
            needs = self.needs_work(now)
        if not needs:
            return None
        prio = self.project_priorities(now)
        for name in sorted(self.projects, key=lambda n: -prio.get(n, 0.0)):
            p = self.projects[name]
            if any(self.fetchable(p, r, now) for r in needs):
                reqs = {
                    r: rr
                    for r, rr in needs.items()
                    if self.fetchable(p, r, now)
                }
                if reqs:
                    return WorkRequest(project=name, requests=reqs)
        return None

    def piggyback_request(
        self,
        project: str,
        now: float,
        needs: Optional[Dict[ResourceType, ResourceRequest]] = None,
    ) -> Dict[ResourceType, ResourceRequest]:
        """When RPCing ``project`` for other reasons, attach a work request
        for each resource where it is the top fetchable project (§6.2)."""
        if needs is None:
            needs = self.needs_work(now)
        out: Dict[ResourceType, ResourceRequest] = {}
        prio = self.project_priorities(now)
        p = self.projects.get(project)
        if p is None:
            return out
        ranked = sorted(self.projects, key=lambda n: -prio.get(n, 0.0))
        for r, rr in needs.items():
            top = next((n for n in ranked if self.fetchable(self.projects[n], r, now)), None)
            if top == project:
                out[r] = rr
        return out

    # -- reporting policy (§6.2) --

    def should_report(self, project: str, now: float, batch_threshold: int = 4) -> bool:
        pend = [j for j in self.completed if j.project == project]
        if not pend:
            return False
        p = self.projects.get(project)
        if p is not None and p.report_immediately:
            return True
        if len(pend) >= batch_threshold:
            return True
        # report when a deadline approaches (§6.2). The window is *relative*
        # to the job's own deadline allowance (deadline - received_time):
        # comparing against 0.1 × the absolute virtual-time deadline made
        # every completion report immediately once now grew past ~90% of the
        # deadline value, silently defeating report batching in long runs.
        soonest = min(pend, key=lambda j: j.deadline)
        window = max(3600.0, 0.1 * max(soonest.deadline - soonest.received_time, 0.0))
        return now >= soonest.deadline - window

    def take_completed(self, project: str) -> List[ClientJob]:
        out = [j for j in self.completed if j.project == project]
        self.completed = [j for j in self.completed if j.project != project]
        return out

    # -- account-manager support (§2.3) --

    def apply_am_reply(self, attach: Sequence[ProjectAttachment], detach: Sequence[str], now: float = 0.0) -> None:
        for name in detach:
            self.detach(name, now)
        for p in attach:
            if p.name not in self.projects:
                self.attach(p, now)
