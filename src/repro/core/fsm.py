"""The transitioner: the job-lifecycle finite-state machine (§4, §5.1).

"Viewing the progress of a job as a finite-state machine, this handles the
transitions. The events that trigger transitions come from potentially
concurrent processes like schedulers and validators. Instead of handling the
transitions, these programs set a flag in the job's database record. The
transitioner enumerates these records and processes them. This eliminates
the need for concurrency control of DB access."

Responsibilities per job (§4):
  * create the initial ``init_ninstances`` instances;
  * on deadline pass, mark instances NO_REPLY and create replacements;
  * trigger validation at quorum; designate the canonical instance;
  * grant credit (via the credit system) to valid instances;
  * cancel unsent instances once a canonical instance exists;
  * enforce max_error_instances / max_success_instances;
  * mark jobs for assimilation/file-deletion/purge.

Two implementations drive the validate pass:

  * **scalar oracle** (``batch_validate=False``): per-job Python —
    ``check_set`` pairwise comparator grouping, immediate per-instance
    credit/reputation updates. Faithful and simple; the parity reference.
  * **batch engine** (``batch_validate=True``, the default): a
    :class:`~repro.core.batch_validate.BatchValidationEngine` pre-pass
    computes per-job counts, payload digests, and quorum decisions for the
    whole tick in fused array passes; the per-job loop applies them, and
    credit/reputation flush once at end of tick (ordered, so granted
    credit is bit-equal to the oracle). See ``core/batch_validate.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from .adaptive import AdaptiveReplication
from .credit import CreditSystem
from .defense import DefenseLayer
from .store import JobStore
from .types import (
    App,
    InstanceOutcome,
    InstanceState,
    Job,
    JobInstance,
    JobState,
    ValidateState,
)
from .validator import check_set, validate_against_canonical


@dataclass
class TransitionerMetrics:
    timeouts: int = 0
    retries_created: int = 0
    jobs_validated: int = 0
    jobs_failed: int = 0
    instances_cancelled: int = 0
    credit_granted: float = 0.0


@dataclass
class Transitioner:
    """Drives job state transitions against a JobStore (§5.1).

    ``instance``/``n_instances`` implement ID-space daemon sharding.
    """

    store: JobStore
    credit: Optional[CreditSystem] = None
    adaptive: Optional[AdaptiveReplication] = None
    instance: int = 0
    n_instances: int = 1
    batch_validate: bool = True
    # execution backend handed to BatchValidationEngine ("numpy" | "jax");
    # "jax" routes homogeneous tensor payload digests through the
    # kernels/quorum_compare Pallas kernel
    engine_backend: str = "numpy"
    # defense layer (§3.4): validation outcomes feed its agreement stats +
    # per-(host, version) quota table. Scalar path calls it inline; batch
    # path defers the identical (valid, invalid) pair lists through
    # ``ValidationPlan.defense_events`` and replays them in finalize order.
    defense: Optional[DefenseLayer] = None
    metrics: TransitionerMetrics = field(default_factory=TransitionerMetrics)
    _engine: object = field(default=None, repr=False)
    # tick-start snapshot of the defense suspicion clusters (host -> cluster
    # id). Quorum decisions consult the snapshot — not live cluster state —
    # so the scalar loop (which feeds the defense layer mid-tick) and the
    # batch engine (which defers the feed to finalize) decide identically.
    _sus_clusters: Dict[int, int] = field(default_factory=dict, repr=False)

    # ------------------------------------------------------------------

    def tick(self, now: float) -> int:
        """One daemon pass: handle deadline misses, then flagged jobs.

        Both passes enumerate the store's indexes (deadline heap, pending
        queue) so the cost is O(work to do), not O(table size); with
        ``store.use_indexes=False`` they fall back to the oracle scans.
        With ``batch_validate`` the flagged-job pass is preceded by the
        engine's fused pre-pass and followed by the credit/reputation
        flush.

        Returns the number of jobs transitioned.
        """
        self._sus_clusters = (
            self.defense.clusters() if self.defense is not None else {}
        )
        self._check_deadlines(now)
        pending = self.store.pending_transitions(self.instance, self.n_instances)
        plan = None
        if self.batch_validate and pending:
            if self._engine is None:
                from .batch_validate import BatchValidationEngine

                self._engine = BatchValidationEngine(
                    self.store, backend=self.engine_backend
                )
            plan = self._engine.prepare(
                pending, now, self.instance, self.n_instances,
                clusters=self._sus_clusters,
            )
        n = 0
        if plan is not None:
            from .batch_validate import DECIDED

            # flag clears, validate-state writes, job completions, and
            # credit/reputation events are deferred into fused bulk passes;
            # the per-job loop applies decisions (the common fully-decided
            # job inline) and tops up instances — order-identical to
            # scalar, since nothing in the loop reads another job's
            # deferred state
            self.store.clear_transition_flags(pending)
            decisions = plan.decisions
            n_error = plan.n_error
            n_succ = plan.n_succ
            metrics = self.metrics
            adaptive = self.adaptive
            credit = self.credit
            apps = self.store.apps
            valid_bulk = plan.valid_bulk
            invalid_bulk = plan.invalid_bulk
            finish = plan.finish
            adp_h = plan.adp_h
            adp_v = plan.adp_v
            adp_ok = plan.adp_ok
            err_out = plan.err_outcome
            credit_entries = plan.credit_entries
            peers_cache = plan.peers_cache
            for pos, job in enumerate(pending):
                dec = decisions[pos]
                if (
                    dec is not None
                    and dec[0] is DECIDED
                    and n_error[pos] <= job.max_error_instances
                ):
                    # common case inlined: queue the decided job's deferred
                    # effects (same order/content as _queue_event)
                    _, canonical, valid, invalid = dec
                    valid_bulk.extend(valid)
                    if invalid:
                        invalid_bulk.extend(invalid)
                    finish.append((job, canonical.id))
                    metrics.jobs_validated += 1
                    if adaptive is not None:
                        if n_succ[pos] >= 2:
                            for i in valid:
                                if i.host_id is not None and i.app_version_id is not None:
                                    adp_h.append(i.host_id)
                                    adp_v.append(i.app_version_id)
                                    adp_ok.append(True)
                        for i in invalid:
                            if i.host_id is not None and i.app_version_id is not None:
                                adp_h.append(i.host_id)
                                adp_v.append(i.app_version_id)
                                adp_ok.append(False)
                            err_out.append(i)
                    if self.defense is not None:
                        plan.defense_events.append((
                            [(i.host_id, i.app_version_id) for i in valid
                             if i.host_id is not None and i.app_version_id is not None],
                            [(i.host_id, i.app_version_id) for i in invalid
                             if i.host_id is not None and i.app_version_id is not None],
                        ))
                    if credit is not None and valid:
                        peers = peers_cache.get(job.app_name)
                        if peers is None:
                            peers = peers_cache[job.app_name] = [
                                v.id for v in apps[job.app_name].latest_versions()
                            ]
                        credit_entries.append((job, valid, peers))
                else:
                    self._transition(job, now, plan, pos)
                n += 1
            self._finalize_plan(plan, now)
        else:
            for job in pending:
                job.transition_flag = False
                self._transition(job, now)
                n += 1
        if self.defense is not None:
            # enforcement sweep: abort clustered in-flight co-placements and
            # unpin HR-stuck retries. After the finalize / scalar loop above
            # both validation engines hold identical store state, so the
            # sweep's decisions are engine-identical.
            self.defense.tick_sweep(now, self.instance, self.n_instances)
        return n

    # ------------------------------------------------------------------

    def _check_deadlines(self, now: float) -> None:
        """Instances past deadline are assumed lost (§4).

        Deadline handling is sharded by ``job_id % n_instances`` like the
        flagged-job pass — each transitioner instance mutates only its own
        ID-space shard (§5.1).
        """
        for inst in self.store.expired_instances(now, self.instance, self.n_instances):
            inst.state = InstanceState.OVER
            inst.outcome = InstanceOutcome.NO_REPLY
            self.metrics.timeouts += 1
            job = self.store.jobs.get(inst.job_id)
            if job is not None:
                job.transition_flag = True
            if self.adaptive is not None and inst.host_id is not None \
                    and inst.app_version_id is not None:
                self.adaptive.on_invalid(inst.host_id, inst.app_version_id)
            if self.defense is not None and inst.host_id is not None \
                    and inst.app_version_id is not None:
                self.defense.on_error(inst.host_id, inst.app_version_id, now)

    # ------------------------------------------------------------------

    def _transition(self, job: Job, now: float, plan=None, pos: int = 0) -> None:
        app = self.store.apps[job.app_name]
        if plan is not None:
            n_outstanding = int(plan.n_outstanding[pos])
            successes = plan.successes(pos)
            n_error = int(plan.n_error[pos])
            n_total = int(plan.n_total[pos])
        else:
            insts = self.store.job_instances(job.id)
            n_outstanding = sum(1 for i in insts if i.is_outstanding())
            successes = [
                i
                for i in insts
                if i.state == InstanceState.OVER and i.outcome == InstanceOutcome.SUCCESS
            ]
            n_error = sum(
                1
                for i in insts
                if i.state == InstanceState.OVER
                and i.outcome
                in (
                    InstanceOutcome.CLIENT_ERROR,
                    InstanceOutcome.NO_REPLY,
                    InstanceOutcome.ABANDONED,
                    InstanceOutcome.VALIDATE_ERROR,
                )
            )
            n_total = len(insts)

        # -- failure limits (§4) --
        if n_error > job.max_error_instances:
            self._fail_job(job, "too many errored instances")
            return

        # -- validation (§4) --
        if job.canonical_instance_id is None:
            if plan is not None:
                has_fresh = bool(plan.fresh[pos])
            else:
                has_fresh = any(
                    s.validate_state == ValidateState.INIT for s in successes
                )
            quorum = self._required_quorum(job)
            if len(successes) >= quorum and has_fresh:
                if plan is not None:
                    if self._apply_decision(job, app, successes, now, plan, pos):
                        return  # decided: completion deferred to finalize
                else:
                    self._validate(job, app, successes, now)
                if job.state != JobState.ACTIVE:
                    return
            if job.canonical_instance_id is None and len(successes) > job.max_success_instances:
                self._fail_job(job, "too many successes without consensus")
                return
        else:
            # late-arriving successes validate against the canonical (§4)
            canonical = self.store.instances.get(job.canonical_instance_id)
            if canonical is not None:
                self._validate_stragglers(
                    job, app, canonical, successes, now, plan, pos
                )

        if job.state != JobState.ACTIVE:
            return

        # -- instance top-up (§4) --
        if job.canonical_instance_id is None:
            target = self._target_instances(job, n_total)
            # Count outstanding plus the largest mutually-agreeing group of
            # successes: "if the outputs agree, they are accepted ...
            # otherwise a third instance is created and run" (§3.4). Two
            # disagreeing successes contribute 1, forcing a tie-breaker.
            clusters = self._sus_clusters
            if clusters and self._has_cluster_pair(successes, clusters):
                # same-cluster successes count as one vote (work-spreading):
                # force the scalar group scan so the top-up sees the reduced
                # effective agreement and issues the tie-breaking replica
                agree = self._largest_agreeing_group(app, successes, clusters)
            elif plan is not None:
                agree = plan.largest_agreeing_group(pos, app, successes)
            else:
                agree = self._largest_agreeing_group(app, successes)
            live = n_outstanding + agree
            total_created = n_total
            while live < target:
                # cap total instance creation to avoid unbounded retry loops
                if total_created >= job.max_error_instances + job.max_success_instances + 1:
                    break
                self.store.create_instance(job)
                if total_created >= job.init_ninstances:
                    self.metrics.retries_created += 1
                live += 1
                total_created += 1
        else:
            # canonical exists: cancel unsent instances (§4)
            if plan is not None:
                unsent = plan.unsent(pos)
            else:
                unsent = [i for i in insts if i.state == InstanceState.UNSENT]
            for i in unsent:
                if i.state == InstanceState.UNSENT:
                    i.state = InstanceState.OVER
                    i.outcome = InstanceOutcome.CANCELLED
                    self.metrics.instances_cancelled += 1

    # ------------------------------------------------------------------

    def _required_quorum(self, job: Job) -> int:
        """Adaptive replication (§3.4): unreplicated jobs have quorum 1."""
        return job.min_quorum

    def _target_instances(self, job: Job, n_total: int) -> int:
        if n_total == 0:
            return job.init_ninstances
        return job.min_quorum

    @staticmethod
    def _has_cluster_pair(
        successes: List[JobInstance], clusters: Dict[int, int]
    ) -> bool:
        """Do two successes come from hosts of the same suspicion cluster?"""
        seen: set = set()
        for s in successes:
            cl = clusters.get(s.host_id) if s.host_id is not None else None
            if cl is not None:
                if cl in seen:
                    return True
                seen.add(cl)
        return False

    @staticmethod
    def _largest_agreeing_group(
        app: App,
        successes: List[JobInstance],
        clusters: Optional[Dict[int, int]] = None,
    ) -> int:
        from .validator import bitwise_equal, effective_quorum_size

        viable = [s for s in successes if s.validate_state != ValidateState.INVALID]
        if len(viable) <= 1:
            return len(viable)
        cmp = app.comparator or bitwise_equal
        groups: List[List[JobInstance]] = []
        for inst in viable:
            for g in groups:
                if cmp(g[0].output, inst.output):
                    g.append(inst)
                    break
            else:
                groups.append([inst])
        if clusters:
            return max(effective_quorum_size(g, clusters) for g in groups)
        return max(len(g) for g in groups)

    # ------------------------------------------------------------------

    def _validate(self, job: Job, app: App, successes: List[JobInstance],
                  now: float, plan=None) -> None:
        result = check_set(
            successes, app.comparator, self._required_quorum(job),
            clusters=self._sus_clusters,
        )
        if result.canonical is None:
            return  # inconclusive; transitioner will top up instances
        job.canonical_instance_id = result.canonical.id
        self.metrics.jobs_validated += 1
        self._post_validation_updates(
            job, app, result.valid, result.invalid, now,
            by_replication=len(successes) >= 2, plan=plan,
        )
        job.state = JobState.SUCCESS
        job.transition_flag = True

    def _apply_decision(self, job: Job, app: App, successes: List[JobInstance],
                        now: float, plan, pos: int) -> bool:
        """Engine counterpart of :meth:`_validate`: consume the plan's
        precomputed quorum decision (digest grouping) for this job.

        Returns True when the job was decided — its SUCCESS completion and
        validate-state writes are queued for the fused finalize pass and
        the caller must stop transitioning it (scalar control-flow parity:
        ``_validate`` would have left it non-ACTIVE).
        """
        from .batch_validate import INCONCLUSIVE

        dec = plan.decisions[pos]
        if dec is not None and dec[0] is INCONCLUSIVE:
            # deferred: nothing later in this job's transition distinguishes
            # INIT from INCONCLUSIVE (top-up only excludes INVALID)
            plan.inconclusive_bulk.extend(successes)
            return False
        # DECIDED jobs are consumed by tick()'s inline fast path (its gate
        # is the exact complement of _transition's error-limit check, so a
        # DECIDED decision cannot reach here); everything else — no
        # precomputed decision, or a comparator/payload that isn't
        # digestable — runs the scalar oracle, with credit/reputation still
        # deferred through the plan so the tick-wide event order matches
        # sequential processing
        self._validate(job, app, successes, now, plan=plan)
        return job.state != JobState.ACTIVE

    def _validate_stragglers(self, job: Job, app: App, canonical: JobInstance,
                             successes: List[JobInstance], now: float,
                             plan, pos: int) -> None:
        """Late successes reported after the canonical exists (§4)."""
        digs = plan.digests(pos) if plan is not None else None
        canon_dig = None
        if digs is not None:
            for k, s in enumerate(successes):
                if s.id == canonical.id:
                    canon_dig = digs[k]
                    break
        for k, s in enumerate(successes):
            if s.id == canonical.id or s.validate_state != ValidateState.INIT:
                continue
            if canon_dig is not None:
                ok = bool(digs[k] == canon_dig)
                (plan.valid_bulk if ok else plan.invalid_bulk).append(s)
            else:
                ok = validate_against_canonical(s, canonical, app.comparator)
            self._post_validation_updates(
                job, app, [s] if ok else [], [] if ok else [s], now,
                by_replication=True, plan=plan,
            )

    def _post_validation_updates(
        self,
        job: Job,
        app: App,
        valid: List[JobInstance],
        invalid: List[JobInstance],
        now: float,
        by_replication: bool = True,
        plan=None,
    ) -> None:
        if plan is not None:
            # engine mode: defer to the fused end-of-tick flush, preserving
            # the per-job event order the scalar loop would have produced
            self._queue_event(plan, job, valid, invalid, by_replication)
            return
        # adaptive-replication reputation (§3.4): N counts only jobs
        # "validated by replication" — trusted singletons don't build it.
        if self.adaptive is not None:
            if by_replication:
                for i in valid:
                    if i.host_id is not None and i.app_version_id is not None:
                        self.adaptive.on_validated(i.host_id, i.app_version_id)
            for i in invalid:
                if i.host_id is not None and i.app_version_id is not None:
                    self.adaptive.on_invalid(i.host_id, i.app_version_id)
                i.outcome = InstanceOutcome.VALIDATE_ERROR

        # defense layer (§3.4): one finalized decision's outcome pairs feed
        # the agreement stats + quota table (valids unconditionally — the
        # by_replication gate is adaptive-reputation-specific)
        if self.defense is not None:
            self.defense.on_validation(
                [(i.host_id, i.app_version_id) for i in valid
                 if i.host_id is not None and i.app_version_id is not None],
                [(i.host_id, i.app_version_id) for i in invalid
                 if i.host_id is not None and i.app_version_id is not None],
                now,
            )

        # credit (§7): grant the outlier-robust average to all valid instances
        if self.credit is not None and valid:
            peer_vids = [v.id for v in self.store.apps[job.app_name].latest_versions()]
            claims = []
            for i in valid:
                self.credit.record(i, job)
                i.claimed_credit = self.credit.claimed_credit(i, peer_vids)
                claims.append(i.claimed_credit)
            grant = CreditSystem.grant_amount(claims)
            for i in valid:
                i.granted_credit = grant
                host = self.store.hosts.get(i.host_id) if i.host_id else None
                self.credit.grant(f"host:{i.host_id}", grant, now)
                if host is not None:
                    self.credit.grant(f"volunteer:{host.volunteer_id}", grant, now)
                self.metrics.credit_granted += grant

    # ------------------------------------------------------------------

    def _queue_event(self, plan, job: Job, valid: List[JobInstance],
                     invalid: List[JobInstance], by_replication: bool) -> None:
        """Queue one job's validation outcome onto the plan's deferred
        reputation/credit structures, in processing order — exactly the
        sequence the scalar ``_post_validation_updates`` would apply."""
        if self.adaptive is not None:
            adp_h = plan.adp_h
            adp_v = plan.adp_v
            adp_ok = plan.adp_ok
            if by_replication:
                for i in valid:
                    if i.host_id is not None and i.app_version_id is not None:
                        adp_h.append(i.host_id)
                        adp_v.append(i.app_version_id)
                        adp_ok.append(True)
            for i in invalid:
                if i.host_id is not None and i.app_version_id is not None:
                    adp_h.append(i.host_id)
                    adp_v.append(i.app_version_id)
                    adp_ok.append(False)
                plan.err_outcome.append(i)
        if self.defense is not None:
            plan.defense_events.append((
                [(i.host_id, i.app_version_id) for i in valid
                 if i.host_id is not None and i.app_version_id is not None],
                [(i.host_id, i.app_version_id) for i in invalid
                 if i.host_id is not None and i.app_version_id is not None],
            ))
        if self.credit is not None and valid:
            peers = plan.peers_cache.get(job.app_name)
            if peers is None:
                peers = plan.peers_cache[job.app_name] = [
                    v.id for v in self.store.apps[job.app_name].latest_versions()
                ]
            plan.credit_entries.append((job, valid, peers))

    def _finalize_plan(self, plan, now: float) -> None:
        """Flush the tick's deferred effects in fused passes: bulk
        validate-state writes and job completions, one vectorized
        reputation pass, and one batched credit-ingestion pass — all in
        the exact event order the scalar loop would have applied them.
        Nothing in the transition loop reads credit, reputation, or
        another job's deferred state, so the flush is observationally
        identical to inline updates."""
        store = self.store
        if plan.valid_bulk:
            store.set_validate_states(plan.valid_bulk, ValidateState.VALID)
        if plan.invalid_bulk:
            store.set_validate_states(plan.invalid_bulk, ValidateState.INVALID)
        if plan.inconclusive_bulk:
            store.set_validate_states(
                plan.inconclusive_bulk, ValidateState.INCONCLUSIVE
            )
        if plan.finish:
            store.finish_jobs(plan.finish)
        if self.adaptive is not None:
            for i in plan.err_outcome:
                i.outcome = InstanceOutcome.VALIDATE_ERROR
            if plan.adp_h:
                self.adaptive.apply_events(plan.adp_h, plan.adp_v, plan.adp_ok)
        if self.defense is not None:
            # sequential replay of the tick's decisions in scalar order:
            # the quota halve/increment fold is order-sensitive, so this is
            # bit-equal to the inline scalar calls by construction
            for vpairs, ipairs in plan.defense_events:
                self.defense.on_validation(vpairs, ipairs, now)
        if self.credit is not None and plan.credit_entries:
            entries = plan.credit_entries
            grants = self.credit.ingest_batch(entries)
            hosts = store.hosts
            by_key: Dict[str, List[float]] = {}
            # hosts repeat across the tick's instances: resolve each host's
            # accounting keys (and amount lists) once
            key_lists: Dict[Any, Tuple[List[float], Optional[List[float]]]] = {}
            metrics = self.metrics
            for (job, valid, _), grant in zip(entries, grants):
                for i in valid:
                    i.__dict__["granted_credit"] = grant  # untracked field
                    hid = i.host_id
                    pair = key_lists.get(hid)
                    if pair is None:
                        hlist = by_key.setdefault(f"host:{hid}", [])
                        host = hosts.get(hid) if hid else None
                        vlist = (
                            by_key.setdefault(f"volunteer:{host.volunteer_id}", [])
                            if host is not None
                            else None
                        )
                        pair = key_lists[hid] = (hlist, vlist)
                    pair[0].append(grant)
                    if pair[1] is not None:
                        pair[1].append(grant)
                    metrics.credit_granted += grant
            self.credit.grant_many(by_key, now)

    def _fail_job(self, job: Job, reason: str) -> None:
        job.state = JobState.FAILURE
        job.error_mask |= 1
        self.metrics.jobs_failed += 1
        # cancel any unsent instances
        for i in self.store.job_instances(job.id):
            if i.state == InstanceState.UNSENT:
                i.state = InstanceState.OVER
                i.outcome = InstanceOutcome.CANCELLED
