"""The transitioner: the job-lifecycle finite-state machine (§4, §5.1).

"Viewing the progress of a job as a finite-state machine, this handles the
transitions. The events that trigger transitions come from potentially
concurrent processes like schedulers and validators. Instead of handling the
transitions, these programs set a flag in the job's database record. The
transitioner enumerates these records and processes them. This eliminates
the need for concurrency control of DB access."

Responsibilities per job (§4):
  * create the initial ``init_ninstances`` instances;
  * on deadline pass, mark instances NO_REPLY and create replacements;
  * trigger validation at quorum; designate the canonical instance;
  * grant credit (via the credit system) to valid instances;
  * cancel unsent instances once a canonical instance exists;
  * enforce max_error_instances / max_success_instances;
  * mark jobs for assimilation/file-deletion/purge.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from .adaptive import AdaptiveReplication
from .credit import CreditSystem
from .store import JobStore
from .types import (
    App,
    InstanceOutcome,
    InstanceState,
    Job,
    JobInstance,
    JobState,
    ValidateState,
)
from .validator import check_set, validate_against_canonical


@dataclass
class TransitionerMetrics:
    timeouts: int = 0
    retries_created: int = 0
    jobs_validated: int = 0
    jobs_failed: int = 0
    instances_cancelled: int = 0
    credit_granted: float = 0.0


@dataclass
class Transitioner:
    """Drives job state transitions against a JobStore (§5.1).

    ``instance``/``n_instances`` implement ID-space daemon sharding.
    """

    store: JobStore
    credit: Optional[CreditSystem] = None
    adaptive: Optional[AdaptiveReplication] = None
    instance: int = 0
    n_instances: int = 1
    metrics: TransitionerMetrics = field(default_factory=TransitionerMetrics)

    # ------------------------------------------------------------------

    def tick(self, now: float) -> int:
        """One daemon pass: handle deadline misses, then flagged jobs.

        Both passes enumerate the store's indexes (deadline heap, pending
        queue) so the cost is O(work to do), not O(table size); with
        ``store.use_indexes=False`` they fall back to the oracle scans.

        Returns the number of jobs transitioned.
        """
        self._check_deadlines(now)
        n = 0
        for job in self.store.pending_transitions(self.instance, self.n_instances):
            job.transition_flag = False
            self._transition(job, now)
            n += 1
        return n

    # ------------------------------------------------------------------

    def _check_deadlines(self, now: float) -> None:
        """Instances past deadline are assumed lost (§4).

        Deadline handling is sharded by ``job_id % n_instances`` like the
        flagged-job pass — each transitioner instance mutates only its own
        ID-space shard (§5.1).
        """
        for inst in self.store.expired_instances(now, self.instance, self.n_instances):
            inst.state = InstanceState.OVER
            inst.outcome = InstanceOutcome.NO_REPLY
            self.metrics.timeouts += 1
            job = self.store.jobs.get(inst.job_id)
            if job is not None:
                job.transition_flag = True
            if self.adaptive is not None and inst.host_id is not None \
                    and inst.app_version_id is not None:
                self.adaptive.on_invalid(inst.host_id, inst.app_version_id)

    # ------------------------------------------------------------------

    def _transition(self, job: Job, now: float) -> None:
        app = self.store.apps[job.app_name]
        insts = self.store.job_instances(job.id)

        n_outstanding = sum(1 for i in insts if i.is_outstanding())
        successes = [
            i
            for i in insts
            if i.state == InstanceState.OVER and i.outcome == InstanceOutcome.SUCCESS
        ]
        n_error = sum(
            1
            for i in insts
            if i.state == InstanceState.OVER
            and i.outcome
            in (
                InstanceOutcome.CLIENT_ERROR,
                InstanceOutcome.NO_REPLY,
                InstanceOutcome.ABANDONED,
                InstanceOutcome.VALIDATE_ERROR,
            )
        )

        # -- failure limits (§4) --
        if n_error > job.max_error_instances:
            self._fail_job(job, "too many errored instances")
            return

        # -- validation (§4) --
        if job.canonical_instance_id is None:
            fresh = [s for s in successes if s.validate_state == ValidateState.INIT]
            quorum = self._required_quorum(job)
            if len(successes) >= quorum and fresh:
                self._validate(job, app, successes, now)
                if job.state != JobState.ACTIVE:
                    return
            if job.canonical_instance_id is None and len(successes) > job.max_success_instances:
                self._fail_job(job, "too many successes without consensus")
                return
        else:
            # late-arriving successes validate against the canonical (§4)
            canonical = self.store.instances.get(job.canonical_instance_id)
            if canonical is not None:
                for s in successes:
                    if s.id != canonical.id and s.validate_state == ValidateState.INIT:
                        ok = validate_against_canonical(s, canonical, app.comparator)
                        self._post_validation_updates(
                            job, app, [s] if ok else [], [] if ok else [s], now,
                            by_replication=True,
                        )

        if job.state != JobState.ACTIVE:
            return

        # -- instance top-up (§4) --
        if job.canonical_instance_id is None:
            target = self._target_instances(job, insts)
            # Count outstanding plus the largest mutually-agreeing group of
            # successes: "if the outputs agree, they are accepted ...
            # otherwise a third instance is created and run" (§3.4). Two
            # disagreeing successes contribute 1, forcing a tie-breaker.
            live = n_outstanding + self._largest_agreeing_group(app, successes)
            total_created = len(insts)
            while live < target:
                # cap total instance creation to avoid unbounded retry loops
                if total_created >= job.max_error_instances + job.max_success_instances + 1:
                    break
                self.store.create_instance(job)
                if total_created >= job.init_ninstances:
                    self.metrics.retries_created += 1
                live += 1
                total_created += 1
        else:
            # canonical exists: cancel unsent instances (§4)
            for i in insts:
                if i.state == InstanceState.UNSENT:
                    i.state = InstanceState.OVER
                    i.outcome = InstanceOutcome.CANCELLED
                    self.metrics.instances_cancelled += 1
            outstanding = [i for i in insts if i.is_outstanding()]
            if not outstanding and not job.assimilated:
                # all resolved: output files of canonical may now be purged
                pass

    # ------------------------------------------------------------------

    def _required_quorum(self, job: Job) -> int:
        """Adaptive replication (§3.4): unreplicated jobs have quorum 1."""
        return job.min_quorum

    def _target_instances(self, job: Job, insts: List[JobInstance]) -> int:
        if not insts:
            return job.init_ninstances
        return job.min_quorum

    @staticmethod
    def _largest_agreeing_group(app: App, successes: List[JobInstance]) -> int:
        from .validator import bitwise_equal

        viable = [s for s in successes if s.validate_state != ValidateState.INVALID]
        if len(viable) <= 1:
            return len(viable)
        cmp = app.comparator or bitwise_equal
        groups: List[List[JobInstance]] = []
        for inst in viable:
            for g in groups:
                if cmp(g[0].output, inst.output):
                    g.append(inst)
                    break
            else:
                groups.append([inst])
        return max(len(g) for g in groups)

    # ------------------------------------------------------------------

    def _validate(self, job: Job, app: App, successes: List[JobInstance], now: float) -> None:
        result = check_set(successes, app.comparator, self._required_quorum(job))
        if result.canonical is None:
            return  # inconclusive; transitioner will top up instances
        job.canonical_instance_id = result.canonical.id
        self.metrics.jobs_validated += 1
        self._post_validation_updates(
            job, app, result.valid, result.invalid, now,
            by_replication=len(successes) >= 2,
        )
        job.state = JobState.SUCCESS
        job.transition_flag = True

    def _post_validation_updates(
        self,
        job: Job,
        app: App,
        valid: List[JobInstance],
        invalid: List[JobInstance],
        now: float,
        by_replication: bool = True,
    ) -> None:
        # adaptive-replication reputation (§3.4): N counts only jobs
        # "validated by replication" — trusted singletons don't build it.
        if self.adaptive is not None:
            if by_replication:
                for i in valid:
                    if i.host_id is not None and i.app_version_id is not None:
                        self.adaptive.on_validated(i.host_id, i.app_version_id)
            for i in invalid:
                if i.host_id is not None and i.app_version_id is not None:
                    self.adaptive.on_invalid(i.host_id, i.app_version_id)
                i.outcome = InstanceOutcome.VALIDATE_ERROR

        # credit (§7): grant the outlier-robust average to all valid instances
        if self.credit is not None and valid:
            peer_vids = [v.id for v in self.store.apps[job.app_name].latest_versions()]
            claims = []
            for i in valid:
                self.credit.record(i, job)
                i.claimed_credit = self.credit.claimed_credit(i, peer_vids)
                claims.append(i.claimed_credit)
            grant = CreditSystem.grant_amount(claims)
            for i in valid:
                i.granted_credit = grant
                host = self.store.hosts.get(i.host_id) if i.host_id else None
                self.credit.grant(f"host:{i.host_id}", grant, now)
                if host is not None:
                    self.credit.grant(f"volunteer:{host.volunteer_id}", grant, now)
                self.metrics.credit_granted += grant

    def _fail_job(self, job: Job, reason: str) -> None:
        job.state = JobState.FAILURE
        job.error_mask |= 1
        self.metrics.jobs_failed += 1
        # cancel any unsent instances
        for i in self.store.job_instances(job.id):
            if i.state == InstanceState.UNSENT:
                i.state = InstanceState.OVER
                i.outcome = InstanceOutcome.CANCELLED
