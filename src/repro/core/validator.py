"""Replication-based result validation (§3.4).

A job's successful instances are compared pairwise with an app-supplied
comparator (bitwise by default, fuzzy-numeric for stable numeric apps). If a
strict majority of a quorum agree, one member is designated the canonical
instance. Homogeneous redundancy restricts instances of one job to a single
host equivalence class so that bitwise comparison is meaningful; homogeneous
app version does the same at app-version granularity.

For tensor payloads, the hot comparison loop is the ``quorum_compare`` Pallas
kernel (`repro.kernels.quorum_compare`); this module falls back to numpy when
payloads are plain Python.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

from .types import (
    App,
    InstanceOutcome,
    InstanceState,
    JobInstance,
    ValidateState,
)

Comparator = Callable[[Any, Any], bool]


# ---------------------------------------------------------------------------
# Comparators
# ---------------------------------------------------------------------------


def bitwise_equal(a: Any, b: Any) -> bool:
    """Byte-for-byte comparison (the validator BOINC supplies for apps using
    homogeneous redundancy)."""
    la, lb = _leaves(a), _leaves(b)
    if len(la) != len(lb):
        return False
    for xa, xb in zip(la, lb):
        if isinstance(xa, np.ndarray) or isinstance(xb, np.ndarray):
            xa, xb = np.asarray(xa), np.asarray(xb)
            if xa.shape != xb.shape or xa.dtype != xb.dtype:
                return False
            if not np.array_equal(xa.view(np.uint8) if xa.dtype.kind == "f" else xa,
                                  xb.view(np.uint8) if xb.dtype.kind == "f" else xb):
                return False
        elif xa != xb:
            return False
    return True


def fuzzy_comparator(rtol: float = 1e-5, atol: float = 1e-8,
                     max_bad_fraction: float = 0.0) -> Comparator:
    """Application-specific fuzzy validator (§3.4): values agree within
    tolerances; optionally allow a small fraction of out-of-band elements
    (useful for bf16 gradient payloads where a handful of large-magnitude
    accumulations legitimately differ)."""

    def cmp(a: Any, b: Any) -> bool:
        la, lb = _leaves(a), _leaves(b)
        if len(la) != len(lb):
            return False
        total = 0
        bad = 0
        for xa, xb in zip(la, lb):
            xa = np.asarray(xa, dtype=np.float64)
            xb = np.asarray(xb, dtype=np.float64)
            if xa.shape != xb.shape:
                return False
            ok = np.isclose(xa, xb, rtol=rtol, atol=atol)
            total += ok.size
            bad += int(ok.size - np.count_nonzero(ok))
        if total == 0:
            return True
        return (bad / total) <= max_bad_fraction

    return cmp


def _leaves(x: Any) -> List[Any]:
    """Flatten nested dict/list/tuple payloads to a leaf list (stable order)."""
    if isinstance(x, dict):
        out: List[Any] = []
        for k in sorted(x):
            out.extend(_leaves(x[k]))
        return out
    if isinstance(x, (list, tuple)):
        out = []
        for v in x:
            out.extend(_leaves(v))
        return out
    return [x]


# ---------------------------------------------------------------------------
# Quorum check (§3.4, §4)
# ---------------------------------------------------------------------------


@dataclass
class ValidationResult:
    canonical: Optional[JobInstance]
    valid: List[JobInstance]
    invalid: List[JobInstance]
    inconclusive: List[JobInstance]


def check_set(
    instances: Sequence[JobInstance],
    comparator: Optional[Comparator],
    min_quorum: int,
) -> ValidationResult:
    """Find a canonical instance among successful instances (§4).

    Groups instances into equivalence classes under ``comparator``; if a
    class forms a strict majority of the quorum set, its first member is
    canonical; members of that class are VALID, others INVALID. With fewer
    than ``min_quorum`` successes, everything is INCONCLUSIVE.
    """
    cmp = comparator or bitwise_equal
    succ = [i for i in instances if i.outcome == InstanceOutcome.SUCCESS]
    if len(succ) < min_quorum:
        return ValidationResult(None, [], [], list(succ))

    # Greedy equivalence grouping (comparator assumed transitive in-tolerance).
    groups: List[List[JobInstance]] = []
    for inst in succ:
        placed = False
        for g in groups:
            if cmp(g[0].output, inst.output):
                g.append(inst)
                placed = True
                break
        if not placed:
            groups.append([inst])

    groups.sort(key=len, reverse=True)
    best = groups[0]
    # "a quorum of consistent instances" (§3.4/§4): the largest equivalent
    # group must reach min_quorum (for the min_quorum-sized initial set this
    # is exactly the paper's strict-majority-of-these condition; for larger
    # sets it is what terminates the repeat-until-quorum loop).
    if len(best) >= min_quorum:
        canonical = best[0]
        valid = list(best)
        invalid = [i for g in groups[1:] for i in g]
        for i in valid:
            i.validate_state = ValidateState.VALID
        for i in invalid:
            i.validate_state = ValidateState.INVALID
        return ValidationResult(canonical, valid, invalid, [])

    for i in succ:
        i.validate_state = ValidateState.INCONCLUSIVE
    return ValidationResult(None, [], [], list(succ))


def validate_against_canonical(
    instance: JobInstance,
    canonical: JobInstance,
    comparator: Optional[Comparator],
) -> bool:
    """A straggler success reported after the canonical instance exists is
    validated against it (to grant credit) (§4)."""
    cmp = comparator or bitwise_equal
    ok = bool(cmp(canonical.output, instance.output))
    instance.validate_state = ValidateState.VALID if ok else ValidateState.INVALID
    return ok
