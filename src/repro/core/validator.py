"""Replication-based result validation (§3.4).

A job's successful instances are compared pairwise with an app-supplied
comparator (bitwise by default, fuzzy-numeric for stable numeric apps). If a
strict majority of a quorum agree, one member is designated the canonical
instance. Homogeneous redundancy restricts instances of one job to a single
host equivalence class so that bitwise comparison is meaningful; homogeneous
app version does the same at app-version granularity.

For tensor payloads, the hot comparison loop is the ``quorum_compare`` Pallas
kernel (`repro.kernels.quorum_compare`); this module falls back to numpy when
payloads are plain Python.
"""
from __future__ import annotations

import itertools
import struct
from dataclasses import dataclass
from hashlib import blake2b
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .types import (
    App,
    InstanceOutcome,
    InstanceState,
    JobInstance,
    ValidateState,
)

Comparator = Callable[[Any, Any], bool]


# ---------------------------------------------------------------------------
# Comparators
# ---------------------------------------------------------------------------


def bitwise_equal(a: Any, b: Any) -> bool:
    """Byte-for-byte comparison (the validator BOINC supplies for apps using
    homogeneous redundancy)."""
    la, lb = _leaves(a), _leaves(b)
    if len(la) != len(lb):
        return False
    for xa, xb in zip(la, lb):
        if isinstance(xa, np.ndarray) or isinstance(xb, np.ndarray):
            xa, xb = np.asarray(xa), np.asarray(xb)
            if xa.shape != xb.shape or xa.dtype != xb.dtype:
                return False
            if not np.array_equal(xa.view(np.uint8) if xa.dtype.kind == "f" else xa,
                                  xb.view(np.uint8) if xb.dtype.kind == "f" else xb):
                return False
        elif xa != xb:
            return False
    return True


def fuzzy_comparator(rtol: float = 1e-5, atol: float = 1e-8,
                     max_bad_fraction: float = 0.0) -> Comparator:
    """Application-specific fuzzy validator (§3.4): values agree within
    tolerances; optionally allow a small fraction of out-of-band elements
    (useful for bf16 gradient payloads where a handful of large-magnitude
    accumulations legitimately differ)."""

    def cmp(a: Any, b: Any) -> bool:
        la, lb = _leaves(a), _leaves(b)
        if len(la) != len(lb):
            return False
        total = 0
        bad = 0
        for xa, xb in zip(la, lb):
            xa = np.asarray(xa, dtype=np.float64)
            xb = np.asarray(xb, dtype=np.float64)
            if xa.shape != xb.shape:
                return False
            ok = np.isclose(xa, xb, rtol=rtol, atol=atol)
            total += ok.size
            bad += int(ok.size - np.count_nonzero(ok))
        if total == 0:
            return True
        return (bad / total) <= max_bad_fraction

    # Digest hook for the batch validation engine. A bad-fraction allowance
    # cannot be expressed as a per-payload digest (it is a property of a
    # *pair*), so those comparators stay on the scalar path.
    if max_bad_fraction == 0.0:
        cmp.digest_batch = lambda outputs: _fuzzy_digest_batch(outputs, rtol, atol)  # type: ignore[attr-defined]
        # tolerances, exposed so the jax validation backend can route
        # homogeneous tensor payloads through the quorum_compare Pallas
        # kernel with the same (rtol, atol) contract
        cmp.fuzzy_params = (rtol, atol)  # type: ignore[attr-defined]
    return cmp


def _leaves(x: Any) -> List[Any]:
    """Flatten nested dict/list/tuple payloads to a leaf list (stable order)."""
    if isinstance(x, dict):
        out: List[Any] = []
        for k in sorted(x):
            out.extend(_leaves(x[k]))
        return out
    if isinstance(x, (list, tuple)):
        out = []
        for v in x:
            out.extend(_leaves(v))
        return out
    return [x]


# ---------------------------------------------------------------------------
# Payload digests (batch validation engine)
# ---------------------------------------------------------------------------
#
# The batch engine replaces pairwise comparator calls with equivalence
# grouping over per-instance 64-bit digests: instances of one job with equal
# digests form one group. The digest contracts are:
#
#   * bitwise (comparator None): digests are an *exact* encoding of
#     ``bitwise_equal``'s equivalence — equal payloads share a digest and
#     unequal payloads differ (up to a 2^-64 hash-collision probability for
#     composite payloads; plain-float payloads use the raw IEEE bits, with
#     -0.0 canonicalized to +0.0 and each NaN given a unique sentinel to
#     mirror Python's ``==``).
#   * fuzzy (``fuzzy_comparator`` with ``max_bad_fraction == 0``): each
#     value is quantized to a bucket of width ``atol + rtol*|x|`` (the
#     ``np.isclose`` tolerance at that magnitude). Bucketing is coarser
#     than the pairwise comparator: digest grouping agrees with greedy
#     pairwise grouping **provided** a job's outputs either agree to well
#     within tolerance (same bucket) or disagree by far more than the
#     bucket width. Replicated numeric workloads satisfy this — honest
#     replicas agree to round-off while corruption is orders of magnitude
#     outside tolerance — and the scenario suite asserts oracle agreement.
#     Payloads containing NaN match nothing (``isclose`` semantics), so
#     they get unique sentinels.
#
# Payloads the digest functions cannot encode faithfully (exotic leaf
# types, or a comparator without a ``digest_batch`` hook) raise
# ``DigestError``; the engine then falls back to the scalar ``check_set``
# for that job, so correctness never depends on digest coverage. Digests
# also assume instances of one job use a *consistent payload structure*
# (same nesting/leaf kinds) — true for any real app, where one program
# produced every replica's output.


class DigestError(Exception):
    """Payload (or comparator) not expressible as an equivalence digest."""


_F64 = struct.Struct("<d")
#: int64 value of the 0x7FF8... quiet-NaN bit pattern: the base of the
#: unique-sentinel space. Canonicalized non-NaN floats can never land here.
_NAN_SENTINEL_BASE = struct.unpack("<q", _F64.pack(float("nan")))[0]
_nan_counter = itertools.count(1)


def _float_bits(x: float) -> int:
    """Canonical IEEE-754 bits of ``x`` as a Python int (two's complement):
    -0.0 folds into +0.0 (Python ``==`` semantics); NaN callers must handle
    separately."""
    return struct.unpack("<q", _F64.pack(x + 0.0))[0]


def _nan_sentinel() -> int:
    """A digest no other payload can share: NaN compares unequal even to
    itself under both comparators, so every NaN occurrence is its own
    group."""
    return int(_NAN_SENTINEL_BASE) + next(_nan_counter)


def _hash_digest(parts: List[bytes]) -> int:
    h = blake2b(digest_size=8)
    for p in parts:
        h.update(p)
    return int.from_bytes(h.digest(), "little", signed=True)


def _numeric_bits(v: Any) -> bytes:
    """Encode a scalar numeric leaf so Python ``==`` equivalence is
    preserved across int/float/bool mixes (1 == 1.0 == True)."""
    if isinstance(v, float):
        if v != v:  # NaN
            raise _UniqueDigest()
        return b"N" + _F64.pack(v + 0.0)
    try:
        f = float(v)
    except OverflowError:
        return b"I" + str(int(v)).encode()
    if f == v:
        return b"N" + _F64.pack(f + 0.0)
    return b"I" + str(int(v)).encode()


class _UniqueDigest(Exception):
    """Internal: payload matches nothing — assign a unique sentinel."""


def _bitwise_digest_one(out: Any) -> int:
    leaves = _leaves(out)
    try:
        if len(leaves) == 1 and isinstance(leaves[0], (bool, int, float)) \
                and not isinstance(leaves[0], np.ndarray):
            enc = _numeric_bits(leaves[0])
            if enc[:1] == b"N":
                return struct.unpack("<q", enc[1:])[0]
            return _hash_digest([enc])
        parts: List[bytes] = []
        for leaf in leaves:
            if isinstance(leaf, np.ndarray) or isinstance(leaf, np.generic):
                a = np.ascontiguousarray(leaf)
                parts.append(b"A" + a.dtype.str.encode() + repr(a.shape).encode())
                parts.append(a.tobytes())
            elif isinstance(leaf, (bool, int, float)):
                parts.append(_numeric_bits(leaf))
            elif isinstance(leaf, str):
                parts.append(b"S" + leaf.encode())
            elif isinstance(leaf, bytes):
                parts.append(b"B" + leaf)
            elif leaf is None:
                parts.append(b"Z")
            else:
                raise DigestError(f"unhashable leaf type {type(leaf).__name__}")
        return _hash_digest(parts)
    except _UniqueDigest:
        return _nan_sentinel()


def _homogeneous_arrays(outputs: Sequence[Any]) -> Optional[np.ndarray]:
    """Stack payloads that are all ndarrays of one dtype and shape (the
    typical tensor-result population) into an (n, size) matrix; None when
    the population is mixed."""
    first = outputs[0]
    if not (isinstance(first, np.ndarray) and first.ndim >= 1):
        return None
    dt, shp = first.dtype, first.shape
    for o in outputs:
        if not isinstance(o, np.ndarray) or o.dtype != dt or o.shape != shp:
            return None
    return np.stack(outputs).reshape(len(outputs), -1)


def bitwise_digest_batch(outputs: Sequence[Any]) -> np.ndarray:
    """Digests for ``bitwise_equal`` equivalence. Plain-float payloads (the
    emulator's common case) vectorize to raw IEEE bits; homogeneous ndarray
    payloads hash row-wise off one stacked matrix; anything else goes
    through an 8-byte blake2b per payload."""
    if all(type(o) is float for o in outputs):
        arr = np.asarray(outputs, dtype=np.float64) + 0.0  # -0.0 -> +0.0
        bits = arr.view(np.int64).copy()
        nan = np.isnan(arr)
        if nan.any():
            bits[nan] = [_nan_sentinel() for _ in range(int(nan.sum()))]
        return bits
    mat = _homogeneous_arrays(outputs)
    if mat is not None:
        # same framing as _bitwise_digest_one's single-ndarray case
        prefix = (
            b"A" + outputs[0].dtype.str.encode() + repr(outputs[0].shape).encode()
        )
        mat = np.ascontiguousarray(mat)
        rowbytes = mat.dtype.itemsize * mat.shape[1]
        buf = mat.view(np.uint8).reshape(mat.shape[0], rowbytes)
        out = np.empty(len(outputs), dtype=np.int64)
        for i in range(len(outputs)):
            h = blake2b(prefix, digest_size=8)
            h.update(buf[i].tobytes())
            out[i] = int.from_bytes(h.digest(), "little", signed=True)
        return out
    return np.array([_bitwise_digest_one(o) for o in outputs], dtype=np.int64)


def _quantize(x: np.ndarray, rtol: float, atol: float) -> np.ndarray:
    """Bucket code per element, injective across magnitudes.

    Two regimes, matching the ``np.isclose`` tolerance ``atol + rtol*|x|``:

      * ``|x| <= atol/rtol`` (atol-dominated): linear buckets of width
        ``atol`` — code ``round(x/atol)``, bounded by ``1/rtol``;
      * larger magnitudes (rtol-dominated): buckets of *ratio* ``1+rtol``,
        i.e. width ``rtol`` in log space — code derived from
        ``round(ln|x|/rtol)``, sign-extended and offset clear of the
        linear range. (A naive ``round(x/width(x))`` saturates at
        ``1/rtol`` for large ``x`` and would merge distinct magnitudes.)

    ±inf keep their sign (``isclose`` treats equal infinities as close);
    NaN is handled by the caller. Codes stay integral below 2^53, which
    bounds the usable tolerance at roughly ``rtol >= 1e-12``.
    """
    if rtol <= 0.0:
        w = atol if atol > 0.0 else 1.0
        return np.round(x / w)
    cutoff = atol / rtol
    ax = np.abs(x)
    lin = ax <= cutoff  # x == 0 lands here (its own bucket when atol == 0)
    code = np.empty(x.shape, dtype=np.float64)
    nlog = ~lin  # ±inf and NaN land here (NaN propagates; callers sentinel it)
    if lin.any():
        code[lin] = np.round(x[lin] / atol) if atol > 0.0 else 0.0
    if nlog.any():
        # log-space buckets, shifted positive and offset past the linear
        # range: ln|x| >= ln(5e-324) > -746, so k + 746/rtol >= ~1/rtol > 0
        # and |code| >= 1024/rtol > 1/rtol + 1 > any linear code. ±inf
        # propagate through log/round/sign and keep their own buckets.
        xs = x[nlog]
        k = np.round(np.log(np.abs(xs)) / rtol) + 746.0 / rtol
        code[nlog] = np.sign(xs) * (1024.0 / rtol + k)
    return code


def _bucket_bits(q: np.ndarray) -> np.ndarray:
    """Fold float bucket indices into int64 digests: exact int64 when small,
    raw float bits for huge magnitudes (disjoint ranges)."""
    out = np.zeros(q.shape, dtype=np.int64)
    small = np.abs(q) < 2.0**62
    out[small] = q[small].astype(np.int64)
    big = ~small
    if big.any():
        out[big] = np.ascontiguousarray(q[big]).view(np.int64)
    return out


def _fuzzy_digest_one(out: Any, rtol: float, atol: float) -> int:
    leaves = _leaves(out)
    parts: List[bytes] = []
    for leaf in leaves:
        a = np.asarray(leaf, dtype=np.float64)
        if np.isnan(a).any():
            return _nan_sentinel()
        q = _quantize(a, rtol, atol)
        if len(leaves) == 1 and a.ndim == 0 and np.isfinite(a):
            return int(_bucket_bits(q.reshape(1))[0])
        parts.append(b"F" + repr(a.shape).encode())
        parts.append(np.ascontiguousarray(q).tobytes())
    return _hash_digest(parts)


_mix_cache: dict = {}


def _mix_vector(d: int) -> np.ndarray:
    """Fixed odd int64 multipliers for the row linear hash, derived by
    hashing the column index (blake2b, keyed) — deterministic constants
    with no RNG namespace involved, so the rng-discipline contract (no
    draws outside seeded entry points) holds trivially. Only pairwise
    independence-ish mixing is needed: equal bucket rows always collide,
    distinct rows collide with probability ~2^-64 for *any* fixed odd
    multipliers without structure, which keyed blake2b provides."""
    r = _mix_cache.get(d)
    if r is None:
        raw = b"".join(
            blake2b(i.to_bytes(8, "little"), digest_size=8, key=b"reprolint-mix").digest()
            for i in range(d)
        )
        r = np.frombuffer(raw, dtype="<i8").astype(np.int64) | np.int64(1)
        _mix_cache[d] = r
    return r


def _fuzzy_digest_batch(outputs: Sequence[Any], rtol: float, atol: float) -> np.ndarray:
    if all(type(o) is float for o in outputs):
        arr = np.asarray(outputs, dtype=np.float64)
        dig = _bucket_bits(_quantize(arr, rtol, atol))
        nan = np.isnan(arr)
        if nan.any():
            dig[nan] = [_nan_sentinel() for _ in range(int(nan.sum()))]
        return dig
    mat = _homogeneous_arrays(outputs)
    if mat is not None:
        return _fuzzy_digest_matrix(mat, rtol, atol)
    return np.array(
        [_fuzzy_digest_one(o, rtol, atol) for o in outputs], dtype=np.int64
    )


def _fuzzy_digest_matrix(mat: np.ndarray, rtol: float, atol: float) -> np.ndarray:
    """Fused bucket digests for a homogeneous (n, d) payload matrix.

    Relative (rtol) quantization is a mantissa truncation: keeping the top
    ``m ≈ -log2(rtol)`` mantissa bits buckets values by sign/exponent/
    leading-mantissa — relative bucket width ~2^-m, i.e. the isclose rtol
    band within a small constant factor, in one shift over the raw IEEE
    bits (no log calls, and float32 payloads never widen to float64). The
    atol-dominated band ``|x| <= atol/rtol`` is patched with linear
    ``round(x/atol)`` buckets (this also folds ±0.0 together). Rows then
    collapse through a wraparound-int64 linear hash: equal bucket rows ⇔
    equal digest; distinct rows collide with probability ~2^-64. NaN rows
    get unique sentinels (isclose: NaN matches nothing); ±inf keep their
    (signed) bit patterns and group by equal-inf layout.
    """
    n, d = mat.shape
    if mat.dtype == np.float32:
        bits = mat.view(np.int32)
        mant = 23
    elif mat.dtype == np.float64:
        bits = mat.view(np.int64)
        mant = 52
    else:
        mat = mat.astype(np.float64)
        bits = mat.view(np.int64)
        mant = 52
    keep = 52 if rtol <= 0.0 else min(52, max(1, int(round(-np.log2(max(rtol, 2.0 ** -52))))))
    shift = max(0, mant - keep)
    q = (bits >> shift).astype(np.int64, copy=False)
    # linear patch for the atol-dominated band (covers x == ±0.0)
    cutoff = (atol / rtol) if rtol > 0.0 else np.inf
    lin = np.abs(mat) <= cutoff
    if lin.any():
        idx = np.flatnonzero(lin.reshape(-1))
        vals = mat.reshape(-1)[idx]
        patch = np.round(vals / atol) if atol > 0.0 else np.zeros(len(idx))
        # offset well past the shifted-bits code range so the two bucket
        # families cannot collide (|patch| <= 1/rtol << 2^52)
        q.reshape(-1)[idx] = patch.astype(np.int64) + (np.int64(1) << 61)
    r = _mix_vector(d)
    with np.errstate(over="ignore"):
        out = q @ r
    nan_rows = np.isnan(mat).any(axis=1)
    if nan_rows.any():
        for k in np.flatnonzero(nan_rows):
            out[int(k)] = _nan_sentinel()
    return out


def digest_batch_for(comparator: Optional[Comparator]):
    """The digest hook for an app comparator, or None when only the scalar
    path can evaluate it (custom comparators without a ``digest_batch``
    attribute, fuzzy comparators with a bad-fraction allowance)."""
    if comparator is None:
        return bitwise_digest_batch
    return getattr(comparator, "digest_batch", None)


# ---------------------------------------------------------------------------
# Quorum check (§3.4, §4)
# ---------------------------------------------------------------------------


@dataclass
class ValidationResult:
    canonical: Optional[JobInstance]
    valid: List[JobInstance]
    invalid: List[JobInstance]
    inconclusive: List[JobInstance]


def effective_quorum_size(
    group: Sequence[JobInstance], clusters: Dict[int, int]
) -> int:
    """Quorum votes of a group under work-spreading (§3.4 defense layer):
    replicas from hosts of one suspicion cluster collectively count as a
    single vote, so colluders can never validate each other by themselves.
    Unclustered hosts count individually."""
    seen: set = set()
    n = 0
    for i in group:
        cl = clusters.get(i.host_id) if i.host_id is not None else None
        if cl is None:
            n += 1
        elif cl not in seen:
            seen.add(cl)
            n += 1
    return n


def check_set(
    instances: Sequence[JobInstance],
    comparator: Optional[Comparator],
    min_quorum: int,
    clusters: Optional[Dict[int, int]] = None,
) -> ValidationResult:
    """Find a canonical instance among successful instances (§4).

    Groups instances into equivalence classes under ``comparator``; if a
    class forms a strict majority of the quorum set, its first member is
    canonical; members of that class are VALID, others INVALID. With fewer
    than ``min_quorum`` successes, everything is INCONCLUSIVE.

    **Grouping-order contract** (pinned; the batch engine and its tests
    rely on it). Fuzzy comparators are tolerance relations, not true
    equivalences — non-transitive chains (a~b, b~c, a!~c) make greedy
    grouping order-dependent. The canonical order is:

      1. instances are visited in the order given (the transitioner passes
         them in creation order — the ``JobStore._by_job`` row order);
      2. each instance joins the first existing group (groups in creation
         order) whose **representative** — the group's first member — it
         matches; members beyond the representative are never consulted;
      3. the winning group is the largest, ties broken by earliest group
         creation; its representative becomes canonical.

    So in the a~b, b~c, a!~c chain visited as [a, b, c]: b joins a's group,
    c is compared against a (the representative), fails, and opens its own
    group — {a, b}, {c}.

    With ``clusters`` (the defense layer's tick-start suspicion-cluster
    snapshot, host_id -> cluster id), quorum support is counted by
    :func:`effective_quorum_size` — same-cluster replicas are one vote —
    both for the quorum gate and for ranking the winning group (effective
    size first, then raw size, then creation order). Without clusters the
    behavior is bit-identical to the original.
    """
    cmp = comparator or bitwise_equal
    succ = [i for i in instances if i.outcome == InstanceOutcome.SUCCESS]
    if len(succ) < min_quorum:
        return ValidationResult(None, [], [], list(succ))

    # Greedy equivalence grouping (comparator assumed transitive in-tolerance).
    groups: List[List[JobInstance]] = []
    for inst in succ:
        placed = False
        for g in groups:
            if cmp(g[0].output, inst.output):
                g.append(inst)
                placed = True
                break
        if not placed:
            groups.append([inst])

    if clusters:
        eff = lambda g: effective_quorum_size(g, clusters)  # noqa: E731
        groups.sort(key=lambda g: (eff(g), len(g)), reverse=True)
    else:
        eff = len
        groups.sort(key=len, reverse=True)
    best = groups[0]
    # "a quorum of consistent instances" (§3.4/§4): the largest equivalent
    # group must reach min_quorum (for the min_quorum-sized initial set this
    # is exactly the paper's strict-majority-of-these condition; for larger
    # sets it is what terminates the repeat-until-quorum loop).
    if eff(best) >= min_quorum:
        canonical = best[0]
        valid = list(best)
        invalid = [i for g in groups[1:] for i in g]
        for i in valid:
            i.validate_state = ValidateState.VALID
        for i in invalid:
            i.validate_state = ValidateState.INVALID
        return ValidationResult(canonical, valid, invalid, [])

    for i in succ:
        i.validate_state = ValidateState.INCONCLUSIVE
    return ValidationResult(None, [], [], list(succ))


def validate_against_canonical(
    instance: JobInstance,
    canonical: JobInstance,
    comparator: Optional[Comparator],
) -> bool:
    """A straggler success reported after the canonical instance exists is
    validated against it (to grant credit) (§4)."""
    cmp = comparator or bitwise_equal
    ok = bool(cmp(canonical.output, instance.output))
    instance.validate_state = ValidateState.VALID if ok else ValidateState.INVALID
    return ok
