"""The linear-bounded allocation model (§3.9).

"For each submitter, the system maintains a balance that grows linearly at a
particular rate, up to a fixed maximum. ... When a submitter uses resources,
their balance is decreased accordingly. At any given point, the jobs of the
submitter with the greatest balance are given priority. ... Given a mix of
continuous and sporadic workloads, this policy prioritizes small batches,
thereby minimizing average batch turnaround."

BOINC reuses the same model for client project scheduling priorities (§6.1)
and Science United project allocation (§10.1); so do we: the grid runtime
uses it to arbitrate submitters, and the client uses it for project priority.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict


@dataclass
class _Account:
    rate: float  # balance growth per second
    cap: float  # maximum balance
    balance: float = 0.0
    last_update: float = 0.0
    total_used: float = 0.0


@dataclass
class LinearBoundedAllocator:
    """Fair-share arbiter over named accounts (submitters or projects)."""

    default_rate: float = 1.0
    default_cap: float = 3600.0
    accounts: Dict[str, _Account] = field(default_factory=dict)

    def add_account(self, name: str, rate: float = None, cap: float = None, now: float = 0.0) -> None:
        self.accounts[name] = _Account(
            rate=self.default_rate if rate is None else rate,
            cap=self.default_cap if cap is None else cap,
            last_update=now,
        )

    def ensure(self, name: str, now: float = 0.0) -> _Account:
        if name not in self.accounts:
            self.add_account(name, now=now)
        return self.accounts[name]

    def _accrue(self, acct: _Account, now: float) -> None:
        dt = max(0.0, now - acct.last_update)
        acct.balance = min(acct.cap, acct.balance + acct.rate * dt)
        acct.last_update = now

    def balance(self, name: str, now: float) -> float:
        acct = self.ensure(name, now)
        self._accrue(acct, now)
        return acct.balance

    def debit(self, name: str, amount: float, now: float) -> None:
        """Charge ``amount`` (resource-seconds or credit) to an account."""
        acct = self.ensure(name, now)
        self._accrue(acct, now)
        acct.balance -= amount  # may go negative: over-served accounts wait
        acct.total_used += amount

    def priority(self, name: str, now: float) -> float:
        """Scheduling priority == current balance (§3.9)."""
        return self.balance(name, now)

    def ranked(self, now: float):
        """Accounts in dispatch-priority order (highest balance first)."""
        names = list(self.accounts)
        return sorted(names, key=lambda n: -self.balance(n, now))
