"""Keyword hierarchies and preferences (§2.4).

BOINC defines two keyword hierarchies: science areas and project locations.
Volunteers mark keywords yes/no; the scheduler prefers jobs with "yes"
keywords and never sends jobs with "no" keywords. Science United's
coordinated model (§10.1) is built on the same mechanism.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional, Sequence, Tuple

# The paper's two hierarchies, abbreviated. parent == None marks a root.
SCIENCE_KEYWORDS: Dict[str, Optional[str]] = {
    "science": None,
    "physics": "science",
    "astrophysics": "physics",
    "particle_physics": "physics",
    "biomedicine": "science",
    "cancer_research": "biomedicine",
    "drug_discovery": "biomedicine",
    "mathematics": "science",
    "climate": "science",
    "machine_learning": "science",  # adaptation: ML workloads are first-class
}

LOCATION_KEYWORDS: Dict[str, Optional[str]] = {
    "world": None,
    "asia": "world",
    "europe": "world",
    "united_states": "world",
    "uc_berkeley": "united_states",
    "texas": "united_states",
}


def ancestors(keyword: str, tree: Dict[str, Optional[str]]) -> Tuple[str, ...]:
    """Keyword plus its chain of parents up to the root."""
    out = []
    k: Optional[str] = keyword
    while k is not None:
        out.append(k)
        k = tree.get(k)
    return tuple(out)


@dataclass
class KeywordPrefs:
    """A volunteer's yes/no keyword marks (§2.4)."""

    yes: frozenset = field(default_factory=frozenset)
    no: frozenset = field(default_factory=frozenset)

    @staticmethod
    def make(yes: Iterable[str] = (), no: Iterable[str] = ()) -> "KeywordPrefs":
        return KeywordPrefs(yes=frozenset(yes), no=frozenset(no))

    def empty(self) -> bool:
        return not self.yes and not self.no


def keyword_score(
    job_keywords: Sequence[str],
    prefs: KeywordPrefs,
    tree: Dict[str, Optional[str]] = SCIENCE_KEYWORDS,
) -> Optional[float]:
    """Score a job's keywords against volunteer prefs (§6.4).

    Returns None if the job carries a "no" keyword (job must be skipped);
    otherwise the number of "yes" matches (ancestors count: marking
    "physics" yes matches an "astrophysics" job).
    """
    if prefs.empty():
        return 0.0
    score = 0.0
    for kw in job_keywords:
        chain = ancestors(kw, tree) if kw in tree else (kw,)
        for a in chain:
            if a in prefs.no:
                return None
            if a in prefs.yes:
                score += 1.0
                break
    return score
