"""Vectorized validation→credit→reputation engine (§3.4, §4, §7).

The transitioner's validate pass is the last scalar per-job server hot
path: ``check_set`` runs O(n²) pairwise comparator calls per job, and the
credit/reputation updates walk Python dicts one instance at a time. This
module processes **every dirty job of a tick at once**:

  * one struct-of-arrays gather over the flagged jobs' instance rows
    (state / outcome / validate-state codes), with per-job aggregates —
    outstanding, error, success, fresh-success counts — as fused
    ``bincount`` passes;
  * payload **digests** computed once per success instance (raw IEEE bits
    for plain-float payloads; fused mantissa-truncation buckets + row hash
    for homogeneous tensor payloads; 8-byte blake2b otherwise — see
    ``validator.py`` for the digest contracts), batched per app across all
    jobs of the tick;
  * equivalence grouping as a single ``lexsort`` over ``(job, digest)``
    keys instead of pairwise comparator loops; quorum / canonical
    decisions for all candidate jobs in one boolean-mask pass, with the
    winning group chosen by (size desc, creation order asc) — exactly the
    pinned ``check_set`` grouping contract;
  * mutations and bookkeeping deferred into fused end-of-tick passes:
    bulk validate-state writes and ACTIVE→SUCCESS completions
    (``JobStore.set_validate_states`` / ``finish_jobs``), claimed credit
    via ``CreditSystem.ingest_batch`` (bit-equal to the scalar
    record/claim sequence), per-key grant replay
    (``CreditSystem.grant_many``), and reputation via
    ``AdaptiveReplication.apply_events`` (one fused reset/increment pass).

Candidate jobs come from the store's **validation-pending index** (jobs
holding a fresh OVER/SUCCESS/INIT instance) intersected with the flagged
set, so quiescent flagged jobs never pay for the digest pass.

Apps whose comparator has no digest hook (custom comparators, fuzzy with a
bad-fraction allowance) or whose payloads defeat digesting fall back to the
scalar ``check_set`` per job — results stay correct, only the speedup is
lost. ``Transitioner(batch_validate=True)`` routes through this engine;
the scalar path is kept verbatim as the parity oracle
(``tests/test_batch_validate.py``).
"""
from __future__ import annotations

from collections import Counter
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .store import JobStore
from .types import (
    App,
    InstanceOutcome,
    InstanceState,
    Job,
    JobInstance,
    ValidateState,
)
from .validator import DigestError, digest_batch_for

# dense codes for the SoA gather; error outcomes are contiguous (2..5) so
# the §4 failure-limit count is one range test
_STATE_CODE = {
    InstanceState.UNSENT: 0,
    InstanceState.IN_PROGRESS: 1,
    InstanceState.OVER: 2,
}
_OUTCOME_CODE = {
    InstanceOutcome.INIT: 0,
    InstanceOutcome.SUCCESS: 1,
    InstanceOutcome.CLIENT_ERROR: 2,
    InstanceOutcome.NO_REPLY: 3,
    InstanceOutcome.ABANDONED: 4,
    InstanceOutcome.VALIDATE_ERROR: 5,
    InstanceOutcome.CANCELLED: 6,
}
_VSTATE_CODE = {
    ValidateState.INIT: 0,
    ValidateState.VALID: 1,
    ValidateState.INVALID: 2,
    ValidateState.INCONCLUSIVE: 3,
}

# cache each code on its enum member: a plain attribute read beats a dict
# probe through the (python-level) Enum.__hash__ in the row gather
for _enum_map in (_STATE_CODE, _OUTCOME_CODE, _VSTATE_CODE):
    for _member, _c in _enum_map.items():
        _member._bv_code = _c  # type: ignore[attr-defined]

#: decision kinds
DECIDED = "decided"
INCONCLUSIVE = "inconclusive"
SCALAR = "scalar"  # comparator/payload not digestable: scalar check_set


class ValidationPlan:
    """Per-tick result of :meth:`BatchValidationEngine.prepare`.

    Exposes the per-job aggregates and precomputed quorum decisions the
    transitioner consumes instead of rescanning/recomparing instances, and
    accumulates the tick's deferred effects — validate-state writes, job
    completions, credit entries, reputation events — for the fused
    ``Transitioner._finalize_plan`` flush. Effects are appended in job
    processing order, so the flush replays exactly the event sequence the
    scalar loop would have produced.
    """

    def __init__(self, engine: "BatchValidationEngine", jobs: List[Job]) -> None:
        self.engine = engine
        self.jobs = jobs
        nj = len(jobs)
        self.refs: List[JobInstance] = []
        # per-job aggregate counts (plain lists: cheaper per-element reads
        # in the transition loop than numpy scalars)
        self.n_outstanding: List[int] = []
        self.n_error: List[int] = []
        self.n_succ: List[int] = []
        self.n_total: List[int] = []
        self.fresh: List[int] = []
        self.row_off: List[int] = [0]
        self._st: List[int] = []
        self.succ_rows: List[int] = []
        self.succ_off: List[int] = [0]
        self._succ_cache: List[Optional[List[JobInstance]]] = [None] * nj
        # pos -> (start, end) into _digall, aligned with successes(pos)
        self._dig_off: List[Optional[Tuple[int, int]]] = [None] * nj
        self._digall: Optional[np.ndarray] = None
        self.decisions: List[Optional[Tuple]] = [None] * nj
        # deferred bulk mutations & bookkeeping, in job processing order,
        # flushed by Transitioner._finalize_plan
        self.valid_bulk: List[JobInstance] = []
        self.invalid_bulk: List[JobInstance] = []
        self.inconclusive_bulk: List[JobInstance] = []
        self.finish: List[Tuple[Job, int]] = []
        self.adp_h: List[int] = []
        self.adp_v: List[int] = []
        self.adp_ok: List[bool] = []
        self.err_outcome: List[JobInstance] = []
        self.credit_entries: List[Tuple[Job, List[JobInstance], List[int]]] = []
        self.peers_cache: Dict[str, List[int]] = {}
        # defense layer (§3.4): one ((host, ver) valid pairs, invalid pairs)
        # entry per finalized decision, replayed sequentially in finalize —
        # the quota fold is order-sensitive, so replay order == scalar order
        self.defense_events: List[Tuple[List[Tuple[int, int]], List[Tuple[int, int]]]] = []

    # -- per-job views ---------------------------------------------------

    def successes(self, pos: int) -> List[JobInstance]:
        out = self._succ_cache[pos]
        if out is None:
            refs = self.refs
            out = self._succ_cache[pos] = [
                refs[r]
                for r in self.succ_rows[self.succ_off[pos]:self.succ_off[pos + 1]]
            ]
        return out

    def unsent(self, pos: int) -> List[JobInstance]:
        refs = self.refs
        st = self._st
        return [
            refs[r]
            for r in range(self.row_off[pos], self.row_off[pos + 1])
            if st[r] == 0
        ]

    def digests(self, pos: int) -> Optional[np.ndarray]:
        span = self._dig_off[pos]
        if span is None or self._digall is None:
            return None
        return self._digall[span[0]:span[1]]

    def largest_agreeing_group(self, pos: int, app: App, successes: List[JobInstance]) -> int:
        """Digest-space mirror of ``Transitioner._largest_agreeing_group``:
        max multiplicity among non-INVALID successes. Reads live
        validate_state (INIT vs INCONCLUSIVE is irrelevant here — only
        INVALID is excluded — so deferred INCONCLUSIVE writes are safe)."""
        viable = [k for k, s in enumerate(successes) if s.validate_state != ValidateState.INVALID]
        if len(viable) <= 1:
            return len(viable)
        digs = self.digests(pos)
        if digs is None:
            fn = self.engine.digest_fn(app)
            if fn is None:
                return _scalar_largest_group(app, successes)
            try:
                digs = fn([s.output for s in successes])
            except DigestError:
                return _scalar_largest_group(app, successes)
        counts = Counter(int(digs[k]) for k in viable)
        return max(counts.values())


def _scalar_largest_group(app: App, successes: List[JobInstance]) -> int:
    from .fsm import Transitioner

    return Transitioner._largest_agreeing_group(app, successes)


class BatchValidationEngine:
    """Builds a :class:`ValidationPlan` per transitioner tick."""

    def __init__(self, store: JobStore, backend: str = "numpy") -> None:
        self.store = store
        # "jax": homogeneous float tensor payload batches of fuzzy
        # comparators route through the kernels/quorum_compare Pallas
        # kernel (interpret mode on CPU); scalars/mixed payloads and every
        # other comparator keep the pure-NumPy digest path
        self.backend = backend
        self._digest_fns: Dict[str, Any] = {}

    def digest_fn(self, app: App):
        """Digest hook for ``app``'s comparator (cached), or None."""
        fn = self._digest_fns.get(app.name, _UNSET)
        if fn is _UNSET:
            fn = digest_batch_for(app.comparator)
            if fn is not None and self.backend == "jax":
                params = getattr(app.comparator, "fuzzy_params", None)
                if params is not None:
                    from .jax_backend import HAVE_JAX, fuzzy_digest_jax

                    if HAVE_JAX:
                        fn = fuzzy_digest_jax(fn, *params)
            self._digest_fns[app.name] = fn
        return fn

    # ------------------------------------------------------------------

    def prepare(
        self,
        jobs: List[Job],
        now: float,
        instance: int = 0,
        n_instances: int = 1,
        clusters: Optional[Dict[int, int]] = None,
    ) -> ValidationPlan:
        """The fused pre-pass over one tick's flagged jobs: gather, count,
        digest, group, decide. Pure — no store mutation happens here; the
        transitioner applies decisions job-by-job in its usual order so
        failure-limit checks and metrics keep exact scalar semantics.

        ``clusters`` is the defense layer's tick-start suspicion-cluster
        snapshot: a candidate whose successes include two hosts of one
        cluster is routed to the scalar ``check_set`` fallback, which
        applies the effective-quorum-size rule (same-cluster replicas are
        one vote). Everything else takes the fused digest path, whose
        group counts equal effective counts when no two members share a
        cluster.
        """
        store = self.store
        plan = ValidationPlan(self, jobs)
        nj = len(jobs)
        by_job = store._by_job
        instances = store.instances

        refs = plan.refs
        jp: List[int] = []
        sc: List[int] = []
        oc: List[int] = []
        vc: List[int] = []
        refs_append = refs.append
        jp_append = jp.append
        sc_append = sc.append
        oc_append = oc.append
        vc_append = vc.append
        for p, job in enumerate(jobs):
            for iid in by_job.get(job.id, ()):
                inst = instances[iid]
                refs_append(inst)
                jp_append(p)
                sc_append(inst.state._bv_code)
                oc_append(inst.outcome._bv_code)
                vc_append(inst.validate_state._bv_code)
        plan._st = sc

        n = len(refs)
        jparr = np.array(jp, dtype=np.int64) if n else np.zeros(0, dtype=np.int64)
        st = np.array(sc, dtype=np.int8) if n else np.zeros(0, dtype=np.int8)
        ot = np.array(oc, dtype=np.int8) if n else np.zeros(0, dtype=np.int8)
        vt = np.array(vc, dtype=np.int8) if n else np.zeros(0, dtype=np.int8)

        over = st == 2
        succ_mask = over & (ot == 1)
        err_mask = over & (ot >= 2) & (ot <= 5)
        fresh_mask = succ_mask & (vt == 0)

        n_succ = np.bincount(jparr[succ_mask], minlength=nj)
        n_fresh = np.bincount(jparr[fresh_mask], minlength=nj)
        plan.n_outstanding = np.bincount(jparr[st <= 1], minlength=nj).tolist()
        plan.n_error = np.bincount(jparr[err_mask], minlength=nj).tolist()
        plan.n_succ = n_succ.tolist()
        plan.fresh = n_fresh.tolist()
        plan.n_total = np.bincount(jparr, minlength=nj).tolist()
        plan.row_off = np.searchsorted(jparr, np.arange(nj + 1)).tolist()

        succ_rows = np.flatnonzero(succ_mask)
        succ_jobs = jparr[succ_rows]
        plan.succ_rows = succ_rows.tolist()
        plan.succ_off = np.searchsorted(succ_jobs, np.arange(nj + 1)).tolist()

        # -- candidate selection: drain the validation-pending index -------
        vp = store.pending_validation(instance, n_instances)
        has_canon = np.fromiter(
            (j.canonical_instance_id is not None for j in jobs), bool, nj
        )
        in_vp = np.fromiter((j.id in vp for j in jobs), bool, nj)
        quorum = np.fromiter((j.min_quorum for j in jobs), np.int64, nj)
        has_fresh = in_vp & (n_fresh > 0)
        candidates = ~has_canon & has_fresh & (n_succ >= quorum)
        stragglers = has_canon & has_fresh

        # -- defense work-spreading veto (§3.4): scalar-route candidates
        #    with a same-cluster success pair so effective-quorum counting
        #    applies (straggler validation has no quorum logic — fused) ----
        if clusters:
            for p in np.flatnonzero(candidates & (n_succ >= 2)).tolist():
                seen: set = set()
                for s in plan.successes(p):
                    cl = (
                        clusters.get(s.host_id)
                        if s.host_id is not None
                        else None
                    )
                    if cl is not None:
                        if cl in seen:
                            plan.decisions[p] = _SCALAR_DECISION
                            candidates[p] = False
                            break
                        seen.add(cl)

        # -- digest pass ---------------------------------------------------
        need_digest = (candidates & (n_succ >= 2)) | stragglers
        dig_pos = np.flatnonzero(need_digest)
        djob = np.zeros(0, dtype=np.int64)
        digall: Optional[np.ndarray] = None
        decisions = plan.decisions
        if dig_pos.size:
            sel = np.isin(succ_jobs, dig_pos)
            drows = succ_rows[sel].tolist()
            djob = succ_jobs[sel]
            doff = np.searchsorted(djob, np.arange(nj + 1)).tolist()
            digall = np.zeros(len(drows), dtype=np.int64)
            # batch the digest hook per app across every job of the tick
            scalar_pos: set = set()
            app_codes: Dict[str, int] = {}
            pos_code = np.fromiter(
                (
                    app_codes.setdefault(jobs[int(p)].app_name, len(app_codes))
                    for p in dig_pos
                ),
                np.int64,
                len(dig_pos),
            )
            if len(app_codes) > 1:
                counts = np.diff(np.asarray(doff))[dig_pos]
                row_app = np.repeat(pos_code, counts)
            for app_name, code in app_codes.items():
                idxs = (
                    range(len(djob))
                    if len(app_codes) == 1
                    else np.flatnonzero(row_app == code).tolist()
                )
                fn = self.digest_fn(store.apps[app_name])
                if fn is not None:
                    try:
                        digall[list(idxs)] = fn([refs[drows[k]].output for k in idxs])
                        continue
                    except DigestError:
                        pass
                for k in idxs:
                    scalar_pos.add(int(djob[k]))
            dig_off = plan._dig_off
            for p in dig_pos.tolist():
                if p in scalar_pos:
                    decisions[p] = _SCALAR_DECISION
                else:
                    dig_off[p] = (doff[p], doff[p + 1])
            plan._digall = digall

        # -- quorum/canonical decisions: one mask pass ---------------------
        # winner per job = largest (job, digest) group, ties to the group
        # whose first member appears earliest (the pinned check_set
        # grouping-order contract). Winner membership for *every* job is
        # extracted with one global boolean mask — no per-job numpy calls.
        wcount_l: List[int] = []
        members_all: List[int] = []
        moff: List[int] = []
        if digall is not None and djob.size:
            if scalar_pos:
                scal_mask = np.zeros(nj, dtype=bool)
                scal_mask[list(scalar_pos)] = True
                keep = ~scal_mask[djob]
            else:
                keep = np.ones(len(djob), dtype=bool)
            cj = djob[keep]
            cd = digall[keep]
            crow = np.flatnonzero(keep)  # kept index -> djob-space index
            if cj.size:
                order = np.lexsort((cd, cj))  # stable: ties keep success order
                js = cj[order]
                ds = cd[order]
                new = np.r_[True, (js[1:] != js[:-1]) | (ds[1:] != ds[:-1])]
                gs = np.flatnonzero(new)
                gc = np.diff(np.r_[gs, len(js)])
                gj = js[gs]
                gid = np.cumsum(new) - 1  # group id per sorted row
                gfirst = crow[order[gs]]  # earliest success row of each group
                worder = np.lexsort((gfirst, -gc, gj))
                uj, first = np.unique(gj[worder], return_index=True)
                winner_g = worder[first]  # winning group per job (aligned uj)
                wcount = np.zeros(nj, dtype=np.int64)
                wcount[uj] = gc[winner_g]
                wcount_l = wcount.tolist()
                win_of_job = np.full(nj, -1, dtype=np.int64)
                win_of_job[uj] = winner_g
                winner_mask = win_of_job[js] == gid
                # djob-space indices of winner members, ascending within
                # each job (stable lexsort keeps success order inside runs)
                members_all = crow[order[winner_mask]].tolist()
                moff = np.searchsorted(
                    js[winner_mask], np.arange(nj + 1)
                ).tolist()

        n_succ_l = plan.n_succ
        dig_off = plan._dig_off
        for p in np.flatnonzero(candidates).tolist():
            if decisions[p] is not None:  # scalar fallback
                continue
            q = quorum[p]
            succ = plan.successes(p)
            if n_succ_l[p] == 1:
                # a lone success trivially forms the (only) group
                decisions[p] = (
                    (DECIDED, succ[0], succ, _EMPTY) if 1 >= q
                    else _INCONCLUSIVE_DECISION
                )
                continue
            g_count = wcount_l[p] if wcount_l else 0
            if g_count == 0:
                continue
            if g_count < q:
                decisions[p] = _INCONCLUSIVE_DECISION
                continue
            span = dig_off[p]
            if g_count == span[1] - span[0]:
                decisions[p] = (DECIDED, succ[0], succ, _EMPTY)
            else:
                o1 = span[0]
                vset = set(members_all[moff[p]:moff[p + 1]])
                valid: List[JobInstance] = []
                invalid: List[JobInstance] = []
                for k in range(len(succ)):
                    (valid if k + o1 in vset else invalid).append(succ[k])
                decisions[p] = (DECIDED, valid[0], valid, invalid)

        return plan


_UNSET = object()
_EMPTY: List[JobInstance] = []
_SCALAR_DECISION = (SCALAR, None, None, None)
_INCONCLUSIVE_DECISION = (INCONCLUSIVE, None, None, None)
