"""JAX execution backend for the batch engines (ROADMAP item 1).

Every batch engine (dispatch scoring, the slot-major WRR client passes,
world accrual/completion) runs on NumPy by default. This module provides
the ``backend="jax"`` execution path behind the *same* engine interfaces:
the dense O(slots)/O(J·H)/O(Q·H) inner passes run as ``jax.jit`` kernels,
while the sparse host-side tails (group resolution, lexsort ordering,
per-row locality adjustments, REC debits) stay on the oracle's exact
NumPy/Python code. The contract is the repo's standing one, extended one
level: scalar oracle ⇒ NumPy engine ⇒ JAX engine, *bit-identical* —
asserted whole-run by the 4th parity axis in ``core/scenarios.run_parity``.

Bit-identity on XLA:CPU is not free. XLA's CPU emitter lets LLVM contract
``mul`` feeding ``add``/``sub`` inside one fusion into an FMA (the product
is never rounded), which breaks last-bit identity with NumPy f64 — and in
jax 0.4.x no flag (``--xla_allow_excess_precision=false``,
``--xla_cpu_enable_fast_math=false``, ``--xla_backend_optimization_level=0``)
or ``lax.optimization_barrier`` blocks it: barriers are elided before the
fusion is emitted. What *does* hold bit-identical inside a single jit
(probed empirically, pinned by ``tests/test_jax_backend.py``):

  * elementwise mul, div, sub, compares, ``where``/min/max, boolean logic,
    gathers/scatters;
  * add/sub chains whose operands are **not** un-materialized products
    (sequential row folds, ``fori_loop`` accumulator carries);
  * mul by an exactly-representable power of two feeding an add (the
    product is exact, so contraction cannot change the result).

So every kernel here is **staged**: multiplies that feed accumulations run
in their own jit (the dispatch boundary materializes the rounded product),
and the adds run in a second jit. See the per-field tolerance table in
``docs/ARCHITECTURE.md`` ("execution backends") — with the staging in
place every mirrored field is in the "bit-identical" row; f32 rows apply
only to the Pallas ``quorum_compare`` digest path, which casts payloads to
f32 by design (kernel contract) and is therefore gated to payloads whose
agreement/disagreement is far from the tolerance boundary (the digest
contract ``core/validator.py`` already documents).

Shapes are padded to power-of-two buckets so jit retraces stay O(log n)
per call site. Padding lanes are neutralized (masks forced False, scatter
indices out of range with ``mode="drop"``), never observable.
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

try:  # pragma: no cover - exercised only when jax is absent
    import jax

    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from jax import lax

    HAVE_JAX = True
except Exception:  # pragma: no cover
    jax = None  # type: ignore[assignment]
    jnp = None  # type: ignore[assignment]
    lax = None  # type: ignore[assignment]
    HAVE_JAX = False

BACKENDS = ("numpy", "jax")

# CPU XLA may decline buffer donation; the fallback copy is correct, the
# warning is noise at one-per-jit-call volume.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable"
)


def resolve_backend(backend: str) -> str:
    """Validate a ``backend=`` engine argument; ``"jax"`` requires jax."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if backend == "jax" and not HAVE_JAX:
        raise RuntimeError(
            "backend='jax' requested but jax is not importable in this "
            "environment; install jax[cpu] or use backend='numpy'"
        )
    return backend


def _bucket(n: int, lo: int = 8) -> int:
    """Next power-of-two ≥ max(n, lo): bounds jit retraces per call site."""
    b = lo
    while b < n:
        b <<= 1
    return b


if HAVE_JAX:

    # ------------------------------------------------------------------
    # dispatch kernels (core/batch_dispatch.candidate_rows)
    # ------------------------------------------------------------------

    @jax.jit
    def _k_elig(valid, target, start, host_id):
        # rotated-scan eligibility: slot j of the output corresponds to
        # feeder position (start + j) % n, exactly the scalar scan order
        v = jnp.roll(valid, -start)
        t = jnp.roll(target, -start)
        return v & ((t < 0) | (t == host_id))

    @jax.jit
    def _k_group_mask(g_ok_inv, hr_rep, host_hr_rep, kok):
        hr_ok = (hr_rep == -1) | (hr_rep == host_hr_rep)
        return g_ok_inv & hr_ok & kok

    @jax.jit
    def _k_score_terms(kvec, bal, prio, skips, w_kw, w_bal, w_pr, w_sk):
        # multiplies only — the jit boundary materializes each rounded
        # product before the sum stage can see it (FMA staging contract)
        return (
            w_kw * kvec,
            w_bal * bal,
            w_pr * prio,
            w_sk * jnp.minimum(skips, 5.0),
        )

    @jax.jit
    def _k_score_sum3(t_kw, t_pr, t_sk):
        return (t_kw + t_pr) + t_sk

    @jax.jit
    def _k_score_sum4(t_kw, t_bal, t_pr, t_sk):
        return ((t_kw + t_bal) + t_pr) + t_sk

    @jax.jit
    def _k_est_scaled(flop, pf, avail):
        est = jnp.where(pf > 0.0, flop / pf, jnp.inf)
        scaled = jnp.where(avail > 0.0, est / avail, jnp.inf)
        return est, scaled

    # ------------------------------------------------------------------
    # client kernels (core/batch_client slot-major greedy passes)
    # ------------------------------------------------------------------

    @jax.jit
    def _k_run_set_greedy(
        live_s, cu_s, wss_s, gpu_s, nci_s, u_stack, has_stack, nins_stack,
        ram0, rhs1, rhs2,
    ):
        # §6.1 greedy maximal feasible set, one rank per fori step; every
        # op is add/sub/compare/where on materialized carries — no muls,
        # so a single jit is bit-identical to the NumPy rank loop
        J, H = live_s.shape
        R = u_stack.shape[0]

        def body(r, carry):
            cap, cpu_cpu, cpu_all, ram_left, chosen = carry
            lv = live_s[r]
            cu = cu_s[r]
            gpu_r = gpu_s[r]
            feas = lv
            for i in range(R):
                u = u_stack[i, r]
                bad = (cap[i] < u - 1e-12) & (u > 0.0)
                feas = feas & ~bad
            feas = feas & ~((~gpu_r) & ((cpu_cpu + cu) > rhs1))
            feas = feas & ((cpu_all + cu) <= rhs2)
            feas = feas & (wss_s[r] <= ram_left)
            feas = feas | (nci_s[r] & lv)
            chosen = chosen.at[r].set(feas)
            for i in range(R):
                sel = feas & has_stack[i]
                cap = cap.at[i].set(jnp.where(sel, cap[i] - u_stack[i, r], cap[i]))
            cpu_cpu = jnp.where(feas & ~gpu_r, cpu_cpu + cu, cpu_cpu)
            cpu_all = jnp.where(feas, cpu_all + cu, cpu_all)
            ram_left = jnp.where(feas, ram_left - wss_s[r], ram_left)
            return cap, cpu_cpu, cpu_all, ram_left, chosen

        init = (
            nins_stack,
            jnp.zeros(H),
            jnp.zeros(H),
            ram0,
            jnp.zeros((J, H), dtype=bool),
        )
        return lax.fori_loop(0, J, body, init)[4]

    @jax.jit
    def _k_wrr_greedy(
        order_live, active, u_stack, ueps_stack, uzero_stack, wss_w,
        has_stack, nins_stack, ram,
    ):
        # WRR-order greedy under per-resource caps + RAM (the event-loop
        # feasibility pass). No muls; single jit is bit-identical.
        J, H = order_live.shape
        R = u_stack.shape[0]

        def body(k, carry):
            cap, ram_left, running = carry
            feas = order_live[k] & active
            for i in range(R):
                feas = feas & ((cap[i] >= ueps_stack[i, k]) | uzero_stack[i, k])
            feas = feas & (wss_w[k] <= ram_left)
            running = running.at[k].set(feas)
            for i in range(R):
                sel = feas & has_stack[i]
                cap = cap.at[i].set(jnp.where(sel, cap[i] - u_stack[i, k], cap[i]))
            ram_left = jnp.where(feas, ram_left - wss_w[k], ram_left)
            return cap, ram_left, running

        init = (nins_stack, ram, jnp.zeros((J, H), dtype=bool))
        cap, _, running = lax.fori_loop(0, J, body, init)
        return running, cap

    # ------------------------------------------------------------------
    # world kernels (core/world accrual + completion masks)
    # ------------------------------------------------------------------

    @partial(jax.jit, static_argnums=(0,))
    def _k_advance1(k, q_total, q_runtime, q_frac, q_running, idx, lane, dts):
        # gather + clamped accrual; the only arithmetic is sub/div/where,
        # none of which XLA can contract — single jit, bit-identical.
        # Only the first k queue rows (the occupied depth, power-of-two
        # bucketed by the caller) are gathered: rows >= k have
        # q_running == False everywhere, so skipping them is a no-op the
        # NumPy K-loop also takes.
        tot = q_total[:k, idx]
        run = q_runtime[:k, idx]
        frac = q_frac[:k, idx]
        m = q_running[:k, idx] & lane[None, :]
        rem = tot - run
        rem = jnp.where(rem < 0.0, 0.0, rem)
        d2 = jnp.broadcast_to(dts[None, :], tot.shape)
        eff = jnp.where(d2 < rem, d2, rem)
        eff = jnp.where(m, eff, 0.0)
        run2 = jnp.where(m, run + eff, run)
        denom = jnp.where(tot > 1e-9, tot, 1e-9)
        fr = run2 / denom
        fr = jnp.where(fr > 1.0, 1.0, fr)
        frac2 = jnp.where(m, fr, frac)
        return m, run2, frac2, eff

    @partial(jax.jit, static_argnums=(0,))
    def _k_products(k, q_cpu, q_weight, idx, eff):
        # the accrual charge products — staged alone so the downstream
        # accumulation jits see rounded (materialized) products, never an
        # LLVM-contracted FMA
        return eff * q_cpu[:k, idx], eff * q_weight[:k, idx]

    @jax.jit
    def _k_fold(m, binc, winc, busy_sub):
        # row-sequential accumulation in queue-row order, matching the
        # scalar/NumPy per-row loop; adds only
        Q = m.shape[0]

        def body(k, carry):
            busy, debit = carry
            busy = jnp.where(m[k], busy + binc[k], busy)
            debit = jnp.where(m[k], debit + winc[k], debit)
            return busy, debit

        init = (busy_sub, jnp.zeros(m.shape[1]))
        return lax.fori_loop(0, Q, body, init)

    @jax.jit
    def _k_gather_busy(busy, idx):
        return busy[idx]

    def _k_scatter(q_runtime, q_frac, busy, idx, run2, frac2, busy_sub):
        # pad lanes carry idx == n_cols (out of range): mode="drop";
        # row extent comes from run2's (k-sliced) shape
        k = run2.shape[0]
        q_runtime = q_runtime.at[:k, idx].set(run2, mode="drop")
        q_frac = q_frac.at[:k, idx].set(frac2, mode="drop")
        busy = busy.at[idx].set(busy_sub, mode="drop")
        return q_runtime, q_frac, busy

    _k_scatter = jax.jit(_k_scatter, donate_argnums=(0, 1, 2))

    @jax.jit
    def _k_completed(q_running, q_runtime, q_total, idx, counts):
        m = q_running[:, idx]
        run = q_runtime[:, idx]
        tot = q_total[:, idx]
        Q = m.shape[0]
        rowmask = jnp.arange(Q)[:, None] < counts[None, :]
        return m & (run >= tot - 1e-6) & rowmask

    @jax.jit
    def _k_col_upload(dev, host_vals, cols):
        return dev.at[:, cols].set(host_vals)

    @jax.jit
    def _k_vec_upload(dev, host_vals, cols):
        return dev.at[cols].set(host_vals)


# ----------------------------------------------------------------------
# dispatch wrappers
# ----------------------------------------------------------------------


def dispatch_elig(valid: np.ndarray, target: np.ndarray, start: int,
                  host_id: int) -> np.ndarray:
    """Rotated-scan eligibility mask on device; entry j refers to feeder
    position ``(start + j) % n`` (the caller's ``rot`` order)."""
    return np.asarray(_k_elig(valid, target, start, host_id))


def dispatch_group_mask(g_ok_inv: np.ndarray, hr_rep: np.ndarray,
                        host_hr_rep: np.ndarray, kok: np.ndarray) -> np.ndarray:
    return np.asarray(_k_group_mask(g_ok_inv, hr_rep, host_hr_rep, kok))


def dispatch_scores(
    kvec: np.ndarray,
    bal: Optional[np.ndarray],
    prio: np.ndarray,
    skips: np.ndarray,
    flop: np.ndarray,
    pf: np.ndarray,
    avail: float,
    weights: Tuple[float, float, float, float],
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """§6.4 base score + runtime estimates for the masked candidate set.

    Staged: the four weighted terms are products in one jit, the sum runs
    in a second jit in the NumPy engine's exact accumulation order
    (``t_kw (+ t_bal) + t_pr + t_sk``); the sparse locality / size-match
    adjustments stay host-side in the caller. Returns (scores, est, scaled).
    """
    w_kw, w_bal, w_pr, w_sk = weights
    M = kvec.shape[0]
    P = _bucket(M)

    def pad(a):
        out = np.zeros(P, dtype=np.float64)
        out[:M] = a
        return out

    has_bal = bal is not None
    t_kw, t_bal, t_pr, t_sk = _k_score_terms(
        pad(kvec), pad(bal) if has_bal else np.zeros(P), pad(prio),
        pad(skips), w_kw, w_bal, w_pr, w_sk,
    )
    if has_bal:
        scores = _k_score_sum4(t_kw, t_bal, t_pr, t_sk)
    else:
        scores = _k_score_sum3(t_kw, t_pr, t_sk)
    est, scaled = _k_est_scaled(pad(flop), pad(pf), avail)
    return (
        np.asarray(scores)[:M].copy(),
        np.asarray(est)[:M].copy(),
        np.asarray(scaled)[:M].copy(),
    )


# ----------------------------------------------------------------------
# client wrappers
# ----------------------------------------------------------------------


def run_set_greedy(
    live_s: np.ndarray,
    cu_s: np.ndarray,
    wss_s: np.ndarray,
    gpu_s: np.ndarray,
    nci_s: np.ndarray,
    u_s: Dict,
    has: Dict,
    nins: Dict,
    ram0: np.ndarray,
    rhs1: np.ndarray,
    rhs2: np.ndarray,
) -> np.ndarray:
    """JAX run of ``BatchClientEngine._run_set_pass``'s greedy rank loop.

    ``u_s``/``has``/``nins`` are keyed by the non-CPU resource types in the
    snapshot's iteration order (the order the NumPy loop visits them).
    ``ram0`` is the host-side ``ram * ram_frac`` product — computed by the
    caller in NumPy so the in-loop RAM subtractions never share a jit with
    the multiply. Returns the chosen [J, H] mask.
    """
    J, H = live_s.shape
    JP, HP = _bucket(J), _bucket(H)
    rts = list(u_s)
    R = len(rts)

    def pad2(a, dtype=np.float64):
        out = np.zeros((JP, HP), dtype=dtype)
        out[:J, :H] = a
        return out

    def pad1(a, dtype=np.float64):
        out = np.zeros(HP, dtype=dtype)
        out[:H] = a
        return out

    u_stack = np.zeros((R, JP, HP))
    has_stack = np.zeros((R, HP), dtype=bool)
    nins_stack = np.zeros((R, HP))
    for i, rt in enumerate(rts):
        u_stack[i, :J, :H] = u_s[rt]
        has_stack[i, :H] = has[rt]
        nins_stack[i, :H] = nins[rt]

    chosen = _k_run_set_greedy(
        pad2(live_s, bool), pad2(cu_s), pad2(wss_s), pad2(gpu_s, bool),
        pad2(nci_s, bool), u_stack, has_stack, nins_stack,
        pad1(ram0), pad1(rhs1), pad1(rhs2),
    )
    return np.asarray(chosen)[:J, :H]


class WRRGreedyContext:
    """Device-resident WRR inputs for one ``_wrr_raw`` call: the static
    per-event arrays (usage, thresholds, caps, RAM) are uploaded once and
    each event's greedy pass runs as one jit over them."""

    def __init__(self, s, u_w: Dict, u_eps: Dict, u_zero: Dict,
                 wss_w: np.ndarray) -> None:
        J, H = s.J, s.H
        self.J, self.H = J, H
        self.JP, self.HP = _bucket(J), _bucket(H)
        self.rtypes = list(s.rtypes)
        R = len(self.rtypes)

        u_stack = np.zeros((R, self.JP, self.HP))
        ueps_stack = np.full((R, self.JP, self.HP), -1e-12)
        uzero_stack = np.ones((R, self.JP, self.HP), dtype=bool)
        has_stack = np.zeros((R, self.HP), dtype=bool)
        nins_stack = np.zeros((R, self.HP))
        for i, rt in enumerate(self.rtypes):
            u_stack[i, :J, :H] = u_w[rt]
            ueps_stack[i, :J, :H] = u_eps[rt]
            uzero_stack[i, :J, :H] = u_zero[rt]
            has_stack[i, :H] = s.has[rt]
            nins_stack[i, :H] = s.nins[rt]
        wss = np.zeros((self.JP, self.HP))
        wss[:J, :H] = wss_w
        ram = np.zeros(self.HP)
        ram[:H] = s.ram

        self._u = jnp.asarray(u_stack)
        self._ueps = jnp.asarray(ueps_stack)
        self._uzero = jnp.asarray(uzero_stack)
        self._has = jnp.asarray(has_stack)
        self._nins = jnp.asarray(nins_stack)
        self._wss = jnp.asarray(wss)
        self._ram = jnp.asarray(ram)

    def greedy(self, order_live: np.ndarray, active: np.ndarray):
        """One greedy maximal-set pass; returns (running [J,H], caps dict)."""
        J, H = self.J, self.H
        ol = np.zeros((self.JP, self.HP), dtype=bool)
        ol[:J, :H] = order_live
        act = np.zeros(self.HP, dtype=bool)
        act[:H] = active
        running, cap = _k_wrr_greedy(
            ol, act, self._u, self._ueps, self._uzero, self._wss,
            self._has, self._nins, self._ram,
        )
        running = np.asarray(running)[:J, :H]
        cap_np = np.asarray(cap)[:, :H]
        return running, {rt: cap_np[i].copy() for i, rt in enumerate(self.rtypes)}


# ----------------------------------------------------------------------
# world device mirror (core/world.HostArrays, backend="jax")
# ----------------------------------------------------------------------


class WorldDeviceMirror:
    """Device-resident mirrors of the accrual-relevant ``HostArrays``
    columns, with a dirty-range upload contract.

    Upload direction (host → device): mutation hooks mark the touched
    dense slot (``HostArrays._touch``); before each device pass only the
    dirty slots' columns are re-uploaded. Array growth or compaction
    reallocates host storage, so a shape change forces a full re-upload
    (``all_dirty``). Compute direction: the accrual pass updates
    ``q_runtime``/``q_frac``/``busy`` on device with donated buffers and
    writes the touched slice back to the host arrays, so host and device
    stay equal after every pass (asserted by the dirty-upload regression
    tests).
    """

    _COLS = ("q_total", "q_runtime", "q_frac", "q_weight")

    def __init__(self) -> None:
        self._shape: Optional[Tuple[int, int]] = None
        self.all_dirty = True
        self.dirty: set = set()
        self.q_total = None
        self.q_runtime = None
        self.q_frac = None
        self.q_running = None
        self.q_weight = None
        self.q_cpu = None
        self.busy = None

    # -- upload ---------------------------------------------------------

    def mark(self, slot: int) -> None:
        self.dirty.add(slot)

    def sync(self, world) -> None:
        """Apply the dirty-range upload contract against ``world``."""
        from .types import ResourceType

        cpu_u = world.q_usage[ResourceType.CPU]
        shape = cpu_u.shape
        if self._shape != shape or self.all_dirty:
            self.q_total = jnp.asarray(world.q_total)
            self.q_runtime = jnp.asarray(world.q_runtime)
            self.q_frac = jnp.asarray(world.q_frac)
            self.q_running = jnp.asarray(world.q_running)
            self.q_weight = jnp.asarray(world.q_weight)
            self.q_cpu = jnp.asarray(cpu_u)
            self.busy = jnp.asarray(world.busy)
            self._shape = shape
            self.all_dirty = False
            self.dirty.clear()
            return
        if not self.dirty:
            return
        cols = np.fromiter(sorted(self.dirty), np.int64, len(self.dirty))
        cj = jnp.asarray(cols)
        self.q_total = _k_col_upload(self.q_total, world.q_total[:, cols], cj)
        self.q_runtime = _k_col_upload(self.q_runtime, world.q_runtime[:, cols], cj)
        self.q_frac = _k_col_upload(self.q_frac, world.q_frac[:, cols], cj)
        self.q_running = _k_col_upload(self.q_running, world.q_running[:, cols], cj)
        self.q_weight = _k_col_upload(self.q_weight, world.q_weight[:, cols], cj)
        self.q_cpu = _k_col_upload(self.q_cpu, cpu_u[:, cols], cj)
        self.busy = _k_vec_upload(self.busy, world.busy[cols], cj)
        self.dirty.clear()

    # -- compute --------------------------------------------------------

    def advance(self, world, sub: np.ndarray, dts: np.ndarray):
        """Device accrual pass over the active host slots ``sub``; returns
        the per-slot REC debit totals and the touched mask, after writing
        the updated runtime/fraction/busy columns back to ``world``."""
        self.sync(world)
        S = len(sub)
        P = _bucket(S)
        n_cols = self._shape[1]
        # occupied queue depth, bucketed: rows >= K are all-False q_running
        # for the active slots, so the device pass skips them just as the
        # NumPy K-loop does
        K = min(_bucket(int(world.q_count[sub].max()), lo=1), self._shape[0])
        idx = np.full(P, n_cols, dtype=np.int64)  # out-of-range pad → drop
        idx[:S] = sub
        lane = np.zeros(P, dtype=bool)
        lane[:S] = True
        dts_p = np.zeros(P)
        dts_p[:S] = dts
        idx_j = jnp.asarray(idx)

        m, run2, frac2, eff = _k_advance1(
            K, self.q_total, self.q_runtime, self.q_frac, self.q_running,
            idx_j, jnp.asarray(lane), jnp.asarray(dts_p),
        )
        binc, winc = _k_products(K, self.q_cpu, self.q_weight, idx_j, eff)
        busy_sub, debit = _k_fold(m, binc, winc, _k_gather_busy(self.busy, idx_j))
        self.q_runtime, self.q_frac, self.busy = _k_scatter(
            self.q_runtime, self.q_frac, self.busy, idx_j, run2, frac2, busy_sub,
        )

        m_np = np.asarray(m)[:, :S]
        world.q_runtime[:K, sub] = np.asarray(run2)[:, :S]
        world.q_frac[:K, sub] = np.asarray(frac2)[:, :S]
        world.busy[sub] = np.asarray(busy_sub)[:S]
        return np.asarray(debit)[:S].copy(), m_np.any(axis=0)

    def completed_mask(self, world, idx: np.ndarray,
                       counts: np.ndarray) -> np.ndarray:
        """Completion mask over the device accrual matrix for slots ``idx``
        (rows ≥ each host's queue count masked out), downloaded as bool."""
        self.sync(world)
        S = len(idx)
        P = _bucket(S)
        n_cols = self._shape[1]
        ip = np.full(P, n_cols - 1, dtype=np.int64)
        ip[:S] = idx
        cp = np.zeros(P, dtype=np.int64)  # pad lanes: count 0 → all rows masked
        cp[:S] = counts
        out = _k_completed(
            self.q_running, self.q_runtime, self.q_total,
            jnp.asarray(ip), jnp.asarray(cp),
        )
        return np.asarray(out)[:, :S]


# ----------------------------------------------------------------------
# Pallas quorum_compare digest routing (core/batch_validate, backend="jax")
# ----------------------------------------------------------------------


def quorum_group_codes(mat: np.ndarray, rtol: float, atol: float,
                       interpret: bool = True) -> np.ndarray:
    """Group codes for a homogeneous (n, d) float payload matrix via the
    ``kernels/quorum_compare`` Pallas kernel (interpret mode on CPU).

    Greedy first-match grouping: row i joins the first group whose
    representative it agrees with (kernel verdict ``n_bad == 0`` under the
    comparator's tolerances), else it founds a new group. Under the digest
    contract (replicas either agree well within tolerance or disagree far
    outside it) this partition equals the scalar comparator's greedy
    pairwise grouping. The kernel compares in f32 — another reason the
    far-from-boundary contract is load-bearing. NaN-carrying rows match
    nothing (kernel predicate is False for NaN, which would read as
    agreement) and get unique sentinels, mirroring ``_fuzzy_digest_*``.
    """
    from ..kernels.quorum_compare.ops import quorum_compare
    from .validator import _nan_sentinel

    n = mat.shape[0]
    codes = np.zeros(n, dtype=np.int64)
    reps: List[int] = []
    nan_rows = np.isnan(mat).any(axis=1)
    for i in range(n):
        if nan_rows[i]:
            codes[i] = _nan_sentinel()
            continue
        assigned = False
        for g, r in enumerate(reps):
            n_bad, _ = quorum_compare(
                mat[i], mat[r], rtol=rtol, atol=atol, interpret=interpret
            )
            if int(n_bad) == 0:
                codes[i] = g
                assigned = True
                break
        if not assigned:
            reps.append(i)
            codes[i] = len(reps) - 1
    return codes


def fuzzy_digest_jax(base, rtol: float, atol: float):
    """Wrap a fuzzy comparator's digest hook: homogeneous float tensor
    payload batches route through the Pallas kernel grouping; everything
    else (plain floats, mixed payloads) falls through to ``base``."""
    from .validator import _homogeneous_arrays

    def fn(outputs: Sequence) -> np.ndarray:
        if len(outputs) >= 2 and isinstance(outputs[0], np.ndarray):
            mat = _homogeneous_arrays(outputs)
            if mat is not None and mat.dtype.kind == "f":
                return quorum_group_codes(mat, rtol, atol)
        return base(outputs)

    return fn
