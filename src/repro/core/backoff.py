"""Exponential backoff for all client/server interactions (§2.2).

"All client/server interactions handle failure using exponential back-off in
order to limit the rate of requests when a server resumes after a period of
being off-line."
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field


@dataclass
class ExponentialBackoff:
    """Retry controller with exponential backoff and uniform jitter.

    ``register_failure(now)`` schedules the next permissible attempt;
    ``register_success()`` resets. ``ready(now)`` gates RPC issue.
    """

    min_interval: float = 60.0
    max_interval: float = 4 * 3600.0
    multiplier: float = 2.0
    jitter: float = 0.2  # +/- fraction of the interval
    seed: int = 0

    n_failures: int = 0
    next_time: float = 0.0
    _rng: random.Random = field(default=None, repr=False)  # type: ignore[assignment]

    def __post_init__(self) -> None:
        self._rng = random.Random(self.seed)

    def ready(self, now: float) -> bool:
        return now >= self.next_time

    def current_interval(self) -> float:
        if self.n_failures == 0:
            return 0.0
        raw = self.min_interval * (self.multiplier ** (self.n_failures - 1))
        return min(raw, self.max_interval)

    def register_failure(self, now: float) -> float:
        """Record a failed attempt; returns the scheduled retry time."""
        self.n_failures += 1
        interval = self.current_interval()
        if self.jitter > 0.0:
            lo = 1.0 - self.jitter
            hi = 1.0 + self.jitter
            interval *= self._rng.uniform(lo, hi)
        self.next_time = now + interval
        return self.next_time

    def register_success(self) -> None:
        self.n_failures = 0
        self.next_time = 0.0
