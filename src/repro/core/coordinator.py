"""The coordinated volunteer-computing model (§10.1): Science United.

"volunteers register for scientific areas (using the keyword mechanism)
rather than for specific projects. SU dynamically attaches hosts to projects
based on these science preferences. ... SU has a mechanism (based on the
linear-bounded model) for allocating computing power among projects. This
means that a prospective new project can be guaranteed a certain amount of
computing power before any investment is made."

Implemented as an account manager (§2.3): clients attach to the coordinator;
the AM reply tells them which vetted projects to attach/detach. Allocation
shares drive a linear-bounded balance per project; hosts are (re)assigned to
the highest-balance project whose keywords pass the volunteer's prefs.

In the TPU adaptation this is the multi-tenant fleet coordinator: "projects"
are experiments/teams with guaranteed shares; "science keywords" are
workload/capability tags.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .allocation import LinearBoundedAllocator
from .client import Client, ProjectAttachment
from .keywords import KeywordPrefs, keyword_score
from .types import ResourceType


@dataclass
class VettedProject:
    """A project registered with the coordinator (§10.1: 'vetted by SU')."""

    name: str
    keywords: Tuple[str, ...]
    share: float = 1.0  # guaranteed relative allocation
    resource_types: Tuple[ResourceType, ...] = (ResourceType.CPU,)


@dataclass
class AMReply:
    attach: List[ProjectAttachment]
    detach: List[str]


@dataclass
class Coordinator:
    """Science United: keyword-driven host->project assignment with
    linear-bounded power allocation."""

    projects: Dict[str, VettedProject] = field(default_factory=dict)
    allocator: LinearBoundedAllocator = field(
        default_factory=lambda: LinearBoundedAllocator(default_cap=24 * 3600.0)
    )
    # volunteer_id -> keyword prefs
    volunteer_prefs: Dict[int, KeywordPrefs] = field(default_factory=dict)
    # host -> currently assigned project
    assignments: Dict[int, str] = field(default_factory=dict)

    def vet_project(self, project: VettedProject, now: float = 0.0) -> None:
        self.projects[project.name] = project
        self.allocator.ensure(project.name, now).rate = project.share

    def register_volunteer(self, volunteer_id: int, prefs: KeywordPrefs) -> None:
        self.volunteer_prefs[volunteer_id] = prefs

    # ------------------------------------------------------------------

    def eligible_projects(self, volunteer_id: int) -> List[str]:
        """Projects whose keywords pass the volunteer's yes/no marks."""
        prefs = self.volunteer_prefs.get(volunteer_id, KeywordPrefs())
        out = []
        for name, p in self.projects.items():
            score = keyword_score(p.keywords, prefs)
            if score is None:
                continue  # "no" keyword: never assign (§2.4)
            out.append((score, name))
        out.sort(key=lambda t: (-t[0], t[1]))
        return [n for _, n in out]

    def am_rpc(self, host_id: int, volunteer_id: int, now: float,
               used_seconds: float = 0.0) -> AMReply:
        """Periodic AM RPC (§2.3): returns attach/detach directives.

        ``used_seconds`` reports computing done for the current assignment
        since the last RPC; it debits the project's allocation balance so
        power is shared per the linear-bounded model.
        """
        current = self.assignments.get(host_id)
        if current is not None and used_seconds > 0:
            self.allocator.debit(current, used_seconds, now)

        eligible = self.eligible_projects(volunteer_id)
        if not eligible:
            if current is not None:
                del self.assignments[host_id]
                return AMReply(attach=[], detach=[current])
            return AMReply(attach=[], detach=[])

        # highest-balance eligible project wins (§3.9 / §10.1)
        best = max(eligible, key=lambda n: self.allocator.balance(n, now))
        if best == current:
            return AMReply(attach=[], detach=[])
        detach = [current] if current else []
        self.assignments[host_id] = best
        p = self.projects[best]
        return AMReply(
            attach=[
                ProjectAttachment(name=best, resource_types=p.resource_types)
            ],
            detach=detach,
        )

    # ------------------------------------------------------------------

    def forget_host(self, host_id: int) -> Optional[str]:
        """Purge a departed host's assignment row (churn hygiene).

        Without this, a churned host stays in ``assignments`` forever:
        ``attached_hosts`` keeps reporting it, so a project's apparent
        fleet never shrinks, and long-churn coordinated runs leak one row
        per departed host. Returns the project the host was assigned to
        (None if unassigned) so callers can surface a detach if the host
        ever reappears. The volunteer's prefs are *not* touched — a
        volunteer outlives any one host (§2.3) and may attach new ones.
        """
        return self.assignments.pop(host_id, None)

    def forget_volunteer(self, volunteer_id: int) -> None:
        """Drop a volunteer's keyword prefs (account deletion, §2.3)."""
        self.volunteer_prefs.pop(volunteer_id, None)

    def attached_hosts(self, project: str) -> List[int]:
        return [h for h, p in self.assignments.items() if p == project]

    def guaranteed_share(self, project: str) -> float:
        total = sum(p.share for p in self.projects.values())
        return self.projects[project].share / total if total else 0.0
