"""BOINC core middleware: the paper's primary contribution, in Python/JAX.

Layout (paper section in parens):
  types        — projects/hosts/apps/app-versions/plan-classes/jobs (§2, §3)
  backoff      — exponential backoff (§2.2)
  keywords     — keyword hierarchies & prefs (§2.4)
  store        — the job database + ID-space daemon sharding (§5.1)
  fsm          — transitioner: job lifecycle FSM (§4)
  validator    — replication validation, HR classes, payload digests (§3.4)
  adaptive     — adaptive replication reputations, array-backed (§3.4)
  batch_validate — vectorized validation→credit→reputation engine (§3.4, §7)
  estimation   — runtime estimation / proj_flops (§6.3)
  credit       — PFC credit + normalizations + cross-project (§7)
  allocation   — linear-bounded allocation model (§3.9)
  defense      — work-spreading / HR census / host punishment (§3.4)
  scheduler    — feeder, job cache, dispatch policy (§5.1, §6.4)
  shard        — host→shard affinity, cache-slot ownership, migration (§5.1)
  batch_dispatch — vectorized slots×hosts batch scoring engine (§5.1, §6.4)
  client       — WRR/EDF resource scheduling + work fetch (§6.1–6.2)
  batch_client — vectorized host-population client engine (§6.1–6.2, §9)
  server       — project-server facade w/ daemon set (§5.1)
  simulator    — EmBOINC-style virtual-time emulator (§9)
  scenarios    — trace-driven & adversarial scenario generation (§3.4, §9)
"""
from .adaptive import AdaptiveReplication
from .allocation import LinearBoundedAllocator
from .backoff import ExponentialBackoff
from .batch_client import BatchClientEngine
from .batch_dispatch import BatchDispatchEngine
from .batch_validate import BatchValidationEngine
from .client import Client, ClientJob, ClientPrefs, ClientResource, ProjectAttachment
from .coordinator import AMReply, Coordinator, VettedProject
from .credit import CreditSystem, peak_flop_count
from .defense import DefenseLayer, DefensePolicy
from .estimation import RuntimeEstimator
from .fsm import Transitioner
from .keywords import KeywordPrefs, keyword_score
from .scheduler import (
    Candidate,
    CompletedResult,
    Feeder,
    ResourceRequest,
    ScheduleReply,
    ScheduleRequest,
    Scheduler,
)
from .scenarios import (
    Clique,
    CreditFarm,
    Outage,
    ScenarioResult,
    ScenarioSpec,
    Sybil,
    TraceReplay,
    generate_population,
    run_parity,
    run_spec,
    sybil_identity_ids,
)
from .server import ProjectServer
from .shard import ShardMap, ShardPolicy, ShardStats
from .simulator import GridSimulation, HostSpec, make_population
from .world import ExpDrawCache, HostArrays
from .store import JobStore
from .types import (
    App,
    AppVersion,
    Batch,
    HRLevel,
    Host,
    InstanceOutcome,
    InstanceState,
    Job,
    JobInstance,
    JobState,
    Platform,
    PlanClass,
    ProcessingResource,
    ResourceType,
    ValidateState,
    default_cpu_plan_class,
    gpu_plan_class,
    hr_class,
    next_id,
    reset_ids,
)
from .validator import (
    bitwise_digest_batch,
    bitwise_equal,
    check_set,
    digest_batch_for,
    fuzzy_comparator,
)

__all__ = [
    "AdaptiveReplication",
    "App",
    "AppVersion",
    "Batch",
    "BatchClientEngine",
    "BatchDispatchEngine",
    "BatchValidationEngine",
    "Candidate",
    "Client",
    "ClientJob",
    "ClientPrefs",
    "ClientResource",
    "Clique",
    "CompletedResult",
    "Coordinator",
    "CreditFarm",
    "CreditSystem",
    "DefenseLayer",
    "DefensePolicy",
    "ExponentialBackoff",
    "Feeder",
    "GridSimulation",
    "HostArrays",
    "ExpDrawCache",
    "HRLevel",
    "Host",
    "HostSpec",
    "InstanceOutcome",
    "InstanceState",
    "Job",
    "JobInstance",
    "JobState",
    "JobStore",
    "KeywordPrefs",
    "LinearBoundedAllocator",
    "Outage",
    "Platform",
    "PlanClass",
    "ProcessingResource",
    "ProjectAttachment",
    "ProjectServer",
    "ResourceRequest",
    "ResourceType",
    "RuntimeEstimator",
    "ScenarioResult",
    "ScenarioSpec",
    "ScheduleReply",
    "ScheduleRequest",
    "Scheduler",
    "Sybil",
    "TraceReplay",
    "Transitioner",
    "ValidateState",
    "bitwise_digest_batch",
    "bitwise_equal",
    "check_set",
    "default_cpu_plan_class",
    "digest_batch_for",
    "fuzzy_comparator",
    "generate_population",
    "gpu_plan_class",
    "hr_class",
    "keyword_score",
    "make_population",
    "next_id",
    "peak_flop_count",
    "reset_ids",
    "run_parity",
    "run_spec",
    "sybil_identity_ids",
]
