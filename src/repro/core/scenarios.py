"""Trace-driven & adversarial scenario generation (§3.4, §9; ROADMAP item 4).

The 7-scenario matrix that guarded PRs 1–5 was hand-written and synthetic:
flat exponential availability, independently-corrupting malicious hosts,
memoryless churn. Real volunteer populations (cf. "The Computational and
Storage Potential of Volunteer Computing") have diurnal timezone waves,
heavy-tailed sessions, and correlated outages — and the paper's §3.4
replication/adaptive-validation design exists precisely to defeat *hostile*
populations the old matrix could not express: colluding cliques that return
matching wrong results, Sybil churn-and-rejoin identities that shed
reputation, and credit-farming hosts that inflate claims.

This module is the declarative workload layer over the emulator:

  * :class:`ScenarioSpec` — a frozen dataclass naming the whole scenario:
    fleet size/shape, workload, server policy, plus optional adversarial /
    trace layers (:class:`TraceReplay`, :class:`Outage`, :class:`Clique`,
    :class:`Sybil`, :class:`CreditFarm`, correlated failures);
  * :func:`generate_population` — a **pure function of (spec, spec.seed)**:
    the same spec always yields field-identical ``HostSpec`` lists (and
    therefore identical ``HostArrays`` columns and event streams — pinned
    by a hypothesis property in ``tests/test_scenarios.py``);
  * :func:`build` / :func:`run_spec` — construct the ``ProjectServer`` +
    ``GridSimulation`` pair for any engine-axis combination and run it;
  * :func:`run_parity` — the golden harness: every scenario is executed on
    all three engine axes (batch-validate on/off, vectorized world on/off)
    and the results are asserted identical — SimMetrics, server counts,
    credit totals, per-instance validate states, per-job states — before
    any golden bound is checked;
  * :class:`ScenarioResult` — adversarial effectiveness measures on top of
    ``SimMetrics``: error credit (credit granted on jobs whose canonical
    was wrong), per-host-set credit shares, clique quorum wins.

Availability trace replay lives in ``repro.data.traces`` (fit from the
bundled session trace); this module only assigns the synthesized toggle
schedules onto host specs.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

from ..data import traces
from . import jax_backend
from .defense import DefensePolicy
from .server import ProjectServer
from .simulator import GridSimulation, HostSpec, SimMetrics, make_population
from .types import (
    App,
    AppVersion,
    HRLevel,
    Job,
    Platform,
    ProcessingResource,
    default_cpu_plan_class,
    gpu_plan_class,
    next_id,
    reset_ids,
)
from .validator import fuzzy_comparator

DAY = 86400.0
HOUR = 3600.0

#: Timezone offsets (hours) the trace layer spreads hosts across.
TZ_OFFSETS: Tuple[float, ...] = (-8.0, -5.0, 0.0, 2.0, 5.5, 9.0)

# distinct deterministic salts so each layer's host sample is independent
_SALT_OUTAGE = 0x5BD1E995
_SALT_CLIQUE = 0x9E3779B9
_SALT_FARM = 0xC2B2AE35


# ---------------------------------------------------------------------------
# layer specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TraceReplay:
    """Replay availability fitted from the bundled session trace: diurnal
    timezone waves + heavy-tailed (lognormal) session lengths."""

    n_timezones: int = 3
    diurnal: bool = True  # modulate off-gaps by the trace's hourly profile
    scale: float = 1.0  # stretch/compress all session lengths


@dataclass(frozen=True)
class Outage:
    """Correlated outage: a host fraction loses power simultaneously."""

    start: float
    duration: float
    fraction: float = 0.5


@dataclass(frozen=True)
class Clique:
    """Colluding malicious hosts fabricating identical wrong payloads, so
    replicas landing inside the clique validate each other (§3.4)."""

    size: int = 3
    cheat_prob: float = 1.0
    group: int = 1


@dataclass(frozen=True)
class Sybil:
    """Churn-and-rejoin: a malicious host departs and returns under fresh
    host ids, shedding whatever reputation its old identity earned."""

    host_index: int = 0  # 0-based index into the generated population
    churn_at: float = 0.75 * DAY
    rejoin_at: float = 1.0 * DAY
    rejoins: int = 1  # serial fresh identities after the first departure
    period: float = 0.5 * DAY  # spacing between serial identities
    dwell_fraction: float = 0.75  # lifetime of each non-final identity
    cheat_prob: float = 1.0


@dataclass(frozen=True)
class CreditFarm:
    """Hosts inflating their claimed peak-FLOP counts by ``factor`` while
    returning correct outputs (§7's normalization is the defense)."""

    count: int = 2
    factor: float = 8.0


@dataclass(frozen=True)
class ScenarioSpec:
    """One fully-declared, seed-deterministic scenario."""

    name: str
    seed: int = 1  # population/generation seed
    sim_seed: int = 3  # simulation event/noise seed
    n_hosts: int = 12
    n_jobs: int = 60
    horizon: float = 2 * DAY
    # server / app policy
    adaptive: bool = False
    gpu: bool = False
    min_quorum: int = 2
    delay_bound: float = 4 * HOUR
    est_hours: float = 0.2
    waves: int = 1
    wave_period: float = 6 * HOUR
    # base population model (make_population passthrough)
    availability: float = 1.0
    error_prob: float = 0.0
    malicious_fraction: float = 0.0
    churn_rate: float = 0.0
    gpu_fraction: float = 0.0
    ncpus: int = 4
    # workload / adversarial layers
    trace: Optional[TraceReplay] = None
    outage: Optional[Outage] = None
    clique: Optional[Clique] = None
    sybil: Optional[Sybil] = None
    farm: Optional[CreditFarm] = None
    # error_prob assigned to the least-available quartile of the fleet
    # (failures correlated with poor availability), 0 disables
    correlated_failures: float = 0.0
    # defense-in-depth replica placement (§3.4): work-spreading suspicion
    # clusters, HR-class census pinning, per-(host, version) daily quota +
    # punishment backoff. None (the default) keeps every pre-existing
    # golden byte-identical.
    defense: Optional[DefensePolicy] = None


# ---------------------------------------------------------------------------
# population generation — pure in (spec, spec.seed)
# ---------------------------------------------------------------------------


def _sample(spec: ScenarioSpec, salt: int, k: int, exclude: Sequence[int] = ()) -> List[int]:
    """Deterministic k-subset of host indices for one adversarial layer."""
    pool = [i for i in range(spec.n_hosts) if i not in set(exclude)]
    rng = random.Random(spec.seed * 1_000_003 + salt)
    return sorted(rng.sample(pool, min(k, len(pool))))


def _host_rng(spec: ScenarioSpec, index: int) -> random.Random:
    # int-arithmetic seed (never hash()): deterministic across processes
    return random.Random(spec.seed * 2_654_435_761 + 97 * index + 13)


def _schedule_on_fraction(s: HostSpec, horizon: float) -> float:
    if s.avail_schedule is None:
        return s.avail_on_mean / (s.avail_on_mean + s.avail_off_mean)
    ivals = traces.toggles_to_intervals(s.avail_schedule, horizon)
    return sum(b - a for a, b in ivals) / horizon


def generate_population(spec: ScenarioSpec) -> List[HostSpec]:
    """Generate the scenario's host population. Pure: same spec (including
    its seed) => field-identical list, no global state touched."""
    pop = make_population(
        spec.n_hosts,
        seed=spec.seed,
        gpu_fraction=spec.gpu_fraction,
        ncpus=spec.ncpus,
        error_prob=spec.error_prob,
        malicious_fraction=spec.malicious_fraction,
        availability=spec.availability,
        churn_rate=spec.churn_rate,
        horizon=spec.horizon,
    )
    # -- trace replay: per-host toggle schedules, timezones round-robin --
    if spec.trace is not None:
        tz_count = max(1, spec.trace.n_timezones)
        step = max(1, len(TZ_OFFSETS) // tz_count)
        zones = [TZ_OFFSETS[(j * step) % len(TZ_OFFSETS)] for j in range(tz_count)]
        fit = traces.fit_trace(traces.load_bundled_trace())
        for i, s in enumerate(pop):
            s.avail_schedule = traces.synthesize_toggles(
                fit,
                _host_rng(spec, i),
                spec.horizon,
                tz_offset=zones[i % tz_count],
                scale=spec.trace.scale,
                diurnal=spec.trace.diurnal,
            )
    # -- correlated outage: forced-off window spliced into schedules --
    if spec.outage is not None:
        o = spec.outage
        hit = _sample(spec, _SALT_OUTAGE, int(math.ceil(o.fraction * spec.n_hosts)))
        for i in hit:
            s = pop[i]
            s.avail_schedule = traces.apply_outage(
                s.avail_schedule or (), o.start, o.start + o.duration, spec.horizon
            )
    # -- colluding clique --
    if spec.clique is not None:
        c = spec.clique
        for i in _sample(spec, _SALT_CLIQUE, c.size):
            s = pop[i]
            s.malicious = True
            s.cheat_prob = c.cheat_prob
            s.collusion_group = c.group
    # -- credit farmers (never clique members: separate attack surfaces) --
    if spec.farm is not None:
        clique_ids = (
            _sample(spec, _SALT_CLIQUE, spec.clique.size) if spec.clique else []
        )
        for i in _sample(spec, _SALT_FARM, spec.farm.count, exclude=clique_ids):
            pop[i].claim_factor = spec.farm.factor
    # -- failures correlated with poor availability --
    if spec.correlated_failures > 0.0:
        ranked = sorted(
            range(spec.n_hosts),
            key=lambda i: (_schedule_on_fraction(pop[i], spec.horizon), i),
        )
        for i in ranked[: max(1, spec.n_hosts // 4)]:
            pop[i].error_prob = spec.correlated_failures
    # -- Sybil attacker: mark + schedule the first departure --
    if spec.sybil is not None:
        sy = spec.sybil
        s = pop[sy.host_index]
        s.malicious = True
        s.cheat_prob = sy.cheat_prob
        s.collusion_group = None
        s.churn_time = sy.churn_at
    return pop


# ---------------------------------------------------------------------------
# Sybil identity chain
# ---------------------------------------------------------------------------

#: Base host id for Sybil rejoin identities — far above make_population's
#: 1..n_hosts range so fresh identities can never collide.
SYBIL_ID_BASE = 100_000


def sybil_identity_ids(spec: ScenarioSpec) -> List[int]:
    """The fresh host ids the Sybil attacker will present, in order."""
    if spec.sybil is None:
        return []
    return [SYBIL_ID_BASE + k + 1 for k in range(spec.sybil.rejoins)]


def _sybil_respec(attacker: HostSpec, new_id: int, churn_time: Optional[float]) -> HostSpec:
    """The attacker's machine under a fresh identity: identical hardware
    and behaviour, new host/volunteer id, zero history."""
    h = attacker.host
    host = replace(
        h,
        id=new_id,
        volunteer_id=new_id,
        resources={rt: replace(r) for rt, r in h.resources.items()},
    )
    return HostSpec(
        host=host,
        efficiency=attacker.efficiency,
        runtime_noise=attacker.runtime_noise,
        error_prob=attacker.error_prob,
        crash_prob=attacker.crash_prob,
        malicious=attacker.malicious,
        cheat_prob=attacker.cheat_prob,
        avail_on_mean=attacker.avail_on_mean,
        avail_off_mean=attacker.avail_off_mean,
        churn_time=churn_time,
        rpc_poll=attacker.rpc_poll,
        collusion_group=attacker.collusion_group,
        claim_factor=attacker.claim_factor,
    )


def _install_sybil(spec: ScenarioSpec, sim: GridSimulation, attacker: HostSpec) -> None:
    sy = spec.sybil
    assert sy is not None
    ids = sybil_identity_ids(spec)
    for k, new_id in enumerate(ids):
        arrive = sy.rejoin_at + k * sy.period
        if arrive >= spec.horizon:
            break
        churn_time: Optional[float] = None
        if k < len(ids) - 1:
            churn_time = arrive + sy.dwell_fraction * sy.period
        new_spec = _sybil_respec(attacker, new_id, churn_time)
        sim.schedule_callback(
            arrive, lambda t, s=new_spec: sim.add_host_spec(s, t)
        )


# ---------------------------------------------------------------------------
# server / simulation construction
# ---------------------------------------------------------------------------


def build_server(
    spec: ScenarioSpec, batch_validate: bool, backend: str = "numpy"
) -> ProjectServer:
    server = ProjectServer(
        name="p",
        purge_delay=1e18,
        batch_validate=batch_validate,
        engine_backend=backend,
        defense_policy=spec.defense,
    )
    app = App(
        name="w",
        min_quorum=spec.min_quorum,
        init_ninstances=spec.min_quorum,
        delay_bound=spec.delay_bound,
        adaptive_replication=spec.adaptive,
        comparator=fuzzy_comparator(rtol=1e-6, atol=1e-9),
        hr_level=spec.defense.hr_level if spec.defense is not None else HRLevel.NONE,
    )
    for osn in ("windows", "mac", "linux"):
        app.add_version(
            AppVersion(
                id=next_id("appver"),
                app_name="w",
                platform=Platform(osn, "x86_64"),
                version_num=1,
                plan_class=default_cpu_plan_class(),
            )
        )
        if spec.gpu:
            app.add_version(
                AppVersion(
                    id=next_id("appver"),
                    app_name="w",
                    platform=Platform(osn, "x86_64"),
                    version_num=1,
                    plan_class=gpu_plan_class(),
                )
            )
    server.add_app(app)
    return server


def build(
    spec: ScenarioSpec,
    batch_validate: bool = True,
    vector_world: bool = True,
    epoch: float = 0.0,
    backend: str = "numpy",
) -> Tuple[ProjectServer, GridSimulation, List[HostSpec]]:
    """Construct the (server, simulation) pair for one engine-axis setting,
    with job waves and Sybil arrivals installed as virtual-time callbacks."""
    reset_ids()
    server = build_server(spec, batch_validate, backend=backend)
    pop = generate_population(spec)
    sim = GridSimulation(
        server, pop, seed=spec.sim_seed, vector_world=vector_world, epoch=epoch,
        backend=backend,
    )
    per_wave = spec.n_jobs // spec.waves

    def submit(now: float) -> None:
        for _ in range(per_wave):
            server.submit_job(
                Job(
                    id=next_id("job"),
                    app_name="w",
                    est_flop_count=spec.est_hours * 3600 * 16.5e9,
                ),
                now,
            )

    if spec.waves == 1:
        submit(0.0)
    else:
        for w in range(spec.waves):
            sim.schedule_callback(w * spec.wave_period, submit)
    if spec.sybil is not None:
        _install_sybil(spec, sim, pop[spec.sybil.host_index])
    return server, sim, pop


# ---------------------------------------------------------------------------
# execution + golden/parity harness
# ---------------------------------------------------------------------------


@dataclass
class ScenarioResult:
    """One scenario run plus its adversarial effectiveness measures."""

    spec: ScenarioSpec
    server: ProjectServer
    sim: GridSimulation
    metrics: SimMetrics
    population: List[HostSpec] = field(default_factory=list)

    # -- host-set helpers --

    def clique_host_ids(self) -> List[int]:
        if self.spec.clique is None:
            return []
        return [i + 1 for i in _sample(self.spec, _SALT_CLIQUE, self.spec.clique.size)]

    def farm_host_ids(self) -> List[int]:
        if self.spec.farm is None:
            return []
        clique_ids = (
            _sample(self.spec, _SALT_CLIQUE, self.spec.clique.size)
            if self.spec.clique
            else []
        )
        return [
            i + 1
            for i in _sample(
                self.spec, _SALT_FARM, self.spec.farm.count, exclude=clique_ids
            )
        ]

    # -- adversarial effectiveness measures --

    def wrong_credit(self) -> float:
        """Error credit: total credit granted on jobs whose canonical
        result was wrong — what the adversary's lies actually earned."""
        total = 0.0
        store = self.server.store
        for job in store.jobs.values():
            cid = job.canonical_instance_id
            if cid is None or not self.sim.was_wrong(cid):
                continue
            for inst in store.job_instances(job.id):
                total += max(0.0, inst.granted_credit)
        return total

    def credit_of_hosts(self, host_ids: Sequence[int]) -> float:
        totals = self.server.credit.total
        return sum(totals.get(f"host:{h}", 0.0) for h in host_ids)

    def mean_honest_host_credit(self) -> float:
        bad = set(self.clique_host_ids()) | set(self.farm_host_ids())
        if self.spec.sybil is not None:
            bad.add(self.spec.sybil.host_index + 1)
            bad.update(sybil_identity_ids(self.spec))
        honest = [
            s.host.id for s in self.population
            if s.host.id not in bad and not s.malicious
        ]
        if not honest:
            return 0.0
        return self.credit_of_hosts(honest) / len(honest)

    def clique_quorum_wins(self) -> int:
        """Jobs whose accepted canonical came from a clique host and was
        wrong — successful quorum defeats."""
        clique = set(self.clique_host_ids())
        store = self.server.store
        wins = 0
        for job in store.jobs.values():
            cid = job.canonical_instance_id
            if cid is None:
                continue
            inst = store.instances.get(cid)
            if inst is not None and inst.host_id in clique and self.sim.was_wrong(cid):
                wins += 1
        return wins

    def report(self) -> Dict[str, object]:
        m = self.metrics
        counts = self.server.counts()
        out: Dict[str, object] = {
            "name": self.spec.name,
            "seed": self.spec.seed,
            "n_hosts": self.spec.n_hosts,
            "n_jobs": self.spec.n_jobs,
            "metrics": {
                "jobs_success": counts["jobs_success"],
                "jobs_failure": counts["jobs_failure"],
                "completed_instances": m.completed_instances,
                "instances_executed": m.instances_executed,
                "correct_accepted": m.correct_accepted,
                "wrong_accepted": m.wrong_accepted,
                "error_rate": m.error_rate,
                "replication_overhead": m.replication_overhead,
                "idle_fraction": m.idle_fraction,
                "rpcs": m.rpcs,
                "credit_total": sum(
                    v for k, v in self.server.credit.total.items()
                    if k.startswith("host:")
                ),
            },
        }
        extras: Dict[str, object] = {}
        if self.spec.clique is not None:
            extras["clique_hosts"] = self.clique_host_ids()
            extras["clique_quorum_wins"] = self.clique_quorum_wins()
            extras["clique_credit"] = self.credit_of_hosts(self.clique_host_ids())
        if self.spec.farm is not None:
            extras["farm_hosts"] = self.farm_host_ids()
            extras["farm_credit"] = self.credit_of_hosts(self.farm_host_ids())
        if self.spec.clique is not None or self.spec.sybil is not None:
            extras["wrong_credit"] = self.wrong_credit()
        if self.spec.farm is not None or self.spec.clique is not None:
            extras["mean_honest_host_credit"] = self.mean_honest_host_credit()
        if self.spec.sybil is not None:
            extras["sybil_ids"] = sybil_identity_ids(self.spec)
        if extras:
            out["adversarial"] = extras
        defense = self.server.defense
        if defense is not None:
            d: Dict[str, object] = dict(defense.counters())
            clique = self.clique_host_ids()
            if clique:
                # why the clique was contained, per mechanism: dispatches it
                # was denied by quota/backoff/spread, and whether its hosts
                # ended up inside suspicion clusters
                clusters = defense.clusters()
                d["clique_hosts_clustered"] = sorted(
                    h for h in clique if h in clusters
                )
                d["clique_quota_denials"] = sum(
                    defense.denied_quota_by.get(h, 0) for h in clique
                )
                d["clique_deferrals"] = sum(
                    defense.deferred_by.get(h, 0) for h in clique
                )
                d["clique_spread_denials"] = sum(
                    defense.denied_spread_by.get(h, 0) for h in clique
                )
            out["defense"] = d
        return out


def run_spec(
    spec: ScenarioSpec,
    batch_validate: bool = True,
    vector_world: bool = True,
    epoch: float = 0.0,
    backend: str = "numpy",
) -> ScenarioResult:
    server, sim, pop = build(spec, batch_validate, vector_world, epoch, backend)
    m = sim.run(spec.horizon)
    sim.audit_validation()
    return ScenarioResult(spec=spec, server=server, sim=sim, metrics=m, population=pop)


def _instance_states(server: ProjectServer) -> Dict[int, Tuple[object, float]]:
    return {
        i: (x.validate_state, x.granted_credit)
        for i, x in server.store.instances.items()
    }


def _first_divergence(a: Dict, b: Dict) -> Optional[str]:
    """First differing key (sorted) between two flat dicts, described."""
    for k in sorted(set(a) | set(b), key=str):
        if k not in a:
            return f"{k!r} only in B (B={b[k]!r})"
        if k not in b:
            return f"{k!r} only in A (A={a[k]!r})"
        if a[k] != b[k]:
            return f"{k!r}: A={a[k]!r} B={b[k]!r}"
    return None


def assert_results_identical(
    a: ScenarioResult, b: ScenarioResult, what: str, job_states: bool = False
) -> None:
    """4-axis parity contract. ``what`` names the engine axis under test
    (A = full engines, B = the oracle for that axis); on divergence the
    failure message pinpoints the first differing field/key/instance so
    the break is localizable without re-running the matrix."""

    def fail(section: str, detail: str) -> str:
        return (
            f"[parity] scenario {a.spec.name!r}, axis '{what}': "
            f"{section} diverged first at {detail}"
        )

    d = _first_divergence(vars(a.metrics), vars(b.metrics))
    assert d is None, fail("SimMetrics", d)
    d = _first_divergence(a.server.counts(), b.server.counts())
    assert d is None, fail("server counts", d)
    d = _first_divergence(a.server.credit.total, b.server.credit.total)
    assert d is None, fail("credit totals", d)
    d = _first_divergence(_instance_states(a.server), _instance_states(b.server))
    assert d is None, fail("instance (validate_state, granted_credit)", d)
    if job_states:
        d = _first_divergence(
            {j: x.state for j, x in a.server.store.jobs.items()},
            {j: x.state for j, x in b.server.store.jobs.items()},
        )
        assert d is None, fail("job states", d)


def run_parity(spec: ScenarioSpec, epoch: float = 0.0) -> ScenarioResult:
    """Run the scenario on all engine axes and assert identity: the
    batch-validation engine vs the scalar validation oracle (vector world
    on), the vectorized world loop vs the scalar event loop (batch
    validate on), and — when jax is importable — the full engine stack on
    the jax backend vs the NumPy engines (the 4th axis; the jax engines
    are bit-identical, so the assertion is the same exact-equality check
    as the other axes). Returns the full-engine run for golden-bound
    assertions."""
    full = run_spec(spec, batch_validate=True, vector_world=True, epoch=epoch)
    oracle_v = run_spec(spec, batch_validate=False, vector_world=True, epoch=epoch)
    assert_results_identical(full, oracle_v, "validation engine vs scalar oracle")
    oracle_w = run_spec(spec, batch_validate=True, vector_world=False, epoch=epoch)
    assert_results_identical(
        full, oracle_w, "vector world vs scalar event loop", job_states=True
    )
    if jax_backend.HAVE_JAX:
        jax_full = run_spec(
            spec, batch_validate=True, vector_world=True, epoch=epoch,
            backend="jax",
        )
        assert_results_identical(
            full, jax_full, "jax backend vs numpy engines", job_states=True
        )
    return full
