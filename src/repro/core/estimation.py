"""Server-side job runtime estimation (§6.3).

The server maintains, for each (host H, app version V), the sample mean and
variance of runtime(J)/est_flop_count(J); also per app version V across all
hosts. ``proj_flops(H, V)`` is the estimated FLOPS adjusted for systematic
error in est_flop_count:

  * >= ``min_samples`` samples of R(H,V): use 1/mean(R(H,V))
  * else >= ``min_samples`` samples of R(V): use 1/mean(R(V))
  * else: the peak FLOPS of V on H.

est_runtime(J,H,V) = est_flop_count(J) / proj_flops(H,V).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from .types import AppVersion, Host, Job


@dataclass
class OnlineStats:
    """Welford online mean/variance."""

    n: int = 0
    mean: float = 0.0
    _m2: float = 0.0

    def add(self, x: float) -> None:
        self.n += 1
        delta = x - self.mean
        self.mean += delta / self.n
        self._m2 += delta * (x - self.mean)

    @property
    def variance(self) -> float:
        return self._m2 / (self.n - 1) if self.n > 1 else 0.0

    @property
    def stddev(self) -> float:
        return math.sqrt(max(0.0, self.variance))


@dataclass
class RuntimeEstimator:
    """Tracks runtime/est_flop_count statistics and projects FLOPS (§6.3)."""

    min_samples: int = 10  # the paper's threshold ("currently 10")
    host_version: Dict[Tuple[int, int], OnlineStats] = field(default_factory=dict)
    version: Dict[int, OnlineStats] = field(default_factory=dict)
    # host -> version ids with (host, version) stats, so churn cleanup is
    # O(host's versions) instead of a full host_version scan
    _host_versions: Dict[int, set] = field(default_factory=dict)

    def record(self, host: Host, version: AppVersion, job: Job, runtime: float) -> None:
        """Record an observed (runtime, est_flop_count) sample."""
        if runtime <= 0.0 or job.est_flop_count <= 0.0:
            return
        r = runtime / job.est_flop_count  # seconds per FLOP
        self.host_version.setdefault((host.id, version.id), OnlineStats()).add(r)
        self._host_versions.setdefault(host.id, set()).add(version.id)
        self.version.setdefault(version.id, OnlineStats()).add(r)

    def forget_host(self, host_id: int) -> None:
        """Drop a departed host's per-(host, version) stats (§4 churn):
        they can never be read again — ``proj_flops`` is only consulted for
        hosts requesting work — and long-churn populations would otherwise
        accumulate rows forever. Version-level aggregates are kept."""
        for vid in self._host_versions.pop(host_id, ()):
            self.host_version.pop((host_id, vid), None)

    def peak_flops(self, host: Host, version: AppVersion) -> float:
        ev = version.plan_class.evaluate(host)
        if ev is None:
            return 0.0
        _, pf = ev
        return pf

    def proj_flops(self, host: Host, version: AppVersion) -> float:
        hv = self.host_version.get((host.id, version.id))
        if hv is not None and hv.n >= self.min_samples and hv.mean > 0:
            return 1.0 / hv.mean
        v = self.version.get(version.id)
        if v is not None and v.n >= self.min_samples and v.mean > 0:
            return 1.0 / v.mean
        return self.peak_flops(host, version)

    def est_runtime(self, job: Job, host: Host, version: AppVersion) -> float:
        pf = self.proj_flops(host, version)
        if pf <= 0.0:
            return float("inf")
        return job.est_flop_count / pf

    def est_runtime_variance(self, job: Job, host: Host, version: AppVersion) -> float:
        """Runtime variance estimate — groundwork for low-latency scheduling
        (§10.7 suggests using sample variance to bound deadline-miss
        probability; we expose it for the grid runtime's straggler logic)."""
        hv = self.host_version.get((host.id, version.id))
        if hv is None or hv.n < 2:
            return 0.0
        return (hv.stddev * job.est_flop_count) ** 2

    def size_quantile(self, host: Host, version: AppVersion, n_classes: int, all_flops: list) -> int:
        """Which size-class quantile this host's speed falls in (§3.5):
        larger jobs go to faster hosts. ``all_flops`` is the population of
        proj_flops values used to compute quantile boundaries."""
        if n_classes <= 1 or not all_flops:
            return 0
        pf = self.proj_flops(host, version)
        sorted_f = sorted(all_flops)
        rank = sum(1 for f in sorted_f if f <= pf)
        q = int(rank * n_classes / (len(sorted_f) + 1))
        return min(n_classes - 1, q)
