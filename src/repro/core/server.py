"""The project server facade (§5.1).

Wires the store, feeder, scheduler instances, and the daemon set
(transitioner, validator — folded into the transitioner's quorum step as in
the paper's flow, assimilator, file deleter, database purger). Daemons are
independent ``tick`` callables; any can be paused and its work accumulates
in the store (the paper's fault-tolerance property — exercised by tests).

Scale-out (§5.1): every daemon supports ID-space sharding; scheduler
instances share the feeder cache.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from .adaptive import AdaptiveReplication
from .allocation import LinearBoundedAllocator
from .credit import CreditSystem
from .defense import DefenseLayer, DefensePolicy
from .estimation import RuntimeEstimator
from .fsm import Transitioner
from .scheduler import Feeder, Scheduler, ScheduleReply, ScheduleRequest, TrickleUp
from .shard import ShardMap, ShardPolicy
from .store import JobStore
from .types import App, AppVersion, Batch, Host, Job, next_id

AssimilatorFn = Callable[[Job, Any], None]


@dataclass
class DaemonControl:
    """Pause switch per daemon — used to exercise §5.1 fault tolerance."""

    transitioner: bool = True
    assimilator: bool = True
    file_deleter: bool = True
    purger: bool = True
    feeder: bool = True


@dataclass
class ProjectServer:
    name: str = "project"
    store: JobStore = field(default_factory=JobStore)
    estimator: RuntimeEstimator = field(default_factory=RuntimeEstimator)
    credit: CreditSystem = field(default_factory=CreditSystem)
    allocator: LinearBoundedAllocator = field(default_factory=LinearBoundedAllocator)
    adaptive: AdaptiveReplication = field(default_factory=AdaptiveReplication)
    cache_size: int = 1024
    n_scheduler_instances: int = 1
    n_daemon_instances: int = 1
    # route the transitioners' validate pass through the vectorized batch
    # validation engine (core/batch_validate.py); False selects the scalar
    # per-job oracle path (the parity reference)
    batch_validate: bool = True
    # route every scheduler RPC — singletons included — through the
    # vectorized dispatch engine's persistent cache snapshot
    # (core/batch_dispatch.py); False keeps the scalar per-request scan and
    # PR 1's fresh-snapshot-per-batch behavior (the parity reference).
    # GridSimulation(vector_world=True) flips this on via
    # :meth:`set_vector_dispatch`.
    vector_dispatch: bool = False
    # execution backend for the batch engines ("numpy" | "jax"), handed to
    # every Scheduler (dispatch scoring) and Transitioner (validation
    # digests); engine outputs are bit-identical either way (4th parity
    # axis in core/scenarios.run_parity)
    engine_backend: str = "numpy"
    # defense-in-depth replica placement (§3.4): work-spreading, HR census
    # pinning, host punishment. None disables the layer entirely.
    defense_policy: Optional[DefensePolicy] = None
    defense: Optional[DefenseLayer] = None
    # shard-aware federated dispatch (§5.1 scale-out, core/shard.py): with
    # several scheduler instances, partition hosts across them by a stable
    # host→shard affinity and give each shard its own slice of the feeder
    # cache, so rpc_batch runs one vectorized handle_batch pass per shard.
    # None = auto (sharding on exactly when n_scheduler_instances > 1);
    # False keeps the legacy sequential round-robin fallback — the
    # unsharded oracle the parity tests compare against.
    sharded_dispatch: Optional[bool] = None
    # pinned host_id→shard overrides (default affinity: host_id % n_shards)
    shard_affinity: Optional[Dict[int, int]] = None
    shard_policy: Optional[ShardPolicy] = None
    shard_map: Optional[ShardMap] = None
    purge_delay: float = 0.0  # keep completed rows briefly (§4)
    enabled: DaemonControl = field(default_factory=DaemonControl)
    assimilators: Dict[str, AssimilatorFn] = field(default_factory=dict)
    # trickle-up handlers (§3.5): app_name -> fn(instance, trickle, now)
    trickle_handlers: Dict[str, Any] = field(default_factory=dict)
    feeder: Feeder = None  # type: ignore[assignment]
    schedulers: List[Scheduler] = field(default_factory=list)
    transitioners: List[Transitioner] = field(default_factory=list)
    _rr: int = 0
    assimilated_outputs: List[Any] = field(default_factory=list)

    def __post_init__(self) -> None:
        self.feeder = Feeder(store=self.store, cache_size=self.cache_size)
        if self.defense is None and self.defense_policy is not None:
            self.defense = DefenseLayer(policy=self.defense_policy, store=self.store)
        if self.defense is not None:
            # HR relax unpins mutate job.hr_class behind the persistent
            # dispatch snapshot's back; bump the cache generation so the
            # vectorized path re-reads the pins (scalar-parity requirement)
            self.defense.invalidate_dispatch = self.feeder.invalidate
        sharded = self.sharded_dispatch
        if sharded is None:
            sharded = self.n_scheduler_instances > 1
        if sharded and self.n_scheduler_instances > 1 and self.shard_map is None:
            self.shard_map = ShardMap(
                n_shards=self.n_scheduler_instances,
                cache_size=self.cache_size,
                affinity=self.shard_affinity,
                policy=self.shard_policy or ShardPolicy(),
            )
        self.schedulers = [
            Scheduler(
                store=self.store,
                feeder=self.feeder,
                estimator=self.estimator,
                allocator=self.allocator,
                adaptive=self.adaptive,
                seed=i,
                vector_dispatch=self.vector_dispatch,
                engine_backend=self.engine_backend,
                defense=self.defense,
                shard_map=self.shard_map,
                shard=i,
            )
            for i in range(self.n_scheduler_instances)
        ]
        self.transitioners = [
            Transitioner(
                store=self.store,
                credit=self.credit,
                adaptive=self.adaptive,
                instance=i,
                n_instances=self.n_daemon_instances,
                batch_validate=self.batch_validate,
                engine_backend=self.engine_backend,
                defense=self.defense,
            )
            for i in range(self.n_daemon_instances)
        ]

    # ------------------------------------------------------------------
    # registration & submission (§3.9)
    # ------------------------------------------------------------------

    def add_app(self, app: App) -> App:
        return self.store.add_app(app)

    def add_host(self, host: Host) -> Host:
        if self.defense is not None:
            self.defense.on_host_added(host)
        return self.store.add_host(host)

    def submit_job(self, job: Job, now: float = 0.0) -> Job:
        job.created_time = now
        app = self.store.apps[job.app_name]
        # validation/deadline parameters are set "typically at the level of
        # app rather than job" (§4): inherit app values for any field the
        # submitter left at the dataclass default
        from .types import Job as JobCls

        for field_name in (
            "min_quorum",
            "init_ninstances",
            "max_error_instances",
            "max_success_instances",
            "delay_bound",
        ):
            if getattr(job, field_name) == JobCls.__dataclass_fields__[field_name].default:
                setattr(job, field_name, getattr(app, field_name))
        if app.adaptive_replication:
            # start unreplicated; the dispatch path may bump the quorum (§3.4)
            job.min_quorum = 1
            job.init_ninstances = 1
        self.allocator.ensure(job.submitter, now)
        return self.store.submit_job(job)

    def submit_batch(self, jobs: List[Job], submitter: str, now: float = 0.0) -> Batch:
        """Batch submission (§3.9) — designed so a thousand jobs submit fast;
        see benchmarks/bench_dispatch.py."""
        batch = Batch(id=next_id("batch"), submitter=submitter, created_time=now)
        self.store.batches[batch.id] = batch
        for j in jobs:
            j.batch_id = batch.id
            j.submitter = submitter
            self.submit_job(j, now)
        return batch

    # ------------------------------------------------------------------
    # RPC entry (scheduler CGI instances, §5.1)
    # ------------------------------------------------------------------

    def rpc(self, request: ScheduleRequest, now: float) -> ScheduleReply:
        self._handle_trickles(request, now)
        if self.shard_map is not None:
            # federated dispatch: stable host→shard affinity replaces the
            # round-robin rotation, so a host always hits the same shard's
            # cache slice (and the same scheduler RNG stream)
            shard = self.shard_map.shard_of(request.host_id)
            self.shard_map.rebalance(self.feeder, shard)
            reply = self.schedulers[shard].handle_request(request, now)
            self.shard_map.note(shard, requests=1, dispatched=len(reply.jobs))
            return reply
        sched = self.schedulers[self._rr % len(self.schedulers)]
        self._rr += 1
        return sched.handle_request(request, now)

    def rpc_batch(self, requests: List[ScheduleRequest], now: float) -> List[ScheduleReply]:
        """Coalesced scheduler RPCs: one vectorized batch-dispatch pass.

        One scheduler instance serves the whole batch through
        ``Scheduler.handle_batch`` (the shared-memory cache is snapshotted
        into struct-of-arrays form once and scored vectorized per host),
        result-identical to calling :meth:`rpc` per request in order.

        With multiple scheduler instances and federated dispatch active
        (``shard_map``), the batch is grouped by host→shard affinity and
        served as one vectorized ``handle_batch`` pass *per shard* in
        ascending shard order (requests keep their arrival order within a
        shard; replies are scattered back to arrival positions). Each
        request is result-identical to routing it through :meth:`rpc` under
        the same affinity; the shard-parity contract (union of per-shard
        assignments == sequential affinity-routed dispatch) is pinned by
        tests/test_shard_dispatch.py. With sharding opted out
        (``sharded_dispatch=False``) the legacy behavior remains: the
        sequential path round-robins requests across distinct RNG streams,
        so batching would change assignments — fall back to per-request
        dispatch to keep the identity.
        """
        if len(self.schedulers) > 1:
            if self.shard_map is None:
                return [self.rpc(r, now) for r in requests]
            return self._rpc_batch_sharded(requests, now)
        for request in requests:
            self._handle_trickles(request, now)
        if not requests:
            return []
        sched = self.schedulers[self._rr % len(self.schedulers)]
        self._rr += 1
        # adaptive-replication decisions in this coalesced pass consume one
        # prefetched RNG batch instead of interleaved per-job draws (§3.4);
        # the FIFO cache preserves stream order, so every decision is
        # identical to unbatched use regardless of the estimate's accuracy
        self.adaptive.prefetch_draws(len(requests))
        return sched.handle_batch(requests, now)

    def _rpc_batch_sharded(
        self, requests: List[ScheduleRequest], now: float
    ) -> List[ScheduleReply]:
        """Federated coalesced dispatch: one vectorized ``handle_batch``
        pass per shard (ascending shard order, arrival order within each
        shard), after a work-migration check per participating shard.
        Trickles are handled up front for the whole batch, like the
        single-instance coalesced path."""
        for request in requests:
            self._handle_trickles(request, now)
        if not requests:
            return []
        assert self.shard_map is not None
        groups: Dict[int, List[int]] = {}
        for i, r in enumerate(requests):
            groups.setdefault(self.shard_map.shard_of(r.host_id), []).append(i)
        replies: List[Optional[ScheduleReply]] = [None] * len(requests)
        for s in sorted(groups):
            idxs = groups[s]
            # starved-shard migration before the pass, so a drained slice
            # can steal neighbors' cached slots instead of replying empty
            self.shard_map.rebalance(self.feeder, s)
            # one prefetched adaptive-RNG batch per shard pass (same FIFO
            # stream-order guarantee as the single-instance coalesced path)
            self.adaptive.prefetch_draws(len(idxs))
            out = self.schedulers[s].handle_batch([requests[i] for i in idxs], now)
            dispatched = 0
            for i, reply in zip(idxs, out):
                replies[i] = reply
                dispatched += len(reply.jobs)
            self.shard_map.note(s, requests=len(idxs), dispatched=dispatched)
        return replies  # type: ignore[return-value]

    def _handle_trickles(self, request: ScheduleRequest, now: float) -> None:
        """Trickle-up messages are 'conveyed immediately to the server and
        handled by project-specific logic' (§3.5). The default handler
        grants partial credit for partial completion — the paper's example."""
        for t in request.trickles:
            inst = self.store.instances.get(t.instance_id)
            if inst is None:
                continue
            job = self.store.jobs.get(inst.job_id)
            if job is None:
                continue
            handler = self.trickle_handlers.get(job.app_name)
            if handler is not None:
                handler(inst, t, now)
            else:
                # default: partial credit proportional to fraction done
                host = self.store.hosts.get(request.host_id)
                if host is not None and t.fraction_done > 0:
                    partial = (
                        job.est_flop_count * t.fraction_done / 86400.0 / 1e9
                    )
                    self.credit.grant(f"host:{host.id}:partial", partial, now)

    # ------------------------------------------------------------------
    # daemons (§5.1)
    # ------------------------------------------------------------------

    def tick(self, now: float) -> None:
        """Run one pass of every enabled daemon."""
        if self.enabled.feeder:
            self.feeder.fill()
        if self.enabled.transitioner:
            for t in self.transitioners:
                t.tick(now)
            if self.enabled.feeder:
                self.feeder.fill()  # newly created instances become dispatchable
            else:
                # transitions may have staled cached slots (cancelled /
                # timed-out instances); with the feeder paused no fill will
                # clear them, so force the persistent dispatch snapshot to
                # rebuild with its staleness probe
                self.feeder.invalidate()
        if self.enabled.assimilator:
            self.assimilate(now)
        if self.enabled.file_deleter:
            self.delete_files(now)
        if self.enabled.purger:
            self.purge(now)
        self._update_batches(now)

    def assimilate(self, now: float) -> int:
        n = 0
        for job in self.store.pending_assimilation():
            handler = self.assimilators.get(job.app_name)
            output = None
            if job.canonical_instance_id is not None:
                canonical = self.store.instances.get(job.canonical_instance_id)
                output = canonical.output if canonical else None
            if handler is not None:
                handler(job, output)
            else:
                self.assimilated_outputs.append((job.id, output))
            job.assimilated = True
            n += 1
        return n

    def delete_files(self, now: float) -> int:
        n = 0
        for job in self.store.pending_file_deletion():
            # retain canonical output until all instances resolved (§4).
            # The indexed store already defers blocked jobs to their
            # instance-terminal events (store.delete_ready), so this check
            # is a cheap defense there and the actual filter only on the
            # use_indexes=False oracle path.
            if any(i.is_outstanding() for i in self.store.job_instances(job.id)):
                continue
            job.files_deleted = True
            n += 1
        return n

    def remove_host(self, host_id: int, now: float = 0.0) -> None:
        """Device churn (§4): drop the server's scheduling-side traces of
        the host — the DB row, the estimator's (host, version) runtime
        stats, and the adaptive-replication reputation row. In-progress
        instances are left to hit their deadlines and get retried
        elsewhere. The credit system's per-(host, version) claim stats are
        deliberately retained: straggler results reported before the
        departure may still reach validation, and their quorum partners'
        claims normalize against that history (§7)."""
        self.store.remove_host(host_id)
        self.estimator.forget_host(host_id)
        self.adaptive.forget_host(host_id)
        if self.defense is not None:
            self.defense.forget_host(host_id)
        if self.shard_map is not None:
            self.shard_map.forget_host(host_id)

    def set_vector_dispatch(self, flag: bool) -> None:
        """Flip the persistent-snapshot dispatch path on every scheduler
        instance (used by ``GridSimulation(vector_world=...)``)."""
        self.vector_dispatch = flag
        for s in self.schedulers:
            s.vector_dispatch = flag

    def purge(self, now: float) -> int:
        # the store pops only rows past the retention window (§4): jobs
        # still inside it stay heaped and cost nothing per tick
        n = 0
        for job in self.store.purgeable_jobs(now - self.purge_delay):
            self.store.purge_job(job)
            n += 1
        if n:
            # purged jobs may still be referenced by the persistent dispatch
            # snapshot's static arrays — force a rebuild
            self.feeder.invalidate()
        return n

    def _update_batches(self, now: float) -> None:
        if self.store.use_indexes:
            # O(newly completed): the store flags a batch the moment its
            # last job reaches a terminal state
            for bid in self.store.drain_completed_batches():
                b = self.store.batches.get(bid)
                # re-check doneness (O(1) counter probe): the batch may have
                # reopened since it was flagged
                if b is not None and b.completed_time is None and self.store.batch_done(bid):
                    b.completed_time = now
            return
        for b in self.store.batches.values():
            if b.completed_time is None and b.job_ids and self.store.batch_done(b.id):
                b.completed_time = now

    # ------------------------------------------------------------------
    # status
    # ------------------------------------------------------------------

    def counts(self) -> Dict[str, int]:
        return self.store.status_counts()
