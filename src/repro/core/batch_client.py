"""Vectorized host-population client engine (§6.1–6.2, §9).

The EmBOINC-style emulator (§9) exists to model *large* volunteer
populations, but the client half of the paper — weighted-round-robin
resource scheduling with the deadline-miss WRR simulation (§6.1, Fig. 5)
and buffer-watermark work fetch (§6.2) — was scalar Python executed once
per host per event. After PR 1 vectorized server dispatch and PR 2 made
daemon passes O(dirty), the per-host ``wrr_simulate`` / ``Client.schedule``
calls dominate simulator tick cost and cap populations orders of magnitude
below the million-host target.

This module is the third leg of the scalar-oracle + vectorized-engine
architecture: it materializes a set of clients' job queues into
struct-of-arrays form (jobs padded to a per-host ragged layout, slot-major
``[max_jobs, n_hosts]`` so every per-slot pass runs over contiguous rows)
once per tick and runs, for *all hosts sharing the tick*, as fused NumPy
passes:

  * the **WRR simulation**: per-event greedy maximal sets under CPU/GPU/RAM
    feasibility masks, fluid busy-time accounting, deadline-miss
    prediction, and per-resource shortfall / idle / queue-duration /
    saturation outputs;
  * the **run-set selection** of ``Client.schedule``: the §6.1 ordering key
    (EDF-for-misses, GPU-first, mid-slice, CPU width, per-project priority
    broadcast) as one stable global ``np.lexsort``, then the greedy maximal
    feasible set as per-rank vector passes;
  * **work fetch**: the buffer-watermark test (§6.2) over the batched WRR
    outputs, mirroring ``Client._requests_from_sim`` per host.

Every per-element operation mirrors the scalar path in IEEE-754 order:
sequential Python ``sum``/``min`` folds map to ``np.add.reduce`` /
``np.minimum.reduce`` along the slot axis (bitwise-identical row-sequential
accumulation), masked selects use ``x * mask`` / ``reduce(where=...)``
forms that add exact zeros, and the rare inputs where Python's ``min``/
``max`` NaN semantics could diverge (infinite remaining estimates, i.e.
``est_flops <= 0``) fall back to exact ``np.where`` folds. The engine is
therefore *bit-exact* with the scalar oracle: identical run sets,
deadline-miss sets, and work requests. ``tests/test_batch_client.py``
asserts it, ``benchmarks/bench_clients.py`` measures the speedup
(acceptance floor: ≥10× client tick cost at the 10k-host population).
Client state mutations (miss flags, run/preempt transitions) go through
the same ``Client`` helpers as the scalar path.

Known scalar-oracle degeneracy inherited by design: duplicate
``instance_id`` values within one queue share a remaining-time entry in
``wrr_simulate``; the engine keeps per-slot remaining times, so parity is
scoped to queues with unique instance ids (always true for
server-dispatched work).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple, TYPE_CHECKING

import numpy as np

from . import jax_backend
from .client import Client, ClientJob, RunState, WorkRequest, WRRResult
from .scheduler import ResourceRequest
from .types import ResourceType

if TYPE_CHECKING:  # pragma: no cover
    from .world import HostArrays

_MAX_EVENTS = 10_000  # mirrors wrr_simulate's event cap

_GPU_LIKE = (ResourceType.GPU, ResourceType.TPU)

# per-job build-row fields (queue order), before the per-resource usage tail
_NFIELDS = 12


class _Snapshot:
    """SoA view of a set of clients' live queues at one tick.

    Per-job arrays are slot-major ``[J, H]`` (slot k of every host is a
    contiguous row) in *queue order*; ``perm`` maps WRR rank → queue slot.
    """

    __slots__ = (
        "clients", "queued", "prios", "H", "J", "rtypes",
        "live", "rem", "dl", "wss", "nci", "run_state", "slice_start",
        "chk_time", "prio_j", "usage", "cu", "gpu", "perm", "has_inf",
        "identity_perm",
        "nins", "has", "all_has", "client_rtypes", "ram", "ram_frac",
        "horizon", "ts", "ncpu",
    )


class _WRROut:
    """Raw per-host WRR outputs ([H] arrays keyed by resource type)."""

    __slots__ = ("misses", "shortfall", "idle", "queue_dur", "saturated")

    def __init__(self, misses, shortfall, idle, queue_dur, saturated):
        self.misses = misses
        self.shortfall = shortfall
        self.idle = idle
        self.queue_dur = queue_dur
        self.saturated = saturated


class BatchClientEngine:
    """Fused-pass WRR simulation + run-set selection over a host population.

    Stateless between calls: every entry point snapshots the given clients'
    queues (their state changes every tick) and runs the vector passes.
    ``schedule_batch`` / ``tick_batch`` apply the same mutations as
    ``Client.schedule`` via ``Client._set_miss_flags`` /
    ``Client._apply_run_set``.
    """

    def __init__(self, backend: str = "numpy") -> None:
        # "jax" routes the two dense greedy passes (WRR event feasibility,
        # run-set rank loop) through core.jax_backend fori_loop kernels —
        # bit-identical to the NumPy loops (no multiplies inside them);
        # snapshotting, ordering keys, and the sparse event tail stay host-side
        self.backend = jax_backend.resolve_backend(backend)

    # ------------------------------------------------------------------
    # snapshot construction
    # ------------------------------------------------------------------

    def _snapshot(
        self, clients: Sequence[Client], now: float, accrue_empty: bool = True
    ) -> _Snapshot:
        s = _Snapshot()
        s.clients = list(clients)
        H = len(s.clients)
        s.H = H
        # priority accrual side effects are identical to the scalar path:
        # Client.needs_work calls project_priorities(now) unconditionally,
        # but Client.schedule early-returns *before* accrual on an empty
        # queue — schedule_batch passes accrue_empty=False to mirror that
        # (an accrual at an intermediate time changes float association)
        s.prios = [
            c.project_priorities(now)
            if (accrue_empty or any(j.state != RunState.DONE for j in c.jobs))
            else {}
            for c in s.clients
        ]

        # resource-type universe: client resources ∪ job usage keys (the
        # CPU identity test skips hashing on the dominant CPU-only case)
        rt_seen: Dict[ResourceType, None] = {}
        rt_cpu = ResourceType.CPU
        rt_seen.setdefault(rt_cpu, None)
        for c in s.clients:
            for rt in c.resources:
                rt_seen.setdefault(rt, None)
        for c in s.clients:
            for j in c.jobs:
                for rt in j.usage:
                    if rt is not rt_cpu and rt not in rt_seen:
                        rt_seen[rt] = None
        rtypes = list(rt_seen)
        s.rtypes = rtypes
        R = len(rtypes)

        flat: List[float] = []
        ext = flat.extend
        perm_rows: List[Sequence[int]] = []
        s.queued = []
        running_state = RunState.RUNNING
        done_state = RunState.DONE
        # specialize the usage-column tail for the common 1–2 resource cases
        rt0 = rtypes[0] if R > 0 else None
        rt1 = rtypes[1] if R > 1 else None
        for c, pr in zip(s.clients, s.prios):
            q: List[ClientJob] = []
            qappend = q.append
            multi = len(pr) > 1
            if multi:
                prs: List[float] = []
                prappend = prs.append
            else:
                # single attached project: constant priority, FIFO WRR order
                # (jobs of detached projects fall back to 0.0 — tracked as
                # orphan indices so the WRR sort still happens when needed)
                pr_name, pr_val = next(iter(pr.items()), (None, 0.0))
                orphans: List[int] = []
            k = 0
            for j in c.jobs:
                if j.state == done_state:
                    continue
                qappend(j)
                if multi:
                    pj = pr.get(j.project, 0.0)
                    prappend(pj)
                elif j.project == pr_name:
                    pj = pr_val
                else:
                    pj = 0.0
                    orphans.append(k)
                # usage columns via items() + identity tests: enum keys hash
                # through a Python-level __hash__, identity is free
                u = j.usage
                if R <= 2:
                    u0 = u1 = 0.0
                    for rt, v in u.items():
                        if rt is rt0:
                            u0 = v
                        elif rt is rt1:
                            u1 = v
                    ext((
                        j.est_flops, j.est_flop_count, j.fraction_done,
                        j.fraction_done_exact, j.runtime, j.deadline,
                        j.est_wss, j.non_cpu_intensive,
                        j.slice_start, j.checkpoint_time,
                        j.state == running_state, pj, u0, u1,
                    ) if R == 2 else (
                        j.est_flops, j.est_flop_count, j.fraction_done,
                        j.fraction_done_exact, j.runtime, j.deadline,
                        j.est_wss, j.non_cpu_intensive,
                        j.slice_start, j.checkpoint_time,
                        j.state == running_state, pj, u0,
                    ))
                else:
                    ext((
                        j.est_flops, j.est_flop_count, j.fraction_done,
                        j.fraction_done_exact, j.runtime, j.deadline,
                        j.est_wss, j.non_cpu_intensive,
                        j.slice_start, j.checkpoint_time,
                        j.state == running_state, pj,
                    ) + tuple(u.get(rt, 0.0) for rt in rtypes))
                k += 1
            s.queued.append(q)
            if not multi:
                prs = []
                if orphans and pr_val != 0.0:
                    prs = [pr_val] * k
                    for i in orphans:
                        prs[i] = 0.0
            if len(set(prs)) > 1:
                # WRR order: by project priority, stable FIFO inside a project
                perm_rows.append(
                    sorted(range(k), key=prs.__getitem__, reverse=True)
                )
            else:
                perm_rows.append(())  # identity — perm rows pre-filled
        s.identity_perm = all(not p for p in perm_rows)

        counts = (
            np.fromiter(map(len, s.queued), np.int64, H)
            if H
            else np.zeros(0, np.int64)
        )
        J = int(counts.max()) if H else 0
        s.J = J

        nf = _NFIELDS + R
        # ragged-layout mask: rows were appended host-major in queue order
        mask_hm = (
            np.arange(J)[None, :] < counts[:, None]
            if J
            else np.zeros((H, 0), dtype=bool)
        )
        s.live = np.ascontiguousarray(mask_hm.T)
        s.perm = (
            np.tile(np.arange(J, dtype=np.int64)[:, None], (1, H))
            if J
            else np.zeros((0, H), np.int64)
        )
        for h, p in enumerate(perm_rows):
            if p:
                s.perm[: len(p), h] = np.fromiter(p, np.int64, len(p))

        if flat:
            m = np.asarray(flat, dtype=np.float64).reshape(-1, nf)
            # one boolean-mask scatter for every per-job column, then one
            # transpose into the slot-major layout the passes consume
            big = np.zeros((nf, H, J))
            big[:, mask_hm] = m.T
            big = np.ascontiguousarray(big.transpose(0, 2, 1))
            (ef, efc, fd, exact_f, runtime, dl, wss, nci_f,
             slice_start, chk_time, run_f, prio_j) = big[:_NFIELDS]
            s.dl = dl
            s.wss = wss
            s.nci = nci_f > 0.5
            s.run_state = run_f > 0.5
            s.slice_start = slice_start
            s.chk_time = chk_time
            s.prio_j = prio_j
            s.usage = {rt: big[_NFIELDS + i] for i, rt in enumerate(rtypes)}
            exact = exact_f > 0.5
            # remaining_estimate, vectorized in the scalar path's IEEE order
            with np.errstate(divide="ignore", invalid="ignore"):
                static = np.where(ef > 0.0, efc / ef, np.inf)
                dynamic = np.where(fd > 0.0, runtime / fd, 0.0)
                total = np.where(exact, dynamic, fd * dynamic + (1.0 - fd) * static)
                d = total - runtime
                # fd <= 0 short-circuits to the static total, *without* the
                # max(0, total - runtime) clamp — mirror that exactly; the
                # d > 0 select also reproduces Python max(0.0, nan) == 0.0
                rem = np.where(fd > 0.0, np.where(d > 0.0, d, 0.0), static)
            s.rem = np.maximum(rem, 1e-9)
            # padding slots are inf by construction (ef=0) — only *live*
            # infinite estimates force the NaN-exact slow folds
            s.has_inf = bool(np.isinf(s.rem[s.live]).any())
        else:
            z = np.zeros((J, H))
            s.rem = z
            s.dl = z
            s.wss = z
            s.nci = np.zeros((J, H), dtype=bool)
            s.run_state = np.zeros((J, H), dtype=bool)
            s.slice_start = z
            s.chk_time = z
            s.prio_j = z
            s.usage = {rt: np.zeros((J, H)) for rt in rtypes}
            s.has_inf = False

        s.client_rtypes = [list(c.resources) for c in s.clients]
        s.nins = {}
        s.has = {}
        for rt in rtypes:
            s.nins[rt] = np.fromiter(
                (c.resources[rt].ninstances if rt in c.resources else 0
                 for c in s.clients),
                np.float64, H,
            )
            s.has[rt] = np.fromiter(
                (rt in c.resources for c in s.clients), np.bool_, H
            )
        s.ram = np.fromiter((c.ram_bytes for c in s.clients), np.float64, H)
        s.ram_frac = np.fromiter(
            (c.prefs.ram_limit_fraction for c in s.clients), np.float64, H
        )
        s.horizon = np.fromiter((c.prefs.b_hi for c in s.clients), np.float64, H)
        s.ts = np.fromiter((c.prefs.time_slice for c in s.clients), np.float64, H)
        s.ncpu = np.fromiter(
            (c.n_usable_cpus
             or (c.resources[ResourceType.CPU].ninstances
                 if ResourceType.CPU in c.resources else 1)
             for c in s.clients),
            np.float64, H,
        )
        s.all_has = {rt: bool(s.has[rt].all()) for rt in rtypes}
        s.cu = s.usage.get(ResourceType.CPU, np.zeros((J, H)))
        gpu = np.zeros((J, H), dtype=bool)
        for rt in _GPU_LIKE:
            if rt in s.usage:
                gpu |= s.usage[rt] > 0.0
        s.gpu = gpu
        return s

    # ------------------------------------------------------------------
    # world-backed snapshot: persistent columns, no per-job rebuild
    # ------------------------------------------------------------------

    def _snapshot_world(
        self,
        world: "HostArrays",
        host_ids: Sequence[int],
        now: float,
        accrue_empty: bool = True,
    ) -> _Snapshot:
        """Build a :class:`_Snapshot` from the simulator's persistent world
        columns (``core/world.py``) instead of re-materializing every
        ``ClientJob`` object: the per-job fields were mirrored into the
        slot-major ``[max_jobs, n_hosts]`` matrix at mutation time, so the
        snapshot is a set of column gathers plus the shared
        remaining-estimate formula — bit-identical to :meth:`_snapshot`
        over the same queues.

        Dirty-host refresh contract: hosts whose ``ClientJob`` objects were
        mutated outside the simulator/engine hooks (``world.mark_dirty``)
        get their columns rebuilt from the objects first. Multi-project
        hosts (whose WRR priority ordering needs the per-job project map)
        fall back to the object snapshot after a column->object sync.
        """
        if world.dirty:
            for h in host_ids:
                if h in world.dirty:
                    world.resync_host(h)
        idx_l = [world.index[h] for h in host_ids]
        clients = [world.clients[i] for i in idx_l]
        if any(
            world.multi[i] or world.clients[i] is None
            or len(world.clients[i].projects) > 1
            for i in idx_l
        ):
            world.sync_objects(host_ids)
            return self._snapshot(clients, now, accrue_empty)

        s = _Snapshot()
        s.clients = clients
        H = len(clients)
        s.H = H
        idx = np.fromiter(idx_l, np.int64, H) if H else np.zeros(0, np.int64)
        counts = world.q_count[idx]
        # priority accrual side effects mirror the object path: needs_work
        # accrues unconditionally, schedule skips empty queues
        s.prios = [
            c.project_priorities(now)
            if (accrue_empty or counts[k] > 0)
            else {}
            for k, c in enumerate(clients)
        ]
        rtypes = list(world.rtypes)
        s.rtypes = rtypes
        J = int(counts.max()) if H else 0
        s.J = J
        s.live = (
            np.arange(J)[:, None] < counts[None, :]
            if J
            else np.zeros((0, H), dtype=bool)
        )
        s.perm = (
            np.tile(np.arange(J, dtype=np.int64)[:, None], (1, H))
            if J
            else np.zeros((0, H), np.int64)
        )
        s.identity_perm = True  # single project per host: WRR order is FIFO

        ef = world.q_estf[:J, idx]
        efc = world.q_efc[:J, idx]
        fd = world.q_frac[:J, idx]
        runtime = world.q_runtime[:J, idx]
        exact = world.q_exact[:J, idx]
        s.dl = world.q_dl[:J, idx]
        s.wss = world.q_wss[:J, idx]
        s.nci = world.q_nci[:J, idx]
        s.run_state = world.q_running[:J, idx]
        s.slice_start = world.q_slice[:J, idx]
        s.chk_time = world.q_chk[:J, idx]
        pv = np.fromiter(
            (next(iter(p.values()), 0.0) for p in s.prios), np.float64, H
        )
        s.prio_j = np.where(s.live, pv[None, :], 0.0)
        s.usage = {rt: world.q_usage[rt][:J, idx] for rt in rtypes}
        # remaining_estimate — the same fused formula (and the same IEEE op
        # order) as the object snapshot; padding cells are exact zeros by
        # the world's compaction contract, so they evaluate to inf just as
        # the object path's zero-padded rows do
        with np.errstate(divide="ignore", invalid="ignore"):
            static = np.where(ef > 0.0, efc / ef, np.inf)
            dynamic = np.where(fd > 0.0, runtime / fd, 0.0)
            total = np.where(exact, dynamic, fd * dynamic + (1.0 - fd) * static)
            d = total - runtime
            rem = np.where(fd > 0.0, np.where(d > 0.0, d, 0.0), static)
        s.rem = np.maximum(rem, 1e-9)
        s.has_inf = bool(np.isinf(s.rem[s.live]).any()) if J else False

        s.queued = [world.queue_jobs[i] for i in idx_l]
        s.client_rtypes = [list(c.resources) for c in clients]
        s.nins = {rt: world.nins[rt][idx] for rt in rtypes}
        s.has = {rt: world.has[rt][idx] for rt in rtypes}
        s.all_has = {rt: bool(s.has[rt].all()) for rt in rtypes}
        s.ram = world.ram[idx]
        s.ram_frac = world.ram_frac[idx]
        s.horizon = world.b_hi[idx]
        s.ts = world.time_slice[idx]
        s.ncpu = world.sched_ncpu[idx]
        s.cu = s.usage.get(ResourceType.CPU, np.zeros((J, H)))
        gpu = np.zeros((J, H), dtype=bool)
        for rt in _GPU_LIKE:
            if rt in s.usage:
                gpu |= s.usage[rt] > 0.0
        s.gpu = gpu
        return s

    # ------------------------------------------------------------------
    # fused WRR simulation (§6.1, Fig. 5)
    # ------------------------------------------------------------------

    def _greedy(self, s, order_live, active, u_w, u_eps, u_zero, wss_w,
                row_counts=None):
        """One greedy maximal-set pass in WRR order: per-slot feasibility
        under per-resource caps + RAM (columns masked by ``active`` if
        given). Returns the chosen [J, H] mask and the leftover caps (for
        the idle computation). ``row_counts`` (live candidates per WRR
        rank, maintained by the event loop) short-circuits exhausted rows
        without touching the arrays."""
        J = s.J
        rtypes = s.rtypes
        cap = {rt: s.nins[rt].copy() for rt in rtypes}
        ram_left = s.ram.copy()
        running = np.zeros((J, s.H), dtype=bool)
        buf = np.empty(s.H, dtype=bool)
        feas = np.empty(s.H, dtype=bool)
        for k in range(J):
            if row_counts is not None and not row_counts[k]:
                continue
            if active is None:
                np.copyto(feas, order_live[k])
            else:
                np.logical_and(order_live[k], active, out=feas)
            if not feas.any():
                continue
            for rt in rtypes:
                np.greater_equal(cap[rt], u_eps[rt][k], out=buf)
                np.logical_or(buf, u_zero[rt][k], out=buf)
                np.logical_and(feas, buf, out=feas)
            np.logical_and(feas, wss_w[k] <= ram_left, out=feas)
            if feas.any():
                for rt in rtypes:
                    sel = feas if s.all_has[rt] else (feas & s.has[rt])
                    np.subtract(cap[rt], u_w[rt][k], out=cap[rt], where=sel)
                np.subtract(ram_left, wss_w[k], out=ram_left, where=feas)
                running[k] = feas  # copies the buffer's current values
        return running, cap

    def _wrr_raw(self, s: _Snapshot, now: float) -> _WRROut:
        H, J = s.H, s.J
        rtypes = s.rtypes

        if s.identity_perm:
            # queue order == WRR order on every host: no gathers needed
            # (rem is copied — the event loop decrements it in place)
            def wgather(a):
                return a
        else:
            def wgather(a):
                # WRR-rank-major gather: row k holds each host's rank-k job
                return np.take_along_axis(a, s.perm, axis=0) if J else a

        live_w = wgather(s.live)
        rem_w = s.rem.copy() if s.identity_perm else wgather(s.rem)
        dl_w = wgather(s.dl)
        wss_w = wgather(s.wss)
        u_w = {rt: wgather(s.usage[rt]) for rt in rtypes}
        # loop invariants, hoisted: u - 1e-12 thresholds and u <= 0 masks
        u_eps = {rt: u_w[rt] - 1e-12 for rt in rtypes}
        u_zero = {rt: u_w[rt] <= 0.0 for rt in rtypes}

        # queue_dur: remaining time per resource over all live queued jobs —
        # reduce(where=) accumulates row-sequentially, i.e. in WRR order,
        # bitwise-identical to the scalar summation
        qd = {}
        for rt in rtypes:
            sel = live_w & ~u_zero[rt] & s.has[rt][None, :]
            qd[rt] = (
                np.add.reduce(rem_w, axis=0, where=sel) if J else np.zeros(H)
            )

        # jax backend: the per-event inputs (usage, thresholds, caps, RAM)
        # are static across the event loop — upload once, run each event's
        # greedy as a single fori_loop jit over the device context
        ctx = (
            jax_backend.WRRGreedyContext(s, u_w, u_eps, u_zero, wss_w)
            if (self.backend == "jax" and J) else None
        )

        busy = {rt: np.zeros(H) for rt in rtypes}
        t = np.zeros(H)
        not_done = live_w.copy()
        active = live_w.any(axis=0) if J else np.zeros(H, dtype=bool)
        # live candidates per WRR rank, decremented as jobs finish: lets the
        # greedy skip exhausted rows (most of a ragged batch's padding)
        row_counts = not_done.sum(axis=1)  # reprolint: ignore[parity-float] (bool count, integer-exact)
        miss_events: List[Tuple[np.ndarray, np.ndarray]] = []

        cap0 = None  # leftover caps of the *first* greedy (the idle set)
        # degenerate-host early exit: a host whose dt goes non-finite (an
        # infinite remaining estimate) reaches a fixed point — its running
        # set is static, rem stays inf/NaN, and after two more events t and
        # busy stop changing — so it can be frozen instead of spinning the
        # scalar oracle's 10k-event cap (outputs stay bit-identical)
        stall = np.zeros(H, dtype=np.int64)
        ev = 0
        while active.any() and ev < _MAX_EVENTS:
            ev += 1
            # greedy maximal set in WRR order under resource + RAM caps
            if ctx is not None:
                running, cap = ctx.greedy(not_done, active)
            else:
                running, cap = self._greedy(
                    s, not_done, active, u_w, u_eps, u_zero, wss_w,
                    row_counts=row_counts,
                )
            if ev == 1:
                # the scalar idle computation re-runs the greedy over the
                # initial pending set — identical to this first event's pass
                cap0 = cap
            act = active & running.any(axis=0)
            active = act
            if not act.any():
                break
            # running slots as index pairs (row-major == WRR order per host):
            # the event tail works on these ~|running| entries instead of
            # full [J, H] matrices — completions are sparse
            rk, rh = np.nonzero(running)
            run_rem = rem_w[rk, rh]
            # dt = min remaining over the running set; Python min() folds
            # left-to-right, but min is order-independent without NaNs —
            # NaNs require an inf remaining estimate (see has_inf)
            if not s.has_inf:
                dt = np.minimum.reduce(
                    rem_w, axis=0, where=running, initial=np.inf
                )
                # lanes with no running job got the inf initial; zero them
                # (every accumulator update below is gated to active lanes)
                dt[~act] = 0.0
            else:
                dt = np.zeros(H)
                started = np.zeros(H, dtype=bool)
                for k in range(J):
                    mask = running[k]
                    if not mask.any():
                        continue
                    v = rem_w[k]
                    dt = np.where(
                        mask & ~started, v, np.where(mask & (v < dt), v, dt)
                    )
                    started |= mask
            dt = np.maximum(dt, 1e-9)  # NaN-exact: matches Python max(dt, 1e-9)
            # fluid busy accounting inside the horizon (old t, like scalar)
            h_minus_t = s.horizon - t
            if not s.has_inf:
                within = np.maximum(np.minimum(dt, h_minus_t), 0.0)
            else:  # Python min/max NaN semantics
                inner = np.where(dt < h_minus_t, dt, h_minus_t)
                within = np.where(inner > 0.0, inner, 0.0)
            for rt in rtypes:
                # bincount accumulates in input (row-major == WRR) order —
                # bitwise-identical to the scalar's sequential sum
                used = np.bincount(rh, weights=u_w[rt][rk, rh], minlength=H)
                m = np.minimum(used, s.nins[rt])  # min(used, ninstances)
                np.add(busy[rt], m * within, out=busy[rt], where=act)
            np.add(t, dt, out=t, where=act)
            # completions & deadline misses (with the updated t, like scalar)
            with np.errstate(invalid="ignore"):  # inf - inf on degenerate rem
                run_rem -= dt[rh]
            rem_w[rk, rh] = run_rem
            dsel = run_rem <= 1e-9
            if dsel.any():
                dk, dh = rk[dsel], rh[dsel]
                not_done[dk, dh] = False
                np.subtract.at(row_counts, dk, 1)
                msel = (now + t[dh]) > dl_w[dk, dh]
                if msel.any():
                    miss_events.append((dk[msel], dh[msel]))
            if s.has_inf:
                stall[act & ~np.isfinite(dt)] += 1
                active = active & (stall < 3)

        # assemble per-host miss lists: event order, then never-scheduled
        # (infeasible) jobs in WRR order, deduplicated like the scalar path
        misses: List[List[int]] = [[] for _ in range(H)]
        for ks, hs in miss_events:
            for k, h in zip(ks.tolist(), hs.tolist()):
                misses[h].append(s.queued[h][s.perm[k, h]].instance_id)
        if not_done.any():
            left_miss = not_done & ((now + t)[None, :] + rem_w > dl_w)
            for k, h in zip(*np.nonzero(left_miss)):
                iid = s.queued[h][s.perm[k, h]].instance_id
                if iid not in misses[h]:
                    misses[h].append(iid)

        # idle-now: leftover caps of the greedy over the initial queue; with
        # no active host the greedy never ran and everything is idle
        if cap0 is None:
            cap0 = {rt: s.nins[rt].copy() for rt in rtypes}

        shortfall = {}
        idle = {}
        saturated = {}
        for rt in rtypes:
            shortfall[rt] = np.maximum(s.horizon * s.nins[rt] - busy[rt], 0.0)
            idle[rt] = np.maximum(cap0[rt], 0.0)
            saturated[rt] = busy[rt] / np.maximum(s.nins[rt], 1.0)
        return _WRROut(misses, shortfall, idle, qd, saturated)

    def _wrap_results(self, s: _Snapshot, raw: _WRROut) -> List[WRRResult]:
        out: List[WRRResult] = []
        for h in range(s.H):
            rts = s.client_rtypes[h]
            out.append(
                WRRResult(
                    deadline_misses=raw.misses[h],
                    shortfall={rt: float(raw.shortfall[rt][h]) for rt in rts},
                    idle_instances={rt: float(raw.idle[rt][h]) for rt in rts},
                    queue_dur={rt: float(raw.queue_dur[rt][h]) for rt in rts},
                    saturated_until={rt: float(raw.saturated[rt][h]) for rt in rts},
                )
            )
        return out

    def _needs_from_raw(
        self, s: _Snapshot, raw: _WRROut
    ) -> List[Dict[ResourceType, ResourceRequest]]:
        """Buffer-watermark test (§6.2) per host off the raw arrays —
        mirrors ``Client._requests_from_sim`` exactly (same comparison,
        same resource iteration order, same floats)."""
        out: List[Dict[ResourceType, ResourceRequest]] = []
        short, idle, qd, sat = raw.shortfall, raw.idle, raw.queue_dur, raw.saturated
        for h, c in enumerate(s.clients):
            b_lo = c.prefs.b_lo
            d: Dict[ResourceType, ResourceRequest] = {}
            for rt in s.client_rtypes[h]:
                if sat[rt][h] < b_lo:
                    d[rt] = ResourceRequest(
                        req_runtime=float(short[rt][h]),
                        req_idle=float(idle[rt][h]),
                        queue_dur=float(qd[rt][h]),
                    )
            out.append(d)
        return out

    # ------------------------------------------------------------------
    # fused run-set selection (§6.1 ordering + greedy maximal feasible set)
    # ------------------------------------------------------------------

    def _run_set_pass(
        self, s: _Snapshot, miss_lists: Sequence[List[int]], now: float
    ) -> List[List[ClientJob]]:
        H, J = s.H, s.J
        if J == 0:
            return [[] for _ in range(H)]
        rtypes = s.rtypes

        # set deadline-miss flags through the same scalar helper, collecting
        # the values for the ordering-key arrays as we go
        miss_q = np.zeros((J, H), dtype=bool)
        for h, (c, q, ms) in enumerate(zip(s.clients, s.queued, miss_lists)):
            mset = set(ms)
            c._set_miss_flags(q, mset)
            if mset:
                for k, j in enumerate(q):
                    if j.deadline_miss:
                        miss_q[k, h] = True

        # §6.1 ordering key as one stable global lexsort (host-major)
        k1 = 2.0 - s.live  # 2: padding last, 1: live, 0: predicted miss
        k1[miss_q] = 0.0
        k2 = np.zeros((J, H))
        k2[miss_q] = s.dl[miss_q]
        in_slice = s.run_state & ((now - s.slice_start) < s.ts[None, :])
        # GPU-first and mid-slice are both {0,1} keys: 2·k3 + k4 preserves
        # the (k3, k4) lexicographic order in a single key
        k34 = 2.0 * s.gpu + (
            in_slice | (s.run_state & (s.chk_time <= s.slice_start))
        )
        np.subtract(3.0, k34, out=k34)
        k5 = -s.cu
        k6 = -s.prio_j
        # arrays are [J, H]: transpose before raveling so the sort is
        # host-major with the original queue order as the stable tiebreak
        hidx = np.repeat(np.arange(H), J)
        flat = np.lexsort((
            k6.T.ravel(), k5.T.ravel(), k34.T.ravel(),
            k2.T.ravel(), k1.T.ravel(), hidx,
        ))
        # sidx[r, h]: queue slot of host h's rank-r job
        sidx = (flat.reshape(H, J) - np.arange(H)[:, None] * J).astype(np.int64).T

        def sgather(a):
            return np.take_along_axis(a, sidx, axis=0)

        live_s = sgather(s.live)
        cu_s = sgather(s.cu)
        wss_s = sgather(s.wss)
        gpu_s = sgather(s.gpu)
        nci_s = sgather(s.nci)
        u_s = {rt: sgather(s.usage[rt]) for rt in rtypes if rt != ResourceType.CPU}

        # ram * ram_frac is computed here in NumPy on both backends: the
        # product must be materialized before it ever meets the greedy's
        # subtract chain (FMA staging contract, see core/jax_backend)
        ram0 = s.ram * s.ram_frac
        rhs1 = s.ncpu + 1e-12
        rhs2 = (s.ncpu + 1.0) + 1e-12
        if self.backend == "jax":
            chosen = jax_backend.run_set_greedy(
                live_s, cu_s, wss_s, gpu_s, nci_s, u_s,
                {rt: s.has[rt] for rt in u_s},
                {rt: s.nins[rt] for rt in u_s},
                ram0, rhs1, rhs2,
            )
        else:
            cap = {rt: s.nins[rt].copy() for rt in u_s}
            cpu_cpu = np.zeros(H)
            cpu_all = np.zeros(H)
            ram_left = ram0
            chosen = np.zeros((J, H), dtype=bool)
            buf = np.empty(H, dtype=bool)
            for r in range(J):
                lv = live_s[r]
                if not lv.any():
                    continue
                cu = cu_s[r]
                gpu_r = gpu_s[r]
                feas = lv.copy()
                for rt, u in u_s.items():
                    # u > 0 gate: the scalar loop only visits usage keys the
                    # job actually carries, and real usage dicts hold
                    # positive entries
                    np.less(cap[rt], u[r] - 1e-12, out=buf)
                    np.logical_and(buf, u[r] > 0.0, out=buf)
                    np.logical_and(feas, ~buf, out=feas)
                np.logical_and(feas, ~(~gpu_r & ((cpu_cpu + cu) > rhs1)), out=feas)
                np.logical_and(feas, (cpu_all + cu) <= rhs2, out=feas)
                np.logical_and(feas, wss_s[r] <= ram_left, out=feas)
                np.logical_or(feas, nci_s[r] & lv, out=feas)  # §3.5: always run
                if not feas.any():
                    continue
                chosen[r] = feas
                for rt, u in u_s.items():
                    sel = feas if s.all_has[rt] else (feas & s.has[rt])
                    np.subtract(cap[rt], u[r], out=cap[rt], where=sel)
                np.add(cpu_cpu, cu, out=cpu_cpu, where=feas & ~gpu_r)
                np.add(cpu_all, cu, out=cpu_all, where=feas)
                np.subtract(ram_left, wss_s[r], out=ram_left, where=feas)

        out: List[List[ClientJob]] = [[] for _ in range(H)]
        for r, h in zip(*np.nonzero(chosen)):
            out[h].append(s.queued[h][sidx[r, h]])
        return out

    def _apply_run_sets(
        self, s: _Snapshot, miss_lists: Sequence[List[int]], now: float
    ) -> List[List[ClientJob]]:
        run_sets = self._run_set_pass(s, miss_lists, now)
        out: List[List[ClientJob]] = []
        for c, q, chosen in zip(s.clients, s.queued, run_sets):
            if not q:
                c.running = []
                out.append([])
                continue
            out.append(c._apply_run_set(chosen, now))
        return out

    # ------------------------------------------------------------------
    # public entry points
    # ------------------------------------------------------------------

    def wrr_batch(self, clients: Sequence[Client], now: float) -> List[WRRResult]:
        """Batched ``wrr_simulate`` over each client's live queue."""
        s = self._snapshot(clients, now)
        return self._wrap_results(s, self._wrr_raw(s, now))

    def schedule_batch(
        self, clients: Sequence[Client], now: float
    ) -> List[List[ClientJob]]:
        """Batched ``Client.schedule``: applies the same state mutations
        (miss flags, run/preempt transitions) and returns each run set."""
        s = self._snapshot(clients, now, accrue_empty=False)
        raw = self._wrr_raw(s, now)
        return self._apply_run_sets(s, raw.misses, now)

    def needs_work_batch(
        self, clients: Sequence[Client], now: float
    ) -> List[Dict[ResourceType, ResourceRequest]]:
        """Batched ``Client.needs_work``: one fused WRR pass, then each
        host's buffer-watermark test over its own result."""
        s = self._snapshot(clients, now)
        return self._needs_from_raw(s, self._wrr_raw(s, now))

    def choose_fetch_batch(
        self, clients: Sequence[Client], now: float
    ) -> List[Optional[WorkRequest]]:
        """Batched ``Client.choose_fetch_project``."""
        needs = self.needs_work_batch(clients, now)
        return [
            c.choose_fetch_project(now, needs=n) for c, n in zip(clients, needs)
        ]

    def tick_batch(
        self, clients: Sequence[Client], now: float
    ) -> Tuple[List[List[ClientJob]], List[Dict[ResourceType, ResourceRequest]]]:
        """One full client tick (reschedule + work-fetch test) for the whole
        population off a single snapshot and WRR pass. The WRR inputs are
        unchanged by run-set transitions, so sharing the pass is exact."""
        s = self._snapshot(clients, now)
        raw = self._wrr_raw(s, now)
        run_sets = self._apply_run_sets(s, raw.misses, now)
        return run_sets, self._needs_from_raw(s, raw)

    # ------------------------------------------------------------------
    # world-backed entry points (persistent columns; see _snapshot_world)
    # ------------------------------------------------------------------

    def needs_work_world(
        self, world: "HostArrays", host_ids: Sequence[int], now: float
    ) -> List[Dict[ResourceType, ResourceRequest]]:
        """Batched ``Client.needs_work`` straight off the world columns."""
        s = self._snapshot_world(world, host_ids, now)
        return self._needs_from_raw(s, self._wrr_raw(s, now))

    def schedule_world(
        self, world: "HostArrays", host_ids: Sequence[int], now: float
    ) -> List[List[ClientJob]]:
        """Batched ``Client.schedule`` off the world columns; the run-set
        mutations are applied to the ``ClientJob`` objects and the world's
        run-state columns are re-synced."""
        s = self._snapshot_world(world, host_ids, now, accrue_empty=False)
        raw = self._wrr_raw(s, now)
        out = self._apply_run_sets(s, raw.misses, now)
        for h in host_ids:
            world.sync_run_state(h)
        return out
