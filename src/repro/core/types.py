"""Core datatypes for the BOINC-JAX middleware layer.

Faithful to the abstractions in Anderson, "BOINC: A Platform for Volunteer
Computing" (2019): projects, hosts, platforms, apps, app versions, plan
classes, jobs (workunits) and job instances (results).

The names follow the paper's terminology (section references in docstrings).
Everything here is plain host-side Python: these objects describe *work*, not
tensors. The JAX layer plugs in through ``App.execute`` payloads (see
``repro.runtime.grid_runtime``).
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Resources and platforms (§3.1, §6.1)
# ---------------------------------------------------------------------------


class ResourceType(enum.Enum):
    """A processing-resource type on a host (§6.1)."""

    CPU = "cpu"
    GPU = "gpu"
    TPU = "tpu"  # hardware adaptation: TPU slices are first-class resources


@dataclass(frozen=True)
class Platform:
    """A (processor type, operating system) pair (§3.1)."""

    os: str  # "windows" | "mac" | "linux" | "android" | "tpu-vm"
    arch: str  # "x86_64" | "arm64" | "tpu-v5e" | ...

    @property
    def name(self) -> str:
        return f"{self.os}-{self.arch}"


@dataclass
class ProcessingResource:
    """A pool of identical processing-resource instances on one host (§6.1)."""

    rtype: ResourceType
    ninstances: int
    peak_flops: float  # per instance; Whetstone for CPUs, vendor est for GPUs
    availability: float = 1.0  # long-term fraction of time usable (§6)
    model: str = "generic"
    driver_version: int = 0

    @property
    def total_peak_flops(self) -> float:
        return self.ninstances * self.peak_flops


@dataclass
class Host:
    """A volunteer device / worker node (§2.1).

    In the TPU adaptation a Host is a slice (worker group); ``platforms``
    then contains e.g. Platform("tpu-vm", "tpu-v5e").
    """

    id: int
    platforms: Tuple[Platform, ...]
    resources: Dict[ResourceType, ProcessingResource]
    os_version: str = ""
    cpu_vendor: str = "genuineintel"
    cpu_model: str = "generic"
    ram_bytes: float = 8e9
    disk_free_bytes: float = 100e9
    # Fraction of wall time the host is on & BOINC allowed to compute (§6).
    on_fraction: float = 1.0
    # Owner/account linkage (cross-project credit, §7).
    volunteer_id: int = 0
    n_usable_cpus: int = 0  # 0 => all instances usable
    # Hardware adaptation: numeric determinism class inputs.
    xla_version: str = ""
    deterministic_reductions: bool = True

    def usable_cpus(self) -> int:
        cpu = self.resources.get(ResourceType.CPU)
        if cpu is None:
            return 0
        if self.n_usable_cpus <= 0:
            return cpu.ninstances
        return min(self.n_usable_cpus, cpu.ninstances)

    def peak_flops(self, usage: Dict[ResourceType, float]) -> float:
        """Peak FLOPS of a job with the given per-resource usage (§6.3)."""
        total = 0.0
        for rtype, amount in usage.items():
            res = self.resources.get(rtype)
            if res is not None:
                total += amount * res.peak_flops
        return total

    def supports_platform(self, platform: Platform) -> bool:
        return platform in self.platforms


# ---------------------------------------------------------------------------
# Homogeneous redundancy (§3.4)
# ---------------------------------------------------------------------------


class HRLevel(enum.IntEnum):
    """Homogeneous-redundancy equivalence granularity (§3.4)."""

    NONE = 0
    COARSE = 1  # (OS family, CPU vendor)
    FINE = 2  # + CPU model

    # Hardware adaptation: TPU fleets group by (generation, XLA version,
    # deterministic-reduction flag) — same root cause (FP non-determinism).
    NUMERIC_CLASS = 3


def hr_class(host: Host, level: HRLevel) -> Tuple:
    """Equivalence class of ``host`` at ``level``; jobs validated by byte
    comparison are only co-scheduled within one class (§3.4)."""
    if level == HRLevel.NONE:
        return ()
    if level == HRLevel.COARSE:
        return (host.platforms[0].os, host.cpu_vendor)
    if level == HRLevel.FINE:
        return (host.platforms[0].os, host.cpu_vendor, host.cpu_model)
    if level == HRLevel.NUMERIC_CLASS:
        return (host.platforms[0].arch, host.xla_version, host.deterministic_reductions)
    raise ValueError(f"unknown HR level {level}")


# ---------------------------------------------------------------------------
# Apps, app versions, plan classes (§3.1)
# ---------------------------------------------------------------------------


#: A plan-class function (§3.1): Host -> None (reject) or (usage, peak_flops).
PlanClassFn = Callable[[Host], Optional[Tuple[Dict[ResourceType, float], float]]]


@dataclass
class PlanClass:
    """Fine-grained app-version applicability (§3.1).

    ``fn`` returns, for an accepting host, the per-resource usage (possibly
    fractional) and the resulting peak FLOPS.
    """

    name: str
    fn: PlanClassFn

    def evaluate(self, host: Host) -> Optional[Tuple[Dict[ResourceType, float], float]]:
        return self.fn(host)


def default_cpu_plan_class(ncpus: float = 1.0) -> PlanClass:
    def fn(host: Host):
        cpu = host.resources.get(ResourceType.CPU)
        if cpu is None or host.usable_cpus() < ncpus:
            return None
        usage = {ResourceType.CPU: ncpus}
        return usage, ncpus * cpu.peak_flops

    return PlanClass(name=f"cpu{ncpus:g}", fn=fn)


def gpu_plan_class(min_driver: int = 0, gpu_usage: float = 1.0, cpu_usage: float = 0.1) -> PlanClass:
    def fn(host: Host):
        gpu = host.resources.get(ResourceType.GPU)
        if gpu is None or gpu.driver_version < min_driver:
            return None
        usage = {ResourceType.GPU: gpu_usage, ResourceType.CPU: cpu_usage}
        cpu = host.resources.get(ResourceType.CPU)
        pf = gpu_usage * gpu.peak_flops + (cpu.peak_flops * cpu_usage if cpu else 0.0)
        return usage, pf

    return PlanClass(name=f"gpu{gpu_usage:g}", fn=fn)


@dataclass
class AppVersion:
    """One build of an app for a (platform, plan class) (§3.1).

    In the TPU adaptation an AppVersion is a *compiled executable*: a
    (mesh shape, sharding rules, precision) variant of a jitted step.
    """

    id: int
    app_name: str
    platform: Platform
    version_num: int
    plan_class: PlanClass
    files: Tuple[str, ...] = ()
    # Payload executed by the grid runtime; signature (job, host) -> output.
    execute: Optional[Callable[["Job", Host], Any]] = None

    def key(self) -> Tuple[str, str, str]:
        return (self.app_name, self.platform.name, self.plan_class.name)


@dataclass
class App:
    """An application: a set of app versions for one program (§3.1)."""

    name: str
    min_quorum: int = 2
    init_ninstances: int = 2
    max_error_instances: int = 3
    max_success_instances: int = 6
    delay_bound: float = 14 * 86400.0
    # Validation configuration (§3.4).
    hr_level: HRLevel = HRLevel.NONE
    homogeneous_app_version: bool = False
    adaptive_replication: bool = False
    # Comparator: (out_a, out_b) -> bool. None => bitwise equality.
    comparator: Optional[Callable[[Any, Any], bool]] = None
    non_cpu_intensive: bool = False
    uses_locality: bool = False
    multi_size: bool = False
    n_size_classes: int = 1
    # Jobs always use dynamic runtime estimate (fixed-iteration apps, §6.1).
    fraction_done_exact: bool = False
    versions: List[AppVersion] = field(default_factory=list)
    keywords: Tuple[str, ...] = ()

    def add_version(self, version: AppVersion) -> None:
        assert version.app_name == self.name
        self.versions.append(version)

    def latest_versions(self) -> List[AppVersion]:
        """Latest version per (platform, plan class) (§3.1)."""
        best: Dict[Tuple[str, str, str], AppVersion] = {}
        for v in self.versions:
            k = v.key()
            if k not in best or v.version_num > best[k].version_num:
                best[k] = v
        return list(best.values())


# ---------------------------------------------------------------------------
# Jobs and instances (§3.3, §4)
# ---------------------------------------------------------------------------


class IndexObserved:
    """Mixin: notify the owning :class:`~repro.core.store.JobStore` when an
    indexed field is assigned.

    The store's §5.1 "DB indexes" (state sets, pending queues, the deadline
    heap) are maintained *at mutation time*. Concurrent daemons — and tests —
    mutate rows by plain attribute assignment (``inst.state = ...``,
    ``job.transition_flag = True``), exactly like UPDATEs against the real
    MySQL schema, so the hook lives here rather than in store methods: any
    assignment to a field named in ``_TRACKED`` is forwarded to
    ``store._on_field_change``. Rows not attached to a store (``_store``
    unset) behave as plain dataclasses.
    """

    _TRACKED = frozenset()

    def __setattr__(self, name: str, value: Any) -> None:
        if name in self._TRACKED:
            store = self.__dict__.get("_store")
            if store is not None:
                old = self.__dict__.get(name)
                object.__setattr__(self, name, value)
                if old != value:
                    store._on_field_change(self, name, old, value)
                return
        object.__setattr__(self, name, value)


class JobState(enum.Enum):
    ACTIVE = "active"  # instances outstanding or validation pending
    SUCCESS = "success"  # canonical instance found & assimilated
    FAILURE = "failure"  # error/success limits exceeded
    PURGED = "purged"  # removed from DB (§4)


class InstanceState(enum.Enum):
    UNSENT = "unsent"
    IN_PROGRESS = "in_progress"
    OVER = "over"


class InstanceOutcome(enum.Enum):
    INIT = "init"
    SUCCESS = "success"
    CLIENT_ERROR = "client_error"
    NO_REPLY = "no_reply"  # deadline passed (§4)
    ABANDONED = "abandoned"  # host detached / churned
    CANCELLED = "cancelled"  # unsent instance cancelled after canonical found
    VALIDATE_ERROR = "validate_error"


class ValidateState(enum.Enum):
    INIT = "init"
    VALID = "valid"
    INVALID = "invalid"
    INCONCLUSIVE = "inconclusive"


@dataclass
class Job(IndexObserved):
    """A workunit (§3.3). Instances of it are dispatched to hosts."""

    _TRACKED = frozenset({"state", "transition_flag", "assimilated", "files_deleted"})

    id: int
    app_name: str
    est_flop_count: float  # a-priori size estimate (§6.3)
    max_flop_count: float = 0.0  # abort infinite loops (§3.3)
    ram_bytes: float = 0.0  # working-set estimate, job selection (§6.4)
    disk_bytes: float = 0.0  # upper bound (§3.3)
    delay_bound: float = 14 * 86400.0  # §4
    min_quorum: int = 2
    init_ninstances: int = 2
    max_error_instances: int = 3
    max_success_instances: int = 6
    keywords: Tuple[str, ...] = ()
    input_files: Tuple[str, ...] = ()
    size_class: int = 0  # multi-size jobs (§3.5)
    target_host: Optional[int] = None  # targeted jobs (§3.5)
    pinned_version_num: Optional[int] = None  # version pinning (§3.5)
    submitter: str = "default"
    batch_id: int = 0
    priority: float = 0.0
    created_time: float = 0.0
    # Ground-truth payload for emulation: what a correct execution returns.
    payload: Any = None

    # -- server-side state (§4) --
    state: JobState = JobState.ACTIVE
    canonical_instance_id: Optional[int] = None
    hr_class: Optional[Tuple] = None  # locked after first dispatch (§3.4)
    hav_version_id: Optional[int] = None  # homogeneous app version lock
    assimilated: bool = False
    files_deleted: bool = False
    transition_flag: bool = True  # set by concurrent daemons; cleared by transitioner
    error_mask: int = 0


@dataclass
class JobInstance(IndexObserved):
    """A job instance / result (§3.3, §4)."""

    _TRACKED = frozenset({"state", "deadline", "host_id", "outcome", "validate_state"})

    id: int
    job_id: int
    state: InstanceState = InstanceState.UNSENT
    outcome: InstanceOutcome = InstanceOutcome.INIT
    validate_state: ValidateState = ValidateState.INIT
    host_id: Optional[int] = None
    # volunteer of record, captured by the store when host_id is assigned:
    # the one-instance-per-volunteer rule (§6.4) keys on this
    volunteer_id: Optional[int] = None
    app_version_id: Optional[int] = None
    sent_time: float = 0.0
    deadline: float = 0.0
    received_time: float = 0.0
    runtime: float = 0.0  # raw runtime (§6)
    peak_flop_count: float = 0.0  # PFC (§7)
    output: Any = None
    stderr: str = ""
    exit_code: int = 0
    claimed_credit: float = 0.0
    granted_credit: float = 0.0

    def is_outstanding(self) -> bool:
        return self.state in (InstanceState.UNSENT, InstanceState.IN_PROGRESS)


# ---------------------------------------------------------------------------
# Batches & submitters (§3.9)
# ---------------------------------------------------------------------------


@dataclass
class Batch:
    id: int
    submitter: str
    job_ids: List[int] = field(default_factory=list)
    created_time: float = 0.0
    completed_time: Optional[float] = None


_id_counters: Dict[str, itertools.count] = {}


def next_id(kind: str) -> int:
    """Process-wide monotonically increasing IDs per entity kind."""
    if kind not in _id_counters:
        _id_counters[kind] = itertools.count(1)
    return next(_id_counters[kind])


def reset_ids() -> None:
    """Reset ID counters (tests / simulator determinism)."""
    _id_counters.clear()


def clone_job(job: Job, **overrides: Any) -> Job:
    return dataclasses.replace(job, **overrides)
