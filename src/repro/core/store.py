"""In-memory job database (the relational DB of §5.1).

The real BOINC server centers on MySQL; here the store is an indexed
in-memory structure with the same role: the single point of coordination
between scheduler instances and daemons. Daemons communicate *only* through
this store (flags on rows), which is what makes the multi-daemon
architecture fault-tolerant: a stopped daemon's work accumulates here.

**Indexes (§5.1).** Real BOINC daemons never table-scan: they enumerate
flagged records through DB indexes (``WHERE transition_time < now``). This
store reproduces that with structures maintained *at mutation time* (rows
notify the store on field assignment, see ``types.IndexObserved``):

  * per-state ID sets for jobs and instances (``counts`` in O(1));
  * per-daemon pending queues — ``transition_pending``,
    ``assimilate_pending``, ``delete_pending``, ``purge_pending`` and
    ``batch_done_pending`` — so a daemon pass is O(work to do), not
    O(table size);
  * a lazy min-heap over IN_PROGRESS instance deadlines, so the
    transitioner's deadline pass pops only expired entries;
  * per-job ``(host, volunteer)`` assignment sets, making the
    one-instance-per-volunteer "slow check" (§6.4) O(1);
  * per-batch open-job counters replacing the all-jobs ``batch_done`` scan;
  * a validation-pending set — jobs holding a fresh (OVER/SUCCESS/INIT)
    instance — consumed by the batch validation engine's digest pass;
  * a file-deletion readiness set — delete-pending jobs with zero
    outstanding instances (per-job counts maintained on instance state
    transitions), so the deleter never re-scans blocked jobs per tick.

The original scan queries (``jobs_with_flag`` & co.) are kept as the
debug/oracle path: ``use_indexes=False`` routes every daemon query through
them, and :meth:`check_invariants` asserts index ↔ scan agreement.

ID-space sharding (§5.1): every daemon iterates ``shard(items, i, n)`` —
instance ``i`` of ``n`` handles rows with ``id % n == i``.
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Iterable, Iterator, List, Optional, Set, Tuple

from .types import (
    App,
    AppVersion,
    Batch,
    Host,
    InstanceOutcome,
    InstanceState,
    Job,
    JobInstance,
    JobState,
    ValidateState,
    next_id,
)


def shard(ids: Iterable[int], instance: int, n_instances: int) -> Iterator[int]:
    """ID-space daemon sharding: (ID mod N) == i (§5.1)."""
    for i in ids:
        if i % n_instances == instance:
            yield i


_TERMINAL = (JobState.SUCCESS, JobState.FAILURE)


@dataclass
class JobStore:
    apps: Dict[str, App] = field(default_factory=dict)
    app_versions: Dict[int, AppVersion] = field(default_factory=dict)
    hosts: Dict[int, Host] = field(default_factory=dict)
    jobs: Dict[int, Job] = field(default_factory=dict)
    instances: Dict[int, JobInstance] = field(default_factory=dict)
    batches: Dict[int, Batch] = field(default_factory=dict)
    _by_job: Dict[int, List[int]] = field(default_factory=dict)
    # instances awaiting dispatch, FIFO per app; entries are dropped lazily
    # (from the head, or skipped mid-queue) once no longer UNSENT.
    # _unsent_ids mirrors queue membership exactly so re-enqueues (a row
    # returning to UNSENT while its stale entry is still mid-queue) can't
    # create duplicates
    _unsent: Dict[str, Deque[int]] = field(default_factory=dict)
    _unsent_ids: Dict[str, Set[int]] = field(default_factory=dict)
    # monotonically increasing DB "row version" for cheap change detection
    mutations: int = 0
    # daemon queries go through the maintained indexes; False selects the
    # original scan implementations (the oracle used for parity tests)
    use_indexes: bool = True

    # ---- maintained indexes (§5.1 "DB index" analogy) ----
    _jobs_by_state: Dict[JobState, Set[int]] = field(default_factory=dict)
    _insts_by_state: Dict[InstanceState, Set[int]] = field(default_factory=dict)
    transition_pending: Set[int] = field(default_factory=set)
    assimilate_pending: Set[int] = field(default_factory=set)
    delete_pending: Set[int] = field(default_factory=set)
    purge_pending: Set[int] = field(default_factory=set)
    batch_done_pending: Set[int] = field(default_factory=set)
    _batch_open: Dict[int, int] = field(default_factory=dict)
    # (deadline, instance_id) heap over IN_PROGRESS instances; entries are
    # validated on pop (state / deadline may have changed since push)
    _deadline_heap: List[Tuple[float, int]] = field(default_factory=list)
    # (created_time, job_id) heap over purge-pending jobs, so a purger with
    # a retention window (purge_delay, §4) pops only eligible rows instead
    # of re-visiting every completed-but-retained job each tick
    _purge_heap: List[Tuple[float, int]] = field(default_factory=list)
    # job_id -> host ids / volunteer ids ever assigned an instance
    _job_hosts: Dict[int, Set[int]] = field(default_factory=dict)
    _job_vols: Dict[int, Set[int]] = field(default_factory=dict)
    # validation-pending index (§3.4/§4): jobs holding >=1 *fresh* success —
    # an instance with state OVER, outcome SUCCESS, validate_state INIT.
    # These are exactly the jobs whose next transition may run the quorum
    # check; the batch validation engine reads this set to decide which
    # flagged jobs need the digest pass. Maintained from the per-job fresh
    # counts below on every tracked-field assignment.
    validation_pending: Set[int] = field(default_factory=set)
    _fresh_success: Dict[int, int] = field(default_factory=dict)
    # file-deletion readiness (§4): the deleter must retain a job's files
    # while any instance is outstanding (UNSENT / IN_PROGRESS). Rather than
    # re-scanning every delete-pending job's instances each tick, the store
    # keeps a per-job outstanding-instance count (maintained on instance
    # state transitions) and promotes a job into ``delete_ready`` the
    # moment its count hits zero — i.e. the re-check is deferred to
    # instance-*terminal* events.
    delete_ready: Set[int] = field(default_factory=set)
    _job_outstanding: Dict[int, int] = field(default_factory=dict)

    def __post_init__(self) -> None:
        for s in JobState:
            self._jobs_by_state.setdefault(s, set())
        for s in InstanceState:
            self._insts_by_state.setdefault(s, set())

    # ---- registration ----

    def add_app(self, app: App) -> App:
        self.apps[app.name] = app
        for v in app.versions:
            self.app_versions[v.id] = v
        self.mutations += 1
        return app

    def add_app_version(self, version: AppVersion) -> AppVersion:
        self.apps[version.app_name].add_version(version)
        self.app_versions[version.id] = version
        self.mutations += 1
        return version

    def add_host(self, host: Host) -> Host:
        self.hosts[host.id] = host
        self.mutations += 1
        return host

    def remove_host(self, host_id: int) -> None:
        self.hosts.pop(host_id, None)
        self.mutations += 1

    # ---- jobs & instances ----

    def submit_job(self, job: Job) -> Job:
        assert job.app_name in self.apps, f"unknown app {job.app_name}"
        job.transition_flag = True
        self.jobs[job.id] = job
        self._by_job.setdefault(job.id, [])
        self._jobs_by_state[job.state].add(job.id)
        if job.batch_id:
            self.batches.setdefault(
                job.batch_id, Batch(id=job.batch_id, submitter=job.submitter)
            ).job_ids.append(job.id)
            if job.state not in _TERMINAL and job.state != JobState.PURGED:
                self._batch_open[job.batch_id] = self._batch_open.get(job.batch_id, 0) + 1
                # the batch reopened: a momentarily-complete batch must not
                # keep its done flag
                self.batch_done_pending.discard(job.batch_id)
        object.__setattr__(job, "_store", self)  # begin observing mutations
        self._reindex_job(job)
        self.mutations += 1
        return job

    def create_instance(self, job: Job) -> JobInstance:
        inst = JobInstance(id=next_id("instance"), job_id=job.id)
        self.instances[inst.id] = inst
        self._by_job[job.id].append(inst.id)
        self._insts_by_state[inst.state].add(inst.id)
        self._outstanding_delta(job.id, 1)  # new instances start UNSENT
        self._unsent.setdefault(job.app_name, deque()).append(inst.id)
        self._unsent_ids.setdefault(job.app_name, set()).add(inst.id)
        object.__setattr__(inst, "_store", self)
        self.mutations += 1
        return inst

    def job_instances(self, job_id: int) -> List[JobInstance]:
        return [self.instances[i] for i in self._by_job.get(job_id, [])]

    def unsent_instances(
        self,
        app_name: str,
        limit: int = 0,
        exclude: Optional[Set[int]] = None,
    ) -> List[JobInstance]:
        """First ``limit`` dispatchable instances of ``app_name``, FIFO.

        ``exclude`` (the feeder passes its in-cache set) skips instance ids
        without counting them toward ``limit`` — otherwise a backlog larger
        than the cache would keep returning the already-cached queue head
        and the feeder could never refill past it.

        O(limit + skipped + dropped): dead entries are popped from the
        queue head; dead entries deeper in the queue are skipped (and
        dropped once they surface at the head) instead of rebuilding the
        whole list per call.
        """
        q = self._unsent.get(app_name)
        if not q:
            return []
        insts = self.instances
        ids = self._unsent_ids.get(app_name, set())
        while q:  # compact the head so the queue cannot grow unboundedly
            inst = insts.get(q[0])
            if inst is not None and inst.state == InstanceState.UNSENT:
                break
            ids.discard(q.popleft())
        out: List[JobInstance] = []
        for iid in q:
            if exclude is not None and iid in exclude:
                continue
            inst = insts.get(iid)
            if inst is None or inst.state != InstanceState.UNSENT:
                continue
            out.append(inst)
            if limit and len(out) >= limit:
                break
        return out

    def host_has_instance_of_job(self, host_id: int, job_id: int) -> bool:
        """One-instance-per-host rule ('slow check', §6.4) — BOINC actually
        enforces one per *volunteer*; we key on the volunteer of record
        captured at dispatch time."""
        if self.use_indexes:
            if host_id in self._job_hosts.get(job_id, ()):
                return True
            host = self.hosts.get(host_id)
            return host is not None and host.volunteer_id in self._job_vols.get(job_id, ())
        # oracle path: resolve the volunteer via the hosts table at query
        # time (the seed semantics), independent of the observer-captured
        # assignment sets it is used to cross-check
        host = self.hosts.get(host_id)
        vol = host.volunteer_id if host else None
        for inst in self.job_instances(job_id):
            if inst.host_id is None:
                continue
            h = self.hosts.get(inst.host_id)
            if inst.host_id == host_id or (vol is not None and h is not None and h.volunteer_id == vol):
                return True
        return False

    def hosts_with_instance(self, job_id: int) -> Set[int]:
        """Host ids ever assigned an instance of ``job_id`` (work-spreading
        constraint, §3.4). Index path reads the maintained assignment set;
        the oracle path rebuilds it by scanning the job's instances — the
        two agree because instances persist until the job row is purged and
        the purge pops the set."""
        if self.use_indexes:
            return self._job_hosts.get(job_id, set())
        return {i.host_id for i in self.job_instances(job_id) if i.host_id is not None}

    def in_progress_instances(self) -> List[JobInstance]:
        """IN_PROGRESS instances in ascending id order (defense spread
        sweep). Index path reads the state index; oracle path scans."""
        if self.use_indexes:
            insts = self.instances
            return [
                insts[iid]
                for iid in sorted(self._insts_by_state[InstanceState.IN_PROGRESS])
                if iid in insts
            ]
        return sorted(
            (i for i in self.instances.values()
             if i.state == InstanceState.IN_PROGRESS),
            key=lambda i: i.id,
        )

    def unsent_job_ids(self) -> Set[int]:
        """Job ids with at least one UNSENT instance (defense HR-relax
        sweep). Index path reads the state index; oracle path scans."""
        if self.use_indexes:
            insts = self.instances
            return {
                insts[iid].job_id
                for iid in self._insts_by_state[InstanceState.UNSENT]
                if iid in insts
            }
        return {
            i.job_id
            for i in self.instances.values()
            if i.state == InstanceState.UNSENT
        }

    # ---- batch bookkeeping (§3.9) ----

    def batch_done(self, batch_id: int) -> bool:
        b = self.batches.get(batch_id)
        if b is None:
            return False
        if self.use_indexes:
            return bool(b.job_ids) and self._batch_open.get(batch_id, 0) <= 0
        return all(
            j not in self.jobs or self.jobs[j].state in _TERMINAL
            for j in b.job_ids  # rows already purged count as done (§4)
        )

    def drain_completed_batches(self) -> List[int]:
        """Batches whose last job just reached a terminal state, ascending."""
        out = sorted(self.batch_done_pending)
        self.batch_done_pending.clear()
        return out

    # ---- queries for daemons ----
    #
    # ``pending_*`` are what the daemons consume: the indexed path reads the
    # maintained queues (O(pending)); the oracle path falls back to the
    # original full scans. Both return ascending job id for determinism.

    def pending_transitions(self, instance: int = 0, n_instances: int = 1) -> List[Job]:
        if self.use_indexes:
            ids = self.transition_pending
        else:
            ids = (j.id for j in self.jobs_with_flag())
        return [self.jobs[j] for j in sorted(shard(ids, instance, n_instances))]

    def pending_validation(self, instance: int = 0, n_instances: int = 1) -> Set[int]:
        """Job ids (one daemon shard) with at least one fresh success — an
        OVER/SUCCESS instance whose validate_state is still INIT.

        The batch validation engine intersects this with the flagged-job
        list to pick the jobs that need the digest pass; the oracle path
        falls back to a full instance-table scan.
        """
        if self.use_indexes:
            ids: Iterable[int] = self.validation_pending
        else:
            ids = {
                inst.job_id
                for inst in self.instances.values()
                if _is_fresh_success(inst)
            }
        return set(shard(ids, instance, n_instances))

    def pending_assimilation(self) -> List[Job]:
        source = self.assimilate_pending if self.use_indexes else (
            j.id for j in self.jobs_to_assimilate()
        )
        return [self.jobs[j] for j in sorted(source)]

    def pending_file_deletion(self) -> List[Job]:
        """Delete-pending jobs that are *ready* — no outstanding (UNSENT /
        IN_PROGRESS) instance. The indexed path reads ``delete_ready``, so
        jobs blocked on a straggler instance cost nothing per tick: their
        re-check is deferred to the instance-terminal event that drops the
        outstanding count to zero. The oracle path keeps the original scan
        over all delete-pending jobs — the deleter daemon re-applies the
        outstanding check itself, so both paths converge on the same jobs.
        """
        source = self.delete_ready if self.use_indexes else (
            j.id for j in self.jobs_to_delete_files()
        )
        return [self.jobs[j] for j in sorted(source)]

    def purgeable_jobs(self, cutoff: float) -> List[Job]:
        """Purge-pending jobs with ``created_time <= cutoff``, ascending id.

        Indexed path: pops the purge heap down to ``cutoff`` — jobs inside
        the retention window stay heaped and cost nothing per tick. Popped
        jobs are expected to be purged by the caller (the purger daemon);
        stale entries are dropped on pop.
        """
        if not self.use_indexes:
            return sorted(
                (j for j in self.jobs_to_purge() if j.created_time <= cutoff),
                key=lambda j: j.id,
            )
        out: List[Job] = []
        h = self._purge_heap
        while h and h[0][0] <= cutoff:
            created, jid = heapq.heappop(h)
            job = self.jobs.get(jid)
            if job is None or jid not in self.purge_pending or job.created_time != created:
                continue  # stale entry
            out.append(job)
        out.sort(key=lambda j: j.id)
        return out

    def expired_instances(self, now: float, instance: int = 0, n_instances: int = 1) -> List[JobInstance]:
        """IN_PROGRESS instances past deadline, for one daemon shard (§5.1).

        Indexed path: pop the deadline heap down to ``now`` — O(expired log
        heap) instead of a full instance-table scan. Entries belonging to
        other shards are pushed back for their transitioner instance.
        """
        if not self.use_indexes:
            return [
                inst
                for inst in self.instances.values()
                if inst.state == InstanceState.IN_PROGRESS
                and now > inst.deadline > 0
                and inst.job_id % n_instances == instance
            ]
        h = self._deadline_heap
        in_progress = self._insts_by_state[InstanceState.IN_PROGRESS]
        if len(h) > 1024 and len(h) > 4 * len(in_progress):
            # mostly-stale heap (instances completed before their deadline):
            # rebuild from live rows so pops stay O(expired)
            h[:] = [
                (inst.deadline, iid)
                for iid in in_progress
                if (inst := self.instances[iid]).deadline > 0
            ]
            heapq.heapify(h)
        out: List[JobInstance] = []
        other_shards: List[Tuple[float, int]] = []
        while h and h[0][0] < now:
            deadline, iid = heapq.heappop(h)
            inst = self.instances.get(iid)
            if (
                inst is None
                or inst.state != InstanceState.IN_PROGRESS
                or inst.deadline != deadline
                or deadline <= 0
            ):
                continue  # stale entry
            if inst.job_id % n_instances != instance:
                other_shards.append((deadline, iid))
                continue
            out.append(inst)
        for entry in other_shards:
            heapq.heappush(h, entry)
        return out

    def status_counts(self) -> Dict[str, int]:
        if self.use_indexes:
            return {
                "jobs_active": len(self._jobs_by_state[JobState.ACTIVE]),
                "jobs_success": len(self._jobs_by_state[JobState.SUCCESS]),
                "jobs_failure": len(self._jobs_by_state[JobState.FAILURE]),
                "instances_unsent": len(self._insts_by_state[InstanceState.UNSENT]),
                "instances_in_progress": len(self._insts_by_state[InstanceState.IN_PROGRESS]),
            }
        jobs = self.jobs.values()
        return {
            "jobs_active": sum(1 for j in jobs if j.state == JobState.ACTIVE),
            "jobs_success": sum(1 for j in self.jobs.values() if j.state == JobState.SUCCESS),
            "jobs_failure": sum(1 for j in self.jobs.values() if j.state == JobState.FAILURE),
            "instances_unsent": sum(
                1 for i in self.instances.values() if i.state == InstanceState.UNSENT
            ),
            "instances_in_progress": sum(
                1 for i in self.instances.values() if i.state == InstanceState.IN_PROGRESS
            ),
        }

    # ---- scan queries (debug / oracle path) ----

    def jobs_with_flag(self) -> List[Job]:
        return [j for j in self.jobs.values() if j.transition_flag and j.state == JobState.ACTIVE]

    def jobs_to_assimilate(self) -> List[Job]:
        return [
            j
            for j in self.jobs.values()
            if j.state in _TERMINAL and not j.assimilated
        ]

    def jobs_to_delete_files(self) -> List[Job]:
        return [
            j
            for j in self.jobs.values()
            if j.assimilated and not j.files_deleted
        ]

    def jobs_to_purge(self) -> List[Job]:
        return [
            j
            for j in self.jobs.values()
            if j.assimilated and j.files_deleted and j.state != JobState.PURGED
        ]

    def purge_job(self, job: Job) -> None:
        """Remove completed rows; the DB is a cache of jobs in progress, not
        an archive (§4)."""
        jid = job.id
        for iid in self._by_job.get(jid, []):
            inst = self.instances.pop(iid, None)
            if inst is not None:
                self._insts_by_state[inst.state].discard(iid)
                object.__setattr__(inst, "_store", None)
        self._by_job.pop(jid, None)
        self._job_hosts.pop(jid, None)
        self._job_vols.pop(jid, None)
        self._fresh_success.pop(jid, None)
        self.validation_pending.discard(jid)
        self._job_outstanding.pop(jid, None)
        self.delete_ready.discard(jid)
        job.state = JobState.PURGED
        self.jobs.pop(jid, None)
        self._jobs_by_state[JobState.PURGED].discard(jid)
        for pending in (
            self.transition_pending,
            self.assimilate_pending,
            self.delete_pending,
            self.purge_pending,
        ):
            pending.discard(jid)
        object.__setattr__(job, "_store", None)
        self.mutations += 1

    # ------------------------------------------------------------------
    # index maintenance: rows notify us on tracked-field assignment
    # (types.IndexObserved) — the moral equivalent of index updates
    # riding along with every UPDATE in the real schema (§5.1)
    # ------------------------------------------------------------------

    def _on_field_change(self, row, name: str, old, new) -> None:
        if isinstance(row, Job):
            self._job_changed(row, name, old, new)
        else:
            self._instance_changed(row, name, old, new)

    def _job_changed(self, job: Job, name: str, old, new) -> None:
        if name == "transition_flag":
            # hot path (every report/clear toggles it): only the
            # transition-pending set can change
            _set_membership(
                self.transition_pending, job.id,
                new and job.state == JobState.ACTIVE,
            )
            return
        if name == "state":
            self._jobs_by_state[old].discard(job.id)
            self._jobs_by_state[new].add(job.id)
            if job.batch_id:
                was_open = old not in _TERMINAL and old != JobState.PURGED
                is_open = new not in _TERMINAL and new != JobState.PURGED
                if was_open and not is_open:
                    left = self._batch_open.get(job.batch_id, 0) - 1
                    self._batch_open[job.batch_id] = left
                    if left <= 0:
                        b = self.batches.get(job.batch_id)
                        if b is not None and b.job_ids and b.completed_time is None:
                            self.batch_done_pending.add(job.batch_id)
                elif is_open and not was_open:
                    self._batch_open[job.batch_id] = self._batch_open.get(job.batch_id, 0) + 1
                    self.batch_done_pending.discard(job.batch_id)
        self._reindex_job(job)

    def _reindex_job(self, job: Job) -> None:
        jid = job.id
        _set_membership(
            self.transition_pending, jid,
            job.transition_flag and job.state == JobState.ACTIVE,
        )
        _set_membership(
            self.assimilate_pending, jid,
            job.state in _TERMINAL and not job.assimilated,
        )
        delete_pending = job.assimilated and not job.files_deleted
        _set_membership(self.delete_pending, jid, delete_pending)
        _set_membership(
            self.delete_ready, jid,
            delete_pending and self._job_outstanding.get(jid, 0) == 0,
        )
        want_purge = job.assimilated and job.files_deleted and job.state != JobState.PURGED
        if want_purge and jid not in self.purge_pending:
            heapq.heappush(self._purge_heap, (job.created_time, jid))
        _set_membership(self.purge_pending, jid, want_purge)

    def _instance_changed(self, inst: JobInstance, name: str, old, new) -> None:
        # validation-pending maintenance: the freshness predicate depends on
        # (state, outcome, validate_state); evaluate the before/after pair
        # inline with the two unchanged fields short-circuiting first
        if name == "state":
            if (
                inst.outcome is InstanceOutcome.SUCCESS
                and inst.validate_state is ValidateState.INIT
            ):
                was = old is InstanceState.OVER
                now_fresh = new is InstanceState.OVER
                if was != now_fresh:
                    self._fresh_delta(inst.job_id, 1 if now_fresh else -1)
        elif name == "outcome":
            if (
                inst.state is InstanceState.OVER
                and inst.validate_state is ValidateState.INIT
            ):
                was = old is InstanceOutcome.SUCCESS
                now_fresh = new is InstanceOutcome.SUCCESS
                if was != now_fresh:
                    self._fresh_delta(inst.job_id, 1 if now_fresh else -1)
            return
        elif name == "validate_state":
            if (
                inst.state is InstanceState.OVER
                and inst.outcome is InstanceOutcome.SUCCESS
            ):
                was = old is ValidateState.INIT
                now_fresh = new is ValidateState.INIT
                if was != now_fresh:
                    self._fresh_delta(inst.job_id, 1 if now_fresh else -1)
            return
        if name == "state":
            self._insts_by_state[old].discard(inst.id)
            self._insts_by_state[new].add(inst.id)
            was_out = old in (InstanceState.UNSENT, InstanceState.IN_PROGRESS)
            now_out = new in (InstanceState.UNSENT, InstanceState.IN_PROGRESS)
            if was_out != now_out:
                self._outstanding_delta(inst.job_id, 1 if now_out else -1)
            if new == InstanceState.IN_PROGRESS and inst.deadline > 0:
                heapq.heappush(self._deadline_heap, (inst.deadline, inst.id))
            elif new == InstanceState.UNSENT:
                # a row returned to the dispatchable pool re-enters the
                # queue — unless its previous entry is still queued (it
                # simply becomes live again)
                job = self.jobs.get(inst.job_id)
                if job is not None:
                    queued = self._unsent_ids.setdefault(job.app_name, set())
                    if inst.id not in queued:
                        self._unsent.setdefault(job.app_name, deque()).append(inst.id)
                        queued.add(inst.id)
        elif name == "deadline":
            if inst.state == InstanceState.IN_PROGRESS and new > 0:
                heapq.heappush(self._deadline_heap, (new, inst.id))
        elif name == "host_id" and new is not None:
            self._job_hosts.setdefault(inst.job_id, set()).add(new)
            host = self.hosts.get(new)
            if host is not None:
                inst.volunteer_id = host.volunteer_id
                self._job_vols.setdefault(inst.job_id, set()).add(host.volunteer_id)

    def clear_transition_flags(self, jobs: List[Job]) -> None:
        """Bulk flag clear for one tick's pending list (batch validation
        engine): same end state as per-job ``transition_flag = False``, with
        one set-difference instead of per-write observer dispatch."""
        for job in jobs:
            object.__setattr__(job, "transition_flag", False)
        self.transition_pending.difference_update([j.id for j in jobs])

    def finish_jobs(self, entries: List[Tuple[Job, int]]) -> None:
        """Bulk ACTIVE→SUCCESS completion for one tick's decided jobs
        (batch validation engine): ``(job, canonical_instance_id)`` pairs.

        Replicates exactly what per-field assignment would do — state-set
        moves, transition/assimilate pending membership, batch open-count
        bookkeeping — as fused set operations. The jobs are ACTIVE (so not
        yet assimilated; the delete/purge indexes cannot change) and end
        with ``transition_flag=True`` exactly like the scalar
        ``_validate`` epilogue. ``check_invariants`` cross-checks this
        against the scan semantics.
        """
        ids = []
        for job, canonical_id in entries:
            job.canonical_instance_id = canonical_id
            object.__setattr__(job, "state", JobState.SUCCESS)
            object.__setattr__(job, "transition_flag", True)
            ids.append(job.id)
            if job.batch_id:
                left = self._batch_open.get(job.batch_id, 0) - 1
                self._batch_open[job.batch_id] = left
                if left <= 0:
                    b = self.batches.get(job.batch_id)
                    if b is not None and b.job_ids and b.completed_time is None:
                        self.batch_done_pending.add(job.batch_id)
        self._jobs_by_state[JobState.ACTIVE].difference_update(ids)
        self._jobs_by_state[JobState.SUCCESS].update(ids)
        # flag is set but the job is no longer ACTIVE: not transition-pending
        self.transition_pending.difference_update(ids)
        self.assimilate_pending.update(ids)

    def set_validate_states(self, insts: List[JobInstance], vstate: ValidateState) -> None:
        """Bulk validate_state assignment (batch validation engine): same
        index maintenance as per-field assignment, minus the per-write
        observer dispatch; freshness deltas are aggregated per job before
        touching the validation-pending index."""
        deltas: Dict[int, int] = {}
        to_init = vstate is ValidateState.INIT
        init = ValidateState.INIT
        over = InstanceState.OVER
        success = InstanceOutcome.SUCCESS
        for inst in insts:
            d = inst.__dict__
            old = d.get("validate_state")
            if old is vstate:
                continue
            d["validate_state"] = vstate
            if d.get("_store") is None:
                continue
            if d["state"] is over and d["outcome"] is success:
                if (old is init) != to_init:
                    jid = inst.job_id
                    deltas[jid] = deltas.get(jid, 0) + (1 if to_init else -1)
        for jid, delta in deltas.items():
            self._fresh_delta(jid, delta)

    def _outstanding_delta(self, job_id: int, delta: int) -> None:
        """Maintain the per-job outstanding-instance count and, for
        delete-pending jobs, the readiness set — the instance-terminal
        event that replaces the deleter's per-tick re-scan."""
        c = self._job_outstanding.get(job_id, 0) + delta
        if c <= 0:
            self._job_outstanding.pop(job_id, None)
        else:
            self._job_outstanding[job_id] = c
        if job_id in self.delete_pending:
            _set_membership(self.delete_ready, job_id, c <= 0)

    def _fresh_delta(self, job_id: int, delta: int) -> None:
        c = self._fresh_success.get(job_id, 0) + delta
        if c <= 0:
            self._fresh_success.pop(job_id, None)
            self.validation_pending.discard(job_id)
        else:
            self._fresh_success[job_id] = c
            self.validation_pending.add(job_id)

    # ------------------------------------------------------------------
    # invariant checker: index ↔ scan agreement
    # ------------------------------------------------------------------

    def check_invariants(self) -> None:
        """Assert every maintained index agrees with a full-table scan.

        This is the oracle tying the O(dirty) daemon path back to the seed
        semantics; tests and the simulator audit path call it.
        """
        problems: List[str] = []

        expect_jobs: Dict[JobState, Set[int]] = {s: set() for s in JobState}
        for j in self.jobs.values():
            expect_jobs[j.state].add(j.id)
        for s in JobState:
            if self._jobs_by_state[s] != expect_jobs[s]:
                problems.append(
                    f"jobs_by_state[{s}] diverged: "
                    f"extra={sorted(self._jobs_by_state[s] - expect_jobs[s])[:5]} "
                    f"missing={sorted(expect_jobs[s] - self._jobs_by_state[s])[:5]}"
                )

        expect_insts: Dict[InstanceState, Set[int]] = {s: set() for s in InstanceState}
        for i in self.instances.values():
            expect_insts[i.state].add(i.id)
        for s in InstanceState:
            if self._insts_by_state[s] != expect_insts[s]:
                problems.append(f"insts_by_state[{s}] diverged")

        scans = {
            "transition_pending": (self.transition_pending, self.jobs_with_flag()),
            "assimilate_pending": (self.assimilate_pending, self.jobs_to_assimilate()),
            "delete_pending": (self.delete_pending, self.jobs_to_delete_files()),
            "purge_pending": (self.purge_pending, self.jobs_to_purge()),
        }
        for label, (idx, scan) in scans.items():
            scan_ids = {j.id for j in scan}
            if idx != scan_ids:
                problems.append(
                    f"{label} diverged: extra={sorted(idx - scan_ids)[:5]} "
                    f"missing={sorted(scan_ids - idx)[:5]}"
                )

        for bid, b in self.batches.items():
            expect_open = sum(
                1
                for j in b.job_ids
                if (jb := self.jobs.get(j)) is not None and jb.state == JobState.ACTIVE
            )
            if self._batch_open.get(bid, 0) != expect_open:
                problems.append(
                    f"batch {bid} open-count {self._batch_open.get(bid, 0)} != scan {expect_open}"
                )
        for bid in self.batch_done_pending:
            if self._batch_open.get(bid, 0) > 0:
                problems.append(f"batch {bid} flagged done with open jobs")

        live_deadlines = {
            (inst.deadline, iid)
            for iid, inst in self.instances.items()
            if inst.state == InstanceState.IN_PROGRESS and inst.deadline > 0
        }
        missing = live_deadlines - set(self._deadline_heap)
        if missing:
            problems.append(f"deadline heap missing live entries: {sorted(missing)[:5]}")

        live_purge = {
            (self.jobs[jid].created_time, jid)
            for jid in self.purge_pending
            if jid in self.jobs
        }
        missing_purge = live_purge - set(self._purge_heap)
        if missing_purge:
            problems.append(f"purge heap missing live entries: {sorted(missing_purge)[:5]}")

        queued: Set[int] = set()
        for app_name, q in self._unsent.items():
            entries = set(q)
            if len(entries) != len(q):
                problems.append(f"dispatch queue for {app_name!r} has duplicate entries")
            if entries != self._unsent_ids.get(app_name, set()):
                problems.append(f"dispatch-queue mirror set for {app_name!r} diverged")
            queued.update(entries)
        for iid in self._insts_by_state[InstanceState.UNSENT]:
            if iid not in queued:
                problems.append(f"UNSENT instance {iid} not in any dispatch queue")
                break

        expect_fresh: Dict[int, int] = {}
        for i in self.instances.values():
            if _is_fresh_success(i):
                expect_fresh[i.job_id] = expect_fresh.get(i.job_id, 0) + 1
        if self._fresh_success != expect_fresh:
            diff = set(self._fresh_success.items()) ^ set(expect_fresh.items())
            problems.append(f"fresh-success counts diverged: {sorted(diff)[:5]}")
        if self.validation_pending != set(expect_fresh):
            problems.append(
                "validation_pending diverged: "
                f"extra={sorted(self.validation_pending - set(expect_fresh))[:5]} "
                f"missing={sorted(set(expect_fresh) - self.validation_pending)[:5]}"
            )

        expect_out: Dict[int, int] = {}
        for i in self.instances.values():
            if i.state in (InstanceState.UNSENT, InstanceState.IN_PROGRESS):
                expect_out[i.job_id] = expect_out.get(i.job_id, 0) + 1
        if self._job_outstanding != expect_out:
            diff = set(self._job_outstanding.items()) ^ set(expect_out.items())
            problems.append(f"outstanding-instance counts diverged: {sorted(diff)[:5]}")
        expect_ready = {
            j.id
            for j in self.jobs_to_delete_files()
            if not any(i.is_outstanding() for i in self.job_instances(j.id))
        }
        if self.delete_ready != expect_ready:
            problems.append(
                "delete_ready diverged: "
                f"extra={sorted(self.delete_ready - expect_ready)[:5]} "
                f"missing={sorted(expect_ready - self.delete_ready)[:5]}"
            )

        expect_hosts: Dict[int, Set[int]] = {}
        expect_vols: Dict[int, Set[int]] = {}
        for inst in self.instances.values():
            if inst.host_id is not None:
                expect_hosts.setdefault(inst.job_id, set()).add(inst.host_id)
            if inst.volunteer_id is not None:
                expect_vols.setdefault(inst.job_id, set()).add(inst.volunteer_id)
        for label, idx, expect in (
            ("job_hosts", self._job_hosts, expect_hosts),
            ("job_vols", self._job_vols, expect_vols),
        ):
            for jid, members in expect.items():
                if not members <= idx.get(jid, set()):
                    problems.append(f"{label}[{jid}] missing assignments")
                    break

        if problems:
            raise AssertionError("store index invariants violated:\n  " + "\n  ".join(problems))


def _set_membership(s: Set[int], item: int, member: bool) -> None:
    if member:
        s.add(item)
    else:
        s.discard(item)


def _is_fresh_success(inst: JobInstance, **override) -> bool:
    """The validation-pending predicate (§4): a completed success whose
    validate_state is still INIT. ``override`` substitutes one field's prior
    value so observers can evaluate the predicate before a mutation."""
    state = override.get("state", inst.state)
    outcome = override.get("outcome", inst.outcome)
    vstate = override.get("validate_state", inst.validate_state)
    return (
        state == InstanceState.OVER
        and outcome == InstanceOutcome.SUCCESS
        and vstate == ValidateState.INIT
    )
