"""In-memory job database (the relational DB of §5.1).

The real BOINC server centers on MySQL; here the store is an indexed
in-memory structure with the same role: the single point of coordination
between scheduler instances and daemons. Daemons communicate *only* through
this store (flags on rows), which is what makes the multi-daemon
architecture fault-tolerant: a stopped daemon's work accumulates here.

ID-space sharding (§5.1): every daemon iterates ``shard(items, i, n)`` —
instance ``i`` of ``n`` handles rows with ``id % n == i``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

from .types import (
    App,
    AppVersion,
    Batch,
    Host,
    InstanceOutcome,
    InstanceState,
    Job,
    JobInstance,
    JobState,
    next_id,
)


def shard(ids: Iterable[int], instance: int, n_instances: int) -> Iterator[int]:
    """ID-space daemon sharding: (ID mod N) == i (§5.1)."""
    for i in ids:
        if i % n_instances == instance:
            yield i


@dataclass
class JobStore:
    apps: Dict[str, App] = field(default_factory=dict)
    app_versions: Dict[int, AppVersion] = field(default_factory=dict)
    hosts: Dict[int, Host] = field(default_factory=dict)
    jobs: Dict[int, Job] = field(default_factory=dict)
    instances: Dict[int, JobInstance] = field(default_factory=dict)
    batches: Dict[int, Batch] = field(default_factory=dict)
    _by_job: Dict[int, List[int]] = field(default_factory=dict)
    # instances awaiting dispatch, FIFO per app
    _unsent: Dict[str, List[int]] = field(default_factory=dict)
    # monotonically increasing DB "row version" for cheap change detection
    mutations: int = 0

    # ---- registration ----

    def add_app(self, app: App) -> App:
        self.apps[app.name] = app
        for v in app.versions:
            self.app_versions[v.id] = v
        self.mutations += 1
        return app

    def add_app_version(self, version: AppVersion) -> AppVersion:
        self.apps[version.app_name].add_version(version)
        self.app_versions[version.id] = version
        self.mutations += 1
        return version

    def add_host(self, host: Host) -> Host:
        self.hosts[host.id] = host
        self.mutations += 1
        return host

    def remove_host(self, host_id: int) -> None:
        self.hosts.pop(host_id, None)
        self.mutations += 1

    # ---- jobs & instances ----

    def submit_job(self, job: Job) -> Job:
        assert job.app_name in self.apps, f"unknown app {job.app_name}"
        self.jobs[job.id] = job
        self._by_job.setdefault(job.id, [])
        job.transition_flag = True
        if job.batch_id:
            self.batches.setdefault(
                job.batch_id, Batch(id=job.batch_id, submitter=job.submitter)
            ).job_ids.append(job.id)
        self.mutations += 1
        return job

    def create_instance(self, job: Job) -> JobInstance:
        inst = JobInstance(id=next_id("instance"), job_id=job.id)
        self.instances[inst.id] = inst
        self._by_job[job.id].append(inst.id)
        self._unsent.setdefault(job.app_name, []).append(inst.id)
        self.mutations += 1
        return inst

    def job_instances(self, job_id: int) -> List[JobInstance]:
        return [self.instances[i] for i in self._by_job.get(job_id, [])]

    def unsent_instances(self, app_name: str, limit: int = 0) -> List[JobInstance]:
        ids = self._unsent.get(app_name, [])
        out: List[JobInstance] = []
        kept: List[int] = []
        for iid in ids:
            inst = self.instances.get(iid)
            if inst is None or inst.state != InstanceState.UNSENT:
                continue  # lazily drop stale queue entries
            kept.append(iid)
            if not limit or len(out) < limit:
                out.append(inst)
        self._unsent[app_name] = kept
        return out

    def requeue_unsent(self, inst: JobInstance) -> None:
        """Return an instance to the dispatch queue (feeder refill path)."""
        job = self.jobs[inst.job_id]
        self._unsent.setdefault(job.app_name, []).append(inst.id)

    def host_has_instance_of_job(self, host_id: int, job_id: int) -> bool:
        """One-instance-per-host rule ('slow check', §6.4) — BOINC actually
        enforces one per *volunteer*; we key on host's volunteer."""
        host = self.hosts.get(host_id)
        vol = host.volunteer_id if host else None
        for inst in self.job_instances(job_id):
            if inst.host_id is None:
                continue
            h = self.hosts.get(inst.host_id)
            if inst.host_id == host_id or (vol is not None and h and h.volunteer_id == vol):
                return True
        return False

    # ---- batch bookkeeping (§3.9) ----

    def batch_done(self, batch_id: int) -> bool:
        b = self.batches.get(batch_id)
        if b is None:
            return False
        return all(
            self.jobs[j].state in (JobState.SUCCESS, JobState.FAILURE)
            for j in b.job_ids
        )

    # ---- queries for daemons ----

    def jobs_with_flag(self) -> List[Job]:
        return [j for j in self.jobs.values() if j.transition_flag and j.state == JobState.ACTIVE]

    def jobs_to_assimilate(self) -> List[Job]:
        return [
            j
            for j in self.jobs.values()
            if j.state in (JobState.SUCCESS, JobState.FAILURE) and not j.assimilated
        ]

    def jobs_to_delete_files(self) -> List[Job]:
        return [
            j
            for j in self.jobs.values()
            if j.assimilated and not j.files_deleted
        ]

    def jobs_to_purge(self) -> List[Job]:
        return [
            j
            for j in self.jobs.values()
            if j.assimilated and j.files_deleted and j.state != JobState.PURGED
        ]

    def purge_job(self, job: Job) -> None:
        """Remove completed rows; the DB is a cache of jobs in progress, not
        an archive (§4)."""
        for iid in self._by_job.get(job.id, []):
            self.instances.pop(iid, None)
        self._by_job.pop(job.id, None)
        job.state = JobState.PURGED
        self.jobs.pop(job.id, None)
        self.mutations += 1
