"""EmBOINC-style virtual-time emulator (§9).

"researchers began using emulation — simulators using the actual BOINC code
to model client and server behavior ... EmBOINC combines a simulator of a
large population of volunteer hosts (driven either by trace data or by a
random model) with an emulator of a project server — that is, the actual
server software ... using virtual time instead of real time."

This module does exactly that: a deterministic event-driven simulator whose
host population drives the *actual* ``ProjectServer`` / ``Client`` /
``Scheduler`` / ``Transitioner`` code in virtual time. All paper-claim
benchmarks and the integration tests run on it.

Two event loops share one world. Per-host state (availability,
generation counters, running-instance accrual, the mirrored client queues)
lives in the persistent columnar :class:`~repro.core.world.HostArrays`
(``core/world.py``), maintained incrementally at mutation time. The
**scalar oracle** (``vector_world=False``) pops one event at a time and
performs per-host operations against those columns — the parity reference.
The **vectorized loop** (``vector_world=True``) drains maximal runs of
same-timestamp, same-kind events (exactly the grouping the oracle's
coalescing produces, so cross-mode event order is identical), advances
accrual for every affected host in one fused array pass, detects
completions as a single mask over the accrual matrix, samples availability
toggles from FIFO-prefetched exponential draw batches, routes every
scheduler RPC through the persistent vectorized dispatch snapshot, and
feeds the batch client engine straight from the world columns. Whole-run
results — SimMetrics, job states, granted credit — are bit-identical
between the two loops (``tests/test_world.py``).

``epoch`` quantizes event times up to a fixed grid (0 disables). Both
loops share the quantization, so parity holds at any epoch; with it, event
coalescing — and therefore the vectorized loop's advantage — grows with
the population (``benchmarks/bench_world.py``).
"""
from __future__ import annotations

import heapq
import math
import random
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from .batch_client import BatchClientEngine
from .client import (
    Client,
    ClientJob,
    ClientPrefs,
    ClientResource,
    ProjectAttachment,
    RunState,
)
from .credit import peak_flop_count
from .scheduler import CompletedResult, ResourceRequest, ScheduleRequest
from .server import ProjectServer
from .types import (
    Host,
    InstanceOutcome,
    Platform,
    ProcessingResource,
    ResourceType,
    ValidateState,
)
from .world import HostArrays

# ---------------------------------------------------------------------------
# Host population model (EmBOINC's "random model")
# ---------------------------------------------------------------------------


@dataclass
class HostSpec:
    """Behavioural model of one volunteer host."""

    host: Host
    efficiency: float = 0.5  # actual/peak FLOPS (§7: varies ~2x between hosts)
    runtime_noise: float = 0.1  # lognormal sigma on job runtimes
    error_prob: float = 0.0  # hardware flakiness: wrong output
    crash_prob: float = 0.0  # app crash: CLIENT_ERROR
    malicious: bool = False  # intentionally wrong results (§3.4)
    cheat_prob: float = 1.0  # if malicious, P(fake result)
    avail_on_mean: float = 8 * 3600.0  # §1.1: availability ~60%/40%
    avail_off_mean: float = 4 * 3600.0
    churn_time: Optional[float] = None  # permanent departure (device churn)
    rpc_poll: float = 600.0
    # -- scenario-layer extensions (core/scenarios.py) --
    # Colluding clique id: malicious hosts sharing a group fabricate the
    # *identical* wrong payload per job, so they validate each other
    # (quorum defeat, §3.4's attack model). None => independent corruption.
    collusion_group: Optional[int] = None
    # Credit farming: reported peak_flop_count is inflated by this factor
    # (the §7 normalization/outlier-robust grant is the defense under test).
    claim_factor: float = 1.0
    # Trace-replayed availability: absolute toggle times (host starts
    # online; each time flips the state). When set, availability is driven
    # entirely by this schedule — no RNG draws — so trace-driven runs keep
    # scalar/vector RNG-stream parity trivially. Exhausted schedules leave
    # the host in its final state.
    avail_schedule: Optional[Tuple[float, ...]] = None


def make_population(
    n_hosts: int,
    seed: int = 0,
    cpu_flops: float = 16.5e9,  # paper §1.1: average 16.5 CPU GigaFLOPS
    gpu_fraction: float = 0.0,
    gpu_flops: float = 1e12,
    ncpus: int = 4,
    error_prob: float = 0.0,
    malicious_fraction: float = 0.0,
    availability: float = 1.0,
    churn_rate: float = 0.0,  # departures per host per simulated second
    horizon: float = 0.0,
    speed_spread: float = 0.5,
) -> List[HostSpec]:
    """Random host population: heterogeneous speeds (lognormal), OSes per the
    paper's 85/7/7 Windows/Mac/Linux split, optional GPUs, availability and
    churn processes, and a malicious subset."""
    rng = random.Random(seed)
    out: List[HostSpec] = []
    for i in range(n_hosts):
        r = rng.random()
        os_name = "windows" if r < 0.85 else ("mac" if r < 0.92 else "linux")
        speed = cpu_flops * math.exp(rng.gauss(0.0, speed_spread))
        resources = {
            ResourceType.CPU: ProcessingResource(
                rtype=ResourceType.CPU,
                ninstances=ncpus,
                peak_flops=speed,
                availability=availability,
            )
        }
        platforms = [Platform(os_name, "x86_64")]
        if rng.random() < gpu_fraction:
            resources[ResourceType.GPU] = ProcessingResource(
                rtype=ResourceType.GPU,
                ninstances=1,
                peak_flops=gpu_flops * math.exp(rng.gauss(0.0, speed_spread)),
                availability=availability,
            )
        host = Host(
            id=i + 1,
            platforms=tuple(platforms),
            resources=resources,
            cpu_vendor=rng.choice(["genuineintel", "authenticamd"]),
            cpu_model=f"model{rng.randrange(4)}",
            os_version=f"{os_name}-10.{rng.randrange(3)}",
            on_fraction=availability,
            volunteer_id=i + 1,
        )
        churn_time = None
        if churn_rate > 0.0 and horizon > 0.0:
            t = rng.expovariate(churn_rate)
            if t < horizon:
                churn_time = t
        if availability >= 1.0:
            on_mean, off_mean = 1e18, 1.0
        else:
            on_mean = 8 * 3600.0
            off_mean = on_mean * (1.0 - availability) / max(availability, 1e-6)
        out.append(
            HostSpec(
                host=host,
                efficiency=rng.uniform(0.35, 0.7),
                runtime_noise=0.08,
                error_prob=error_prob,
                crash_prob=0.0,
                malicious=(rng.random() < malicious_fraction),
                avail_on_mean=on_mean,
                avail_off_mean=off_mean,
                churn_time=churn_time,
                rpc_poll=600.0,
            )
        )
    return out


# ---------------------------------------------------------------------------
# The simulation
# ---------------------------------------------------------------------------

_RPC = "rpc"
_COMPLETE = "complete"
_AVAIL = "avail"
_CHURN = "churn"
_SERVER = "server"
_CALLBACK = "callback"


class _RunningJob:
    """A started instance, viewed through the world's accrual columns.

    ``accrued`` and ``actual_total`` live in ``HostArrays`` (slot-major
    accrual matrix); this object is the per-instance handle scalar code and
    tests address them through.
    """

    __slots__ = ("world", "host_id", "client_job", "started_at")

    def __init__(
        self,
        world: HostArrays,
        host_id: int,
        client_job: ClientJob,
        started_at: float = 0.0,
    ) -> None:
        self.world = world
        self.host_id = host_id
        self.client_job = client_job
        self.started_at = started_at

    @property
    def accrued(self) -> float:
        return self.world.get_accrued(self.host_id, self.client_job.instance_id)

    @accrued.setter
    def accrued(self, value: float) -> None:
        self.world.set_accrued(self.host_id, self.client_job.instance_id, value)

    @property
    def actual_total(self) -> float:
        return self.world.get_total(self.host_id, self.client_job.instance_id)


@dataclass
class SimMetrics:
    completed_instances: int = 0
    correct_accepted: int = 0
    wrong_accepted: int = 0  # accepted-as-canonical but wrong (error rate)
    instances_executed: int = 0
    rpcs: int = 0
    rpcs_with_work: int = 0
    rpcs_requesting_work: int = 0
    busy_cpu_seconds: float = 0.0
    capacity_cpu_seconds: float = 0.0
    flops_done: float = 0.0

    @property
    def replication_overhead(self) -> float:
        if self.completed_instances == 0:
            return 0.0
        jobs = max(1, self.correct_accepted + self.wrong_accepted)
        return self.instances_executed / jobs

    @property
    def error_rate(self) -> float:
        tot = self.correct_accepted + self.wrong_accepted
        return self.wrong_accepted / tot if tot else 0.0

    @property
    def idle_fraction(self) -> float:
        if self.capacity_cpu_seconds <= 0:
            return 0.0
        return 1.0 - self.busy_cpu_seconds / self.capacity_cpu_seconds


class GridSimulation:
    """Drives real server+client code with a synthetic population (§9)."""

    def __init__(
        self,
        server: ProjectServer,
        population: List[HostSpec],
        seed: int = 0,
        server_tick_period: float = 60.0,
        ground_truth: Optional[Callable[[int], Any]] = None,
        executor: Optional[Callable[[Any, Host], Any]] = None,
        corruptor: Optional[Callable[[Any, random.Random], Any]] = None,
        coalesce_rpcs: bool = True,
        batch_clients: bool = True,
        vector_world: bool = True,
        epoch: float = 0.0,
        backend: str = "numpy",
    ) -> None:
        self.server = server
        self.specs: Dict[int, HostSpec] = {s.host.id: s for s in population}
        self.rng = random.Random(seed)
        self.server_tick_period = server_tick_period
        # same-tick scheduler RPCs are coalesced into one vectorized
        # batch-dispatch pass (server.rpc_batch). Dispatch decisions are
        # identical to sequential RPCs; the simulation's own stochastic
        # draws (result corruption, runtime noise) can interleave
        # differently when a coalesced batch carries completion reports,
        # because all requests are built before any reply is applied.
        self.coalesce_rpcs = coalesce_rpcs
        # client half of the same architecture (§6.1–6.2): work-fetch
        # decisions and run-set reschedules for hosts sharing a tick go
        # through the vectorized host-population engine. Bit-exact with the
        # scalar per-host path (tests/test_batch_client.py).
        self.batch_clients = batch_clients
        # epoch-batched vectorized event loop over the columnar world state
        # (see module docstring); False selects the scalar per-event oracle.
        # The vectorized loop implies RPC coalescing and the batch client
        # engine, and turns on the server's persistent-snapshot dispatch.
        self.vector_world = vector_world
        # event-time quantization grid (0 = continuous): every scheduled
        # event lands on the next multiple of ``epoch``. Applied in both
        # loops, so scalar-vs-vector parity holds at any epoch.
        self.epoch = epoch
        # execution backend for the client/world batch engines ("numpy" |
        # "jax"); engine outputs are bit-identical either way (4th parity
        # axis in core/scenarios.run_parity). The server-side engines get
        # their backend via ProjectServer(engine_backend=...).
        self.backend = backend
        self.client_engine = BatchClientEngine(backend=backend)
        self.world = HostArrays(backend=backend)
        self.ground_truth = ground_truth or (lambda job_id: float(job_id) * 1.5)
        # real-compute hook (grid runtime): executor(job, host) -> output
        self.executor = executor
        self.corruptor = corruptor
        self.now = 0.0
        self.metrics = SimMetrics()
        self._heap: List[Tuple[float, int, str, int]] = []
        self._seq = 0
        self._event_gen: Dict[int, int] = {}
        self.clients: Dict[int, Client] = {}
        self.running: Dict[int, Dict[int, _RunningJob]] = {}
        # iid -> (version_id, actual_total) for *resident* (dispatched, not
        # yet completed) instances; entries are dropped at completion and
        # at churn so the map stays O(in-flight work)
        self._instance_meta: Dict[int, Tuple[int, float]] = {}
        # lifetime sum of drawn actual runtimes (clamped-accrual invariant:
        # busy_cpu_seconds can never exceed this)
        self._dispatched_actual_total = 0.0
        self._wrong_outputs: Dict[int, bool] = {}  # iid -> output was wrong
        self._completed_ok = 0  # instances that ran to completion (SUCCESS reports)
        self._callbacks: Dict[int, Callable[[float], None]] = {}
        self._capacity_accounted = 0.0
        # remaining trace-schedule toggle times per host (consumed FIFO)
        self._avail_sched: Dict[int, "deque[float]"] = {}
        if vector_world:
            server.set_vector_dispatch(True)

        for spec in population:
            self._register_host(spec, 0.0)
        self._push(0.0, _SERVER, 0)

    def _register_host(self, spec: HostSpec, now: float) -> None:
        host = spec.host
        self.specs[host.id] = spec
        self.server.add_host(host)
        resources = {
            rt: ClientResource(rt, r.ninstances, r.peak_flops, r.availability)
            for rt, r in host.resources.items()
        }
        client = Client(
            host_id=host.id,
            resources=resources,
            prefs=ClientPrefs(buffer_lo_days=0.05, buffer_hi_days=0.2),
            ram_bytes=host.ram_bytes,
        )
        rtypes = tuple(host.resources.keys())
        client.attach(ProjectAttachment(name=self.server.name, resource_types=rtypes))
        self.clients[host.id] = client
        self.running[host.id] = {}
        cpu = host.resources.get(ResourceType.CPU)
        defense = self.server.defense
        self.world.add_host(
            host.id,
            client,
            cpu.ninstances if cpu else 0.0,
            hr_id=defense.hr_id_of(host) if defense is not None else -1,
        )
        self._push(now + self.rng.uniform(0.0, spec.rpc_poll), _RPC, host.id)
        if spec.avail_schedule is not None:
            # trace replay: availability toggles come from the schedule,
            # never from the RNG stream (scalar/vector draw parity)
            sched = deque(t for t in spec.avail_schedule if t > now)
            self._avail_sched[host.id] = sched
            if sched:
                self._push(sched.popleft(), _AVAIL, host.id)
        elif spec.avail_off_mean > 0 and spec.avail_on_mean < 1e17:
            self._push(now + self.rng.expovariate(1.0 / spec.avail_on_mean), _AVAIL, host.id)
        if spec.churn_time is not None:
            self._push(spec.churn_time, _CHURN, host.id)

    def add_host_spec(self, spec: HostSpec, now: float) -> None:
        """Register a volunteer mid-run (device arrival — or a Sybil
        churn-and-rejoin identity presenting a fresh host id, §3.4). The
        host id must be unused: churned slots are never recycled, which is
        exactly what makes Sybil identity-shedding observable."""
        if spec.host.id in self.world.index:
            raise ValueError(f"host id {spec.host.id} was already registered")
        self._register_host(spec, now)

    # -- event plumbing --

    def _quantize(self, t: float) -> float:
        e = self.epoch
        if e > 0.0:
            return math.ceil(t / e) * e
        return t

    def _push(self, t: float, kind: str, host_id: int, gen: int = -1) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (self._quantize(t), self._seq, kind, host_id))
        if kind == _COMPLETE:
            self._event_gen[self._seq] = gen

    def schedule_callback(self, t: float, fn: Callable[[float], None]) -> None:
        """Run ``fn(now)`` at virtual time ``t`` (streamed job submission,
        daemon outages, elasticity experiments...)."""
        self._seq += 1
        heapq.heappush(self._heap, (self._quantize(t), self._seq, _CALLBACK, 0))
        self._callbacks[self._seq] = fn

    # -- main loop --

    def run(self, horizon: float) -> SimMetrics:
        if self.vector_world:
            self._run_vector(horizon)
        else:
            self._run_scalar(horizon)
        self.now = horizon
        # capacity accounting (incremental: run() may be called in windows)
        dt_cap = horizon - self._capacity_accounted
        if dt_cap > 0:
            self.world.add_capacity(dt_cap)
            self._capacity_accounted = horizon
        # metric accumulators live in per-host world columns; the totals are
        # reduced in fixed host order so both loops produce the same floats
        self.metrics.capacity_cpu_seconds = self.world.capacity_total()
        self.metrics.busy_cpu_seconds = self.world.busy_total()
        self.metrics.flops_done = self.world.flops_total()
        self.server.tick(horizon)
        return self.metrics

    def _run_scalar(self, horizon: float) -> None:
        """The per-event oracle loop (the parity reference)."""
        while self._heap and self._heap[0][0] <= horizon:
            t, seq, kind, host_id = heapq.heappop(self._heap)
            if host_id:
                self._advance_running(host_id, t)
            self.now = t
            if kind == _SERVER:
                self.server.tick(t)
                self._push(t + self.server_tick_period, _SERVER, 0)
            elif kind == _RPC:
                batch = [host_id]
                if self.coalesce_rpcs:
                    # coalesce same-tick scheduler RPCs into one batch pass
                    while (
                        self._heap
                        and self._heap[0][0] == t
                        and self._heap[0][2] == _RPC
                    ):
                        _, _, _, hid2 = heapq.heappop(self._heap)
                        self._advance_running(hid2, t)
                        batch.append(hid2)
                if len(batch) == 1:
                    self._handle_rpc(host_id, t)
                else:
                    self._handle_rpc_batch(batch, t)
            elif kind == _COMPLETE:
                valid = self._event_gen.pop(seq, -1) == self.world.gen_of(host_id)
                hids = [host_id] if valid else []
                if self.batch_clients:
                    # coalesce same-tick completions into one batched
                    # reschedule pass over the affected hosts
                    while (
                        self._heap
                        and self._heap[0][0] == t
                        and self._heap[0][2] == _COMPLETE
                    ):
                        _, seq2, _, hid2 = heapq.heappop(self._heap)
                        self._advance_running(hid2, t)
                        if self._event_gen.pop(seq2, -1) == self.world.gen_of(hid2):
                            hids.append(hid2)
                    hids = list(dict.fromkeys(hids))
                if len(hids) == 1:
                    self._handle_completions(hids[0], t)
                elif hids:
                    self._handle_completions_batch(hids, t)
            elif kind == _AVAIL:
                self._toggle_availability(host_id, t)
            elif kind == _CHURN:
                self._churn(host_id, t)
            elif kind == _CALLBACK:
                fn = self._callbacks.pop(seq, None)
                if fn is not None:
                    fn(t)

    def _run_vector(self, horizon: float) -> None:
        """The epoch-batched vectorized loop. Drains maximal runs of
        same-timestamp, same-kind events (the identical grouping the oracle
        loop's coalescing produces), advances every affected host in one
        fused world pass, then handles the run through the batch engines.
        All RNG consumers execute in the oracle's per-event order, so
        whole-run results are bit-identical to :meth:`_run_scalar`."""
        heap = self._heap
        world = self.world
        while heap and heap[0][0] <= horizon:
            t, seq, kind, host_id = heapq.heappop(heap)
            if kind == _SERVER:
                self.now = t
                self.server.tick(t)
                self._push(t + self.server_tick_period, _SERVER, 0)
                continue
            if kind == _CALLBACK:
                self.now = t
                fn = self._callbacks.pop(seq, None)
                if fn is not None:
                    fn(t)
                continue
            run = [(seq, host_id)]
            while heap and heap[0][0] == t and heap[0][2] == kind:
                _, s2, _, h2 = heapq.heappop(heap)
                run.append((s2, h2))
            # one fused accrual pass for every host sharing the event time
            # (duplicates deduped: the oracle's repeat advances are no-ops)
            world.advance_batch(list(dict.fromkeys(h for _, h in run)), t)
            self.now = t
            if kind == _RPC:
                self._handle_rpc_batch([h for _, h in run], t)
            elif kind == _COMPLETE:
                hids = [
                    h
                    for s, h in run
                    if self._event_gen.pop(s, -1) == world.gen_of(h)
                ]
                hids = list(dict.fromkeys(hids))
                if hids:
                    self._handle_completions_batch(hids, t)
            elif kind == _AVAIL:
                self._avail_run(run, t)
            elif kind == _CHURN:
                for _, h in run:
                    self._churn(h, t)

    # -- host availability & churn --

    def _toggle_scheduled(self, host_id: int, t: float) -> None:
        """Trace-schedule toggle: flip the state, push the next scheduled
        time (if any), and touch no RNG stream."""
        world = self.world
        on = world.is_available(host_id)
        world.set_available(host_id, not on)
        world.bump_gen(host_id)  # invalidate completion events
        if not on:
            self._reschedule_completions(host_id, t)
        sched = self._avail_sched.get(host_id)
        if sched:
            self._push(sched.popleft(), _AVAIL, host_id)

    def _toggle_availability(self, host_id: int, t: float) -> None:
        spec = self.specs.get(host_id)
        if spec is None:
            return
        if spec.avail_schedule is not None:
            self._toggle_scheduled(host_id, t)
            return
        world = self.world
        on = world.is_available(host_id)
        world.set_available(host_id, not on)
        world.bump_gen(host_id)  # invalidate completion events
        if on:
            nxt = self.rng.expovariate(1.0 / spec.avail_off_mean)
        else:
            nxt = self.rng.expovariate(1.0 / spec.avail_on_mean)
            self._reschedule_completions(host_id, t)
        self._push(t + nxt, _AVAIL, host_id)

    def _avail_run(self, run: List[Tuple[int, int]], t: float) -> None:
        """A same-timestamp run of availability toggles: the exponential
        next-toggle draws are prefetched as one uniform batch and consumed
        FIFO, reproducing the oracle's ``rng.expovariate`` stream exactly;
        the toggles themselves apply sequentially in event order.
        Trace-scheduled hosts consume no draws (in either loop), so they
        are excluded from the prefetch count."""
        specs = self.specs
        world = self.world
        world.draws.prefetch(
            self.rng,
            sum(
                1
                for _, h in run
                if (s := specs.get(h)) is not None and s.avail_schedule is None
            ),
        )
        for _, host_id in run:
            spec = specs.get(host_id)
            if spec is None:
                continue
            if spec.avail_schedule is not None:
                self._toggle_scheduled(host_id, t)
                continue
            on = world.is_available(host_id)
            world.set_available(host_id, not on)
            world.bump_gen(host_id)
            if on:
                nxt = world.draws.draw(self.rng, 1.0 / spec.avail_off_mean)
            else:
                nxt = world.draws.draw(self.rng, 1.0 / spec.avail_on_mean)
                self._reschedule_completions(host_id, t)
            self._push(t + nxt, _AVAIL, host_id)

    def _churn(self, host_id: int, t: float) -> None:
        """Permanent departure: in-progress instances will hit their
        deadlines and be retried on other hosts (§4). Every per-host trace
        — specs, client, running set, world columns, undelivered instance
        metadata — is purged, so long-churn runs don't leak state."""
        self.specs.pop(host_id, None)
        self.clients.pop(host_id, None)
        self.running.pop(host_id, None)
        self._avail_sched.pop(host_id, None)
        i = self.world.index.get(host_id)
        if i is not None:
            for j in self.world.queue_jobs[i]:
                self._instance_meta.pop(j.instance_id, None)
        self.world.remove_host(host_id)
        self.server.remove_host(host_id, t)

    # -- execution model --

    def _advance_running(self, host_id: int, t: float) -> None:
        if host_id == 0:
            return
        # clamped columnar accrual (world.advance_host performs the same
        # per-cell IEEE ops as the fused vector pass)
        self.world.advance_host(host_id, t)

    def _reschedule_completions(self, host_id: int, t: float) -> None:
        """(Re)issue completion events for the host's running set."""
        world = self.world
        gen = world.bump_gen(host_id)
        i = world.index[host_id]
        q_total = world.q_total
        q_runtime = world.q_runtime
        for row in world.running_rows(host_id):
            remaining = max(0.0, float(q_total[row, i] - q_runtime[row, i]))
            self._push(t + remaining, _COMPLETE, host_id, gen)

    def _mark_completions(
        self, host_id: int, t: float, rows=None
    ) -> Optional[bool]:
        """Flip finished running jobs to DONE; returns None if the host is
        gone/unavailable, else whether anything completed. ``rows`` may
        carry precomputed completion rows (the vectorized loop's fused
        detection mask)."""
        spec = self.specs.get(host_id)
        client = self.clients.get(host_id)
        world = self.world
        if spec is None or client is None or not world.is_available(host_id):
            return None
        if rows is None:
            rows = world.completed_rows(host_id)
        if len(rows) == 0:
            return False
        i = world.index[host_id]
        running = self.running[host_id]
        done_ids = set()
        for row in rows:
            cj = world.queue_jobs[i][row]
            running.pop(cj.instance_id, None)
            cj.state = RunState.DONE
            cj.fraction_done = 1.0
            # authoritative accrual lives in the world column; sync the
            # object before it is reported (CompletedResult.runtime)
            cj.runtime = float(world.q_runtime[row, i])
            client.completed.append(cj)
            self.metrics.instances_executed += 1
            world.flops[i] += world.q_efc[row, i]
            self._instance_meta.pop(cj.instance_id, None)
            done_ids.add(cj.instance_id)
        client.jobs = [j for j in client.jobs if j.instance_id not in done_ids]
        client.running = [j for j in client.running if j.instance_id not in done_ids]
        world.remove_rows(host_id, rows)
        return True

    def _handle_completions(self, host_id: int, t: float) -> None:
        marked = self._mark_completions(host_id, t)
        if marked is None:
            return
        if marked:
            self._start_jobs(host_id, t)
        client = self.clients[host_id]
        # report opportunistically (deferred batching handled in _handle_rpc)
        if client.completed and client.should_report(self.server.name, t):
            self._do_rpc(host_id, t, force_report=True)

    def _handle_completions_batch(self, host_ids: List[int], t: float) -> None:
        """Coalesced same-tick completions: mark every host's finished jobs,
        run one batched reschedule for the affected hosts, then do the
        per-host opportunistic report RPCs in the original event order (the
        same server-visible order as sequential handling — client state is
        host-local, so deferring the reschedules cannot change outcomes).
        The vectorized loop detects completions as one fused mask over the
        accrual matrix and precomputes the reporters' work-fetch decisions
        in one engine pass; the report RPCs themselves stay sequential so
        every RNG draw happens in oracle order."""
        live: List[int] = []
        to_start: List[int] = []
        vw = self.vector_world
        detected = self.world.completed_rows_batch(host_ids) if vw else {}
        for hid in host_ids:
            marked = self._mark_completions(hid, t, rows=detected.get(hid))
            if marked is None:
                continue
            live.append(hid)
            if marked:
                to_start.append(hid)
        self._start_jobs_batch(to_start, t)
        name = self.server.name
        reporters = [
            hid
            for hid in live
            if (c := self.clients.get(hid)) is not None
            and c.completed
            and c.should_report(name, t)
        ]
        if not reporters:
            return
        needs_map: Dict[int, Dict[ResourceType, ResourceRequest]] = {}
        if vw:
            if len(reporters) > 1:
                needs_map = dict(zip(
                    reporters,
                    self.client_engine.needs_work_world(self.world, reporters, t),
                ))
            else:
                # a one-host engine pass costs more than the scalar oracle
                # call; sync the accrual columns onto the objects and let
                # _build_request take the (bit-identical) scalar path
                self.world.sync_objects(reporters)
        # one coalesced dispatch pass for the whole run's report RPCs (the
        # request builds and reply applications stay sequential per host,
        # so every RNG draw happens in the same order in both loops)
        pending: List[Tuple[int, ScheduleRequest]] = []
        for hid in reporters:
            request = self._build_request(
                hid, t, force_report=True, needs=needs_map.get(hid)
            )
            if request is not None:
                pending.append((hid, request))
        replies = self.server.rpc_batch([r for _, r in pending], t)
        to_start = [
            hid
            for (hid, request), reply in zip(pending, replies)
            if self._apply_reply(hid, request, reply, t, start=False)
        ]
        self._start_jobs_batch(to_start, t)

    def _start_jobs(self, host_id: int, t: float) -> None:
        self._start_jobs_batch([host_id], t)

    def _start_jobs_batch(self, host_ids: List[int], t: float) -> None:
        if not host_ids:
            return
        if self.vector_world:
            if len(host_ids) == 1:
                # one-host reschedule: the scalar oracle call is cheaper
                # than an engine pass and bit-identical to it
                hid = host_ids[0]
                self.world.sync_objects(host_ids)
                chosen_lists = [self.clients[hid].schedule(t)]
                self.world.sync_run_state(hid)
            else:
                # fused run-set selection straight off the world columns
                chosen_lists = self.client_engine.schedule_world(
                    self.world, host_ids, t
                )
        else:
            clients = [self.clients[h] for h in host_ids]
            if self.batch_clients and len(clients) > 1:
                chosen_lists = self.client_engine.schedule_batch(clients, t)
            else:
                chosen_lists = [c.schedule(t) for c in clients]
            for host_id in host_ids:
                self.world.sync_run_state(host_id)
        for host_id, chosen in zip(host_ids, chosen_lists):
            running = self.running[host_id]
            for cj in chosen:
                if cj.instance_id not in running:
                    running[cj.instance_id] = _RunningJob(
                        world=self.world,
                        host_id=host_id,
                        client_job=cj,
                        started_at=t,
                    )
            self._reschedule_completions(host_id, t)

    # -- RPC path --

    def _handle_rpc(self, host_id: int, t: float) -> None:
        spec = self.specs.get(host_id)
        if spec is None:
            return
        # push the next poll *before* handling (the batch path's order), so
        # event sequence numbers — and therefore same-timestamp tie-breaks —
        # are identical whether a poll was handled alone or in a batch
        self._push(t + spec.rpc_poll, _RPC, host_id)
        if self.world.is_available(host_id):
            self._do_rpc(host_id, t)

    def _do_rpc(
        self,
        host_id: int,
        t: float,
        force_report: bool = False,
        needs: Optional[Dict[ResourceType, ResourceRequest]] = None,
    ) -> None:
        request = self._build_request(host_id, t, force_report, needs=needs)
        if request is None:
            return
        reply = self.server.rpc(request, t)
        self._apply_reply(host_id, request, reply, t)

    def _handle_rpc_batch(self, host_ids: List[int], t: float) -> None:
        """Coalesced form of ``_handle_rpc``: build every host's request
        (work-fetch decisions precomputed in one fused WRR pass over the
        whole batch), dispatch them in one ``rpc_batch`` call, apply replies
        in the same order the sequential loop would have, then run one
        batched reschedule for every host that received jobs. The
        vectorized world reads the WRR inputs from the persistent columns;
        the object-snapshot engine and per-host scalar fallbacks remain for
        the oracle loop."""
        world = self.world
        needs_map: Dict[int, Dict[ResourceType, "ResourceRequest"]] = {}
        if self.vector_world:
            avail = [
                hid
                for hid in host_ids
                if hid in self.specs and world.is_available(hid)
            ]
            if len(avail) > 1:
                needs_map = dict(zip(
                    avail,
                    self.client_engine.needs_work_world(world, avail, t),
                ))
            elif avail:
                world.sync_objects(avail)  # scalar needs path, bit-identical
        elif self.batch_clients:
            avail = [
                hid
                for hid in host_ids
                if hid in self.specs and world.is_available(hid)
            ]
            if len(avail) > 1:
                batched = self.client_engine.needs_work_batch(
                    [self.clients[h] for h in avail], t
                )
                needs_map = dict(zip(avail, batched))
        pending: List[Tuple[int, ScheduleRequest]] = []
        for hid in host_ids:
            spec = self.specs.get(hid)
            if spec is None:
                continue
            if world.is_available(hid):
                request = self._build_request(hid, t, needs=needs_map.get(hid))
                if request is not None:
                    pending.append((hid, request))
            self._push(t + spec.rpc_poll, _RPC, hid)
        replies = self.server.rpc_batch([r for _, r in pending], t)
        if self.vector_world or self.batch_clients:
            to_start = [
                hid
                for (hid, request), reply in zip(pending, replies)
                if self._apply_reply(hid, request, reply, t, start=False)
            ]
            self._start_jobs_batch(to_start, t)
        else:
            for (hid, request), reply in zip(pending, replies):
                self._apply_reply(hid, request, reply, t)

    def _build_request(
        self,
        host_id: int,
        t: float,
        force_report: bool = False,
        needs: Optional[Dict[ResourceType, ResourceRequest]] = None,
    ) -> Optional[ScheduleRequest]:
        spec = self.specs[host_id]
        client = self.clients[host_id]
        host = spec.host

        fetch = client.choose_fetch_project(t, needs=needs)
        reqs: Dict[ResourceType, ResourceRequest] = {}
        if fetch is not None and fetch.project == self.server.name:
            reqs = fetch.requests
        want_report = force_report or client.should_report(self.server.name, t)
        if not reqs and not want_report:
            return None

        completed: List[CompletedResult] = []
        if want_report:
            for cj in client.take_completed(self.server.name):
                completed.append(self._make_result(spec, cj, t))

        request = ScheduleRequest(
            host_id=host_id,
            requests=reqs,
            completed=completed,
            usable_disk=host.disk_free_bytes,
        )
        self.metrics.rpcs += 1
        if reqs:
            self.metrics.rpcs_requesting_work += 1
        return request

    def _apply_reply(
        self,
        host_id: int,
        request: ScheduleRequest,
        reply,
        t: float,
        start: bool = True,
    ) -> bool:
        """Apply one scheduler reply; returns True when jobs arrived.
        ``start=False`` defers the reschedule to a batched pass."""
        spec = self.specs.get(host_id)
        client = self.clients.get(host_id)
        if spec is None or client is None:
            return False
        host = spec.host
        reqs = request.requests
        proj = client.projects.get(self.server.name)
        if reply.jobs:
            self.metrics.rpcs_with_work += 1
            if proj:
                for rt in host.resources:
                    proj.backoff_for(rt).register_success()
        elif reqs and proj:
            for rt in reqs:
                proj.backoff_for(rt).register_failure(t)

        for dj in reply.jobs:
            ev = dj.version.plan_class.evaluate(host)
            usage = ev[0] if ev else {ResourceType.CPU: 1.0}
            actual = self._draw_runtime(spec, dj.job.est_flop_count, usage)
            cj = ClientJob(
                instance_id=dj.instance.id,
                job_id=dj.job.id,
                project=self.server.name,
                app_name=dj.job.app_name,
                usage=usage,
                est_flops=dj.est_flops,
                est_flop_count=dj.job.est_flop_count,
                deadline=dj.instance.deadline,
                est_wss=dj.job.ram_bytes,
                received_time=t,
            )
            client.jobs.append(cj)
            self._instance_meta[cj.instance_id] = (dj.version.id, actual)
            self._dispatched_actual_total += actual
            self.world.add_job(host_id, cj, actual)
        if reply.jobs and start:
            self._start_jobs(host_id, t)
        return bool(reply.jobs)

    def _draw_runtime(self, spec: HostSpec, est_flop_count: float, usage: Dict[ResourceType, float]) -> float:
        pf = spec.host.peak_flops(usage)
        if pf <= 0:
            return float("inf")
        base = est_flop_count / (pf * spec.efficiency)
        noise = math.exp(self.rng.gauss(0.0, spec.runtime_noise))
        return base * noise

    def _make_result(self, spec: HostSpec, cj: ClientJob, t: float) -> CompletedResult:
        job = self.server.store.jobs.get(cj.job_id)
        crashed = self.rng.random() < spec.crash_prob
        if crashed:
            self._wrong_outputs[cj.instance_id] = False
            return CompletedResult(
                instance_id=cj.instance_id,
                outcome=InstanceOutcome.CLIENT_ERROR,
                runtime=cj.runtime,
                exit_code=1,
            )
        if self.executor is not None:
            truth = self.executor(job, spec.host)
        else:
            truth = self.ground_truth(cj.job_id)
        wrong = False
        if spec.malicious and self.rng.random() < spec.cheat_prob:
            if spec.collusion_group is not None:
                output, wrong = self._collude(spec.collusion_group, cj, truth), True
            else:
                output, wrong = self._corrupt(truth), True
        elif self.rng.random() < spec.error_prob:
            output, wrong = self._corrupt(truth), True
        else:
            output = truth
        self._wrong_outputs[cj.instance_id] = wrong
        self._completed_ok += 1
        pfc = peak_flop_count(cj.runtime, cj.usage, spec.host)
        if spec.claim_factor != 1.0:
            # credit farming (§7 attack model): the host reports inflated
            # peak FLOPS; validation still sees the *correct* output
            pfc *= spec.claim_factor
        return CompletedResult(
            instance_id=cj.instance_id,
            outcome=InstanceOutcome.SUCCESS,
            runtime=cj.runtime,
            peak_flop_count=pfc,
            output=output,
        )

    def _corrupt(self, truth: Any) -> Any:
        if self.corruptor is not None:
            return self.corruptor(truth, self.rng)
        if isinstance(truth, float):
            return truth + self.rng.uniform(1.0, 2.0)
        return ("corrupt", self.rng.random())

    def _collude(self, group: int, cj: ClientJob, truth: Any) -> Any:
        """Colluding-clique payload (§3.4 attack model): a deterministic
        function of (group, job) — every clique member fabricates the
        *identical* wrong result, so replicated instances landing on two
        clique hosts agree and can win the quorum. Consumes no RNG draws
        (the decision draw in ``_make_result`` already happened), so both
        event loops see identical streams."""
        if isinstance(truth, float):
            return truth + 64.0 + float(group)
        return ("collude", group, cj.job_id)

    def was_wrong(self, instance_id: int) -> bool:
        """Whether the given instance returned a wrong output (ground truth
        known only to the emulator — used by the scenario layer to measure
        error credit and quorum defeats)."""
        return self._wrong_outputs.get(instance_id, False)

    # -- end-of-run audit --

    def audit_validation(self) -> None:
        """Count canonical results that were wrong (accepted-error rate)."""
        store = self.server.store
        counted = set()
        for job in list(store.jobs.values()):
            if job.canonical_instance_id is None or job.id in counted:
                continue
            counted.add(job.id)
            wrong = self._wrong_outputs.get(job.canonical_instance_id, False)
            if wrong:
                self.metrics.wrong_accepted += 1
            else:
                self.metrics.correct_accepted += 1
        # explicit counter of instances that ran to completion — CLIENT_ERROR
        # crashes are reported but never completed, so they don't count
        self.metrics.completed_instances = self._completed_ok
        # the audit doubles as the store's index/scan consistency check
        if store.use_indexes:
            store.check_invariants()
        # ... and the world's column <-> object consistency check (the
        # scalar loop keeps object accrual in lockstep with the columns)
        self.world.check_invariants(strict_dynamic=not self.vector_world)
        # persist the defense layer's final suspicion clusters into the
        # world column (deterministic: cluster ids are smallest-member ids)
        defense = self.server.defense
        if defense is not None:
            clusters = defense.clusters()
            world = self.world
            for host_id, slot in world.index.items():
                if world.alive[slot]:
                    world.suspect_cluster[slot] = clusters.get(host_id, -1)
        self._audit_validate_states()

    def _audit_validate_states(self) -> None:
        """Engine-vs-oracle validation audit: re-check every resident
        validated job's partition against the scalar comparator.

        Whichever path assigned the states (the batch engine's digest
        grouping or the scalar ``check_set``), the §3.4/§4 contract holds:
        the canonical instance is VALID, and every other VALID success
        matches the canonical under the app comparator (both paths compare
        members against the winning group's representative). The converse
        — INVALID implies comparator mismatch with the canonical — is only
        an invariant for exact (bitwise) comparators: greedy grouping may
        never have compared an invalid member against the canonical when a
        fuzzy tolerance relation is non-transitive.
        """
        store = self.server.store
        from .validator import bitwise_equal

        for job in store.jobs.values():
            if job.canonical_instance_id is None:
                continue
            canonical = store.instances.get(job.canonical_instance_id)
            if canonical is None:
                continue
            app = store.apps[job.app_name]
            cmp = app.comparator or bitwise_equal
            assert canonical.validate_state == ValidateState.VALID, (
                f"job {job.id}: canonical instance not VALID"
            )
            for inst in store.job_instances(job.id):
                if (
                    inst.id == canonical.id
                    or inst.outcome != InstanceOutcome.SUCCESS
                ):
                    continue
                if inst.validate_state == ValidateState.VALID:
                    assert cmp(canonical.output, inst.output), (
                        f"job {job.id}: VALID instance {inst.id} disagrees "
                        f"with canonical"
                    )
                elif (
                    inst.validate_state == ValidateState.INVALID
                    and app.comparator is None
                ):
                    assert not cmp(canonical.output, inst.output), (
                        f"job {job.id}: INVALID instance {inst.id} agrees "
                        f"with canonical (bitwise)"
                    )
