"""Persistent columnar world state for the EmBOINC-style simulator (§9).

``simulator.GridSimulation`` models a volunteer host population driving the
real server code in virtual time. Through PR 4 every *engine* around it was
vectorized (dispatch, daemons, client scheduling, validation), but the
simulation *world* itself — which hosts are up, what every host is running,
how far along each running instance is — lived in per-host Python dicts
mutated one event at a time, and every batch engine re-materialized its
inputs from those objects on every call.

This module is the struct-of-arrays replacement: :class:`HostArrays` owns
the population's columnar state and is maintained **incrementally at
mutation time** (observer-style, like ``store.py``'s indexes):

  * per-host columns: ``alive`` (churn status), ``available``, ``gen``
    (completion-event generation counters), ``last_update``, and the
    per-host metric accumulators (``busy`` CPU-seconds, ``flops`` done,
    ``capacity``);
  * a slot-major ``[max_jobs, n_hosts]`` queue matrix mirroring every
    client's job queue — static per-job fields written once on arrival
    (estimates, deadline, working set, usage), dynamic fields (accrued
    runtime, fraction done, run state, slice start) advanced in place;
  * per-host object mirrors (``queue_jobs``, ``row_of``) so scalar code and
    the vectorized passes address the same jobs.

Both simulator modes run on these arrays. The scalar oracle
(``vector_world=False``) performs the identical IEEE-754 operations one
host at a time through :meth:`advance_host`; the vectorized loop
(``vector_world=True``) advances a whole batch of event-sharing hosts in
one fused pass (:meth:`advance_batch`) and detects completions as a single
mask over the accrual matrix (:meth:`completed_rows_batch`). Because both
paths touch the same cells with the same operations in the same per-cell
order, whole-simulation results are bit-identical (asserted across the
scenario matrix by ``tests/test_world.py``).

Accrual is **clamped**: a running instance is charged at most the work it
has left (``actual_total - accrued``), so an availability or RPC event
landing after the nominal finish time — guaranteed under epoch-quantized
event times — cannot inflate runtimes, busy-time, or REC debits past the
instance's actual cost.

:class:`ExpDrawCache` supports the vectorized loop's availability
sampling: uniforms are prefetched from the simulation's ``random.Random``
in scalar event order and consumed FIFO (the pattern ``adaptive.py`` uses
for replication draws), so batched processing sees the exact draw sequence
the per-event oracle would — the exponential transform mirrors
``random.Random.expovariate`` term for term.
"""
from __future__ import annotations

import math
import random
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, TYPE_CHECKING

import numpy as np

from . import jax_backend
from .client import RunState
from .types import ResourceType

if TYPE_CHECKING:  # pragma: no cover
    from .client import Client, ClientJob

_RUNNING = RunState.RUNNING
_DONE = RunState.DONE


class ExpDrawCache:
    """FIFO uniform-draw cache reproducing ``random.Random.expovariate``.

    ``prefetch`` pulls ``n`` uniforms from the RNG *now* in stream order;
    ``draw`` pops them FIFO (falling back to the live RNG when empty) and
    applies the exact CPython transform ``-log(1 - u) / lambd``. Any
    prefetch size therefore leaves every draw bit-identical to unbatched
    ``rng.expovariate(lambd)`` use.
    """

    __slots__ = ("_draws",)

    def __init__(self) -> None:
        self._draws: Deque[float] = deque()

    def prefetch(self, rng: random.Random, n: int) -> None:
        if n > 0:
            self._draws.extend(rng.random() for _ in range(n))

    def draw(self, rng: random.Random, lambd: float) -> float:
        u = self._draws.popleft() if self._draws else rng.random()
        return -math.log(1.0 - u) / lambd

    def __len__(self) -> int:
        return len(self._draws)


class HostArrays:
    """Columnar world state over a (dense-indexed) host population."""

    _Q0 = 8  # initial queue-matrix depth; doubled on demand

    def __init__(self, backend: str = "numpy") -> None:
        # "jax": the accrual/completion passes run against device-resident
        # column mirrors (core.jax_backend.WorldDeviceMirror) kept current
        # by the _touch dirty-range hooks below; bit-identical to the
        # NumPy passes (4th parity axis)
        self.backend = jax_backend.resolve_backend(backend)
        self._mirror = (
            jax_backend.WorldDeviceMirror() if self.backend == "jax" else None
        )
        self.n = 0  # registered hosts (dense slots, never reused)
        self._cap = 0
        self.index: Dict[int, int] = {}  # host_id -> dense slot
        self.ids = np.zeros(0, dtype=np.int64)
        # -- per-host state columns --
        self.alive = np.zeros(0, dtype=bool)
        self.available = np.zeros(0, dtype=bool)
        self.gen = np.zeros(0, dtype=np.int64)
        self.last_update = np.zeros(0, dtype=np.float64)
        # -- per-host metric accumulators (kept across churn) --
        self.busy = np.zeros(0, dtype=np.float64)
        self.flops = np.zeros(0, dtype=np.float64)
        self.capacity = np.zeros(0, dtype=np.float64)
        self.cap_ncpu = np.zeros(0, dtype=np.float64)  # CPU instances (capacity)
        # -- per-host client statics (engine snapshot columns) --
        self.ram = np.zeros(0, dtype=np.float64)
        self.ram_frac = np.zeros(0, dtype=np.float64)
        self.b_hi = np.zeros(0, dtype=np.float64)
        self.time_slice = np.zeros(0, dtype=np.float64)
        self.sched_ncpu = np.zeros(0, dtype=np.float64)  # §6.1 usable CPUs
        # -- defense-layer columns (§3.4): interned HR class id and current
        # suspicion-cluster id (synced from DefenseLayer); -1 = none --
        self.hr_id = np.zeros(0, dtype=np.int64)
        self.suspect_cluster = np.zeros(0, dtype=np.int64)
        # per-resource-type instance counts / presence (grown lazily)
        self.rtypes: List[ResourceType] = [ResourceType.CPU]
        self.nins: Dict[ResourceType, np.ndarray] = {
            ResourceType.CPU: np.zeros(0, dtype=np.float64)
        }
        self.has: Dict[ResourceType, np.ndarray] = {
            ResourceType.CPU: np.zeros(0, dtype=bool)
        }
        # -- slot-major queue matrix [Q, H] --
        self._q = 0  # current depth
        self.q_count = np.zeros(0, dtype=np.int64)
        self.q_estf = self._qz()
        self.q_efc = self._qz()
        self.q_frac = self._qz()
        self.q_runtime = self._qz()  # == accrued: the sim advances both as one
        self.q_total = self._qz()  # actual runtime drawn at dispatch
        self.q_dl = self._qz()
        self.q_wss = self._qz()
        self.q_slice = self._qz()
        self.q_chk = self._qz()
        self.q_weight = self._qz()  # max(sum(usage), 1): REC debit weight
        self.q_running = self._qz(bool)
        self.q_exact = self._qz(bool)
        self.q_nci = self._qz(bool)
        self.q_usage: Dict[ResourceType, np.ndarray] = {ResourceType.CPU: self._qz()}
        # -- per-host object mirrors --
        self.clients: List[Optional["Client"]] = []
        self.queue_jobs: List[List["ClientJob"]] = []
        self.row_of: List[Dict[int, int]] = []  # instance_id -> queue row
        self.project: List[Optional[str]] = []  # single attached project
        self.multi: List[bool] = []  # >1 project or mixed-project queue
        self.dirty: set = set()  # host ids needing object->column resync
        self.draws = ExpDrawCache()

    # ------------------------------------------------------------------
    # growth
    # ------------------------------------------------------------------

    def _qz(self, dtype=np.float64) -> np.ndarray:
        return np.zeros((self._q, self._cap), dtype=dtype)

    def _q_fields(self):
        yield from (
            "q_estf", "q_efc", "q_frac", "q_runtime", "q_total", "q_dl",
            "q_wss", "q_slice", "q_chk", "q_weight", "q_running", "q_exact",
            "q_nci",
        )

    def _grow_hosts(self, need: int) -> None:
        cap = max(self._cap * 2, need, 16)
        for name in (
            "ids", "alive", "available", "gen", "last_update", "busy",
            "flops", "capacity", "cap_ncpu", "ram", "ram_frac", "b_hi",
            "time_slice", "sched_ncpu", "hr_id", "suspect_cluster",
        ):
            old = getattr(self, name)
            new = np.zeros(cap, dtype=old.dtype)
            new[: old.shape[0]] = old
            setattr(self, name, new)
        # -1 sentinels for the defense columns' fresh slots
        self.hr_id[self._cap:] = -1
        self.suspect_cluster[self._cap:] = -1
        for d in (self.nins, self.has):
            for rt, old in d.items():
                new = np.zeros(cap, dtype=old.dtype)
                new[: old.shape[0]] = old
                d[rt] = new
        for name in self._q_fields():
            old = getattr(self, name)
            new = np.zeros((self._q, cap), dtype=old.dtype)
            new[:, : old.shape[1]] = old
            setattr(self, name, new)
        for rt, old in self.q_usage.items():
            new = np.zeros((self._q, cap), dtype=old.dtype)
            new[:, : old.shape[1]] = old
            self.q_usage[rt] = new
        oldc = self.q_count
        self.q_count = np.zeros(cap, dtype=np.int64)
        self.q_count[: oldc.shape[0]] = oldc
        self._cap = cap

    def _grow_queue(self, need: int) -> None:
        q = max(self._q * 2, need, self._Q0)
        for name in self._q_fields():
            old = getattr(self, name)
            new = np.zeros((q, self._cap), dtype=old.dtype)
            new[: old.shape[0]] = old
            setattr(self, name, new)
        for rt, old in self.q_usage.items():
            new = np.zeros((q, self._cap), dtype=old.dtype)
            new[: old.shape[0]] = old
            self.q_usage[rt] = new
        self._q = q

    def _ensure_rtype(self, rt: ResourceType) -> None:
        if rt not in self.nins:
            self.rtypes.append(rt)
            self.nins[rt] = np.zeros(self._cap, dtype=np.float64)
            self.has[rt] = np.zeros(self._cap, dtype=bool)
            self.q_usage[rt] = np.zeros((self._q, self._cap), dtype=np.float64)

    def _touch(self, i: int) -> None:
        """Dirty-range hook (backend="jax"): dense slot ``i``'s mirrored
        queue columns changed host-side; re-upload before the next device
        pass. Host-array growth/compaction is caught separately by the
        mirror's shape check, so only per-slot writers need to call this."""
        if self._mirror is not None:
            self._mirror.mark(i)

    # ------------------------------------------------------------------
    # registration / churn
    # ------------------------------------------------------------------

    def add_host(self, host_id: int, client: "Client", cap_ncpu: float, hr_id: int = -1) -> int:
        """Register a host and mirror its client's static columns."""
        if host_id in self.index:
            raise ValueError(f"host {host_id} already registered")
        i = self.n
        if i >= self._cap:
            self._grow_hosts(i + 1)
        self.n += 1
        self.index[host_id] = i
        self.ids[i] = host_id
        self.alive[i] = True
        self.available[i] = True
        self.gen[i] = 0
        self.last_update[i] = 0.0
        self.cap_ncpu[i] = cap_ncpu
        self.hr_id[i] = hr_id
        self.suspect_cluster[i] = -1
        self.clients.append(client)
        self.queue_jobs.append([])
        self.row_of.append({})
        names = list(client.projects)
        self.project.append(names[0] if len(names) == 1 else None)
        self.multi.append(len(names) > 1)
        self.refresh_client_statics(host_id)
        return i

    def refresh_client_statics(self, host_id: int) -> None:
        """(Re)mirror a client's per-host engine columns (prefs, resources).
        These are immutable during a simulation; call again if mutated."""
        i = self.index[host_id]
        c = self.clients[i]
        self.ram[i] = c.ram_bytes
        self.ram_frac[i] = c.prefs.ram_limit_fraction
        self.b_hi[i] = c.prefs.b_hi
        self.time_slice[i] = c.prefs.time_slice
        cpu = c.resources.get(ResourceType.CPU)
        self.sched_ncpu[i] = c.n_usable_cpus or (cpu.ninstances if cpu else 1)
        for rt in c.resources:
            self._ensure_rtype(rt)
        for rt in self.rtypes:
            res = c.resources.get(rt)
            self.nins[rt][i] = res.ninstances if res is not None else 0
            self.has[rt][i] = res is not None

    def remove_host(self, host_id: int) -> None:
        """Churn (§4): permanently drop the host's live state. Metric
        accumulators (busy/flops/capacity) are deliberately retained; every
        queue/running column and object mirror is purged so long-churn runs
        hold no per-departed-host state."""
        i = self.index.get(host_id)
        if i is None:
            return
        cnt = int(self.q_count[i])
        if cnt:
            for name in self._q_fields():
                getattr(self, name)[:cnt, i] = 0
            for col in self.q_usage.values():
                col[:cnt, i] = 0
            self.q_count[i] = 0
            self._touch(i)
        self.alive[i] = False
        self.available[i] = False
        self.hr_id[i] = -1
        self.suspect_cluster[i] = -1
        self.clients[i] = None
        self.queue_jobs[i] = []
        self.row_of[i] = {}
        self.project[i] = None
        self.dirty.discard(host_id)

    # ------------------------------------------------------------------
    # simple per-host accessors
    # ------------------------------------------------------------------

    def is_available(self, host_id: int) -> bool:
        i = self.index.get(host_id)
        return bool(self.available[i]) if i is not None else False

    def set_available(self, host_id: int, flag: bool) -> None:
        self.available[self.index[host_id]] = flag

    def gen_of(self, host_id: int) -> int:
        i = self.index.get(host_id)
        return int(self.gen[i]) if i is not None else 0

    def bump_gen(self, host_id: int) -> int:
        i = self.index[host_id]
        self.gen[i] += 1
        return int(self.gen[i])

    def get_accrued(self, host_id: int, instance_id: int) -> float:
        i = self.index[host_id]
        return float(self.q_runtime[self.row_of[i][instance_id], i])

    def set_accrued(self, host_id: int, instance_id: int, value: float) -> None:
        i = self.index[host_id]
        self.q_runtime[self.row_of[i][instance_id], i] = value
        self._touch(i)

    def get_total(self, host_id: int, instance_id: int) -> float:
        i = self.index[host_id]
        return float(self.q_total[self.row_of[i][instance_id], i])

    # ------------------------------------------------------------------
    # queue mutation (observer hooks called by the simulator)
    # ------------------------------------------------------------------

    def add_job(self, host_id: int, job: "ClientJob", actual_total: float) -> None:
        """Mirror a newly received job into the queue matrix."""
        i = self.index[host_id]
        row = int(self.q_count[i])
        if row >= self._q:
            self._grow_queue(row + 1)
        self.q_estf[row, i] = job.est_flops
        self.q_efc[row, i] = job.est_flop_count
        self.q_frac[row, i] = job.fraction_done
        self.q_runtime[row, i] = job.runtime
        self.q_total[row, i] = actual_total
        self.q_dl[row, i] = job.deadline
        self.q_wss[row, i] = job.est_wss
        self.q_slice[row, i] = job.slice_start
        self.q_chk[row, i] = job.checkpoint_time
        self.q_weight[row, i] = max(sum(job.usage.values()), 1.0)
        self.q_running[row, i] = job.state == _RUNNING
        self.q_exact[row, i] = job.fraction_done_exact
        self.q_nci[row, i] = job.non_cpu_intensive
        for rt, u in job.usage.items():
            self._ensure_rtype(rt)
        for rt in self.rtypes:
            self.q_usage[rt][row, i] = job.usage.get(rt, 0.0)
        self.queue_jobs[i].append(job)
        self.row_of[i][job.instance_id] = row
        self.q_count[i] = row + 1
        self._touch(i)
        if self.project[i] is not None and job.project != self.project[i]:
            self.multi[i] = True

    def remove_rows(self, host_id: int, rows: np.ndarray) -> None:
        """Drop queue rows (completed jobs), compacting the columns and
        zeroing the freed tail so padding cells stay exactly 0."""
        i = self.index[host_id]
        cnt = int(self.q_count[i])
        if len(rows) == 0:
            return
        mask = np.ones(cnt, dtype=bool)
        mask[rows] = False
        keep = np.flatnonzero(mask)
        newc = len(keep)
        for name in self._q_fields():
            col = getattr(self, name)
            col[:newc, i] = col[keep, i]
            col[newc:cnt, i] = 0
        for col in self.q_usage.values():
            col[:newc, i] = col[keep, i]
            col[newc:cnt, i] = 0
        jobs = self.queue_jobs[i]
        self.queue_jobs[i] = [jobs[r] for r in keep]
        self.row_of[i] = {
            j.instance_id: r for r, j in enumerate(self.queue_jobs[i])
        }
        self.q_count[i] = newc
        self._touch(i)

    def sync_run_state(self, host_id: int) -> None:
        """Re-mirror run-state-dependent columns after a (re)schedule
        mutated job states through ``Client._apply_run_set``."""
        i = self.index[host_id]
        q_running = self.q_running
        q_slice = self.q_slice
        q_chk = self.q_chk
        for row, j in enumerate(self.queue_jobs[i]):
            q_running[row, i] = j.state == _RUNNING
            q_slice[row, i] = j.slice_start
            q_chk[row, i] = j.checkpoint_time
        self._touch(i)

    def mark_dirty(self, host_id: int) -> None:
        """Flag a host whose ``ClientJob`` objects were mutated outside the
        simulator/engine hooks; its columns are rebuilt from the objects on
        the next snapshot (the dirty-host refresh contract)."""
        self.dirty.add(host_id)

    def resync_host(self, host_id: int) -> None:
        """Dirty-host refresh: rebuild the host's queue columns from its
        ``ClientJob`` objects (object fields win; ``actual_total`` — which
        exists only world-side — is carried over by instance id)."""
        i = self.index[host_id]
        cnt = int(self.q_count[i])
        totals = {
            j.instance_id: float(self.q_total[r, i])
            for r, j in enumerate(self.queue_jobs[i])
        }
        for name in self._q_fields():
            getattr(self, name)[:cnt, i] = 0
        for col in self.q_usage.values():
            col[:cnt, i] = 0
        client = self.clients[i]
        jobs = [j for j in client.jobs if j.state != _DONE] if client else []
        self.queue_jobs[i] = []
        self.row_of[i] = {}
        self.q_count[i] = 0
        self._touch(i)  # covers the zeroing even when no jobs re-add below
        for j in jobs:
            self.add_job(host_id, j, totals.get(j.instance_id, 0.0))
        self.dirty.discard(host_id)

    def sync_objects(self, host_ids: Sequence[int]) -> None:
        """Column->object sync: write authoritative accrual state back onto
        the ``ClientJob`` objects (used before falling back to an
        object-based snapshot). Every row is synced — preempted jobs carry
        accrual from earlier run periods too."""
        for h in host_ids:
            i = self.index[h]
            q_runtime = self.q_runtime
            q_frac = self.q_frac
            for row, j in enumerate(self.queue_jobs[i]):
                j.runtime = float(q_runtime[row, i])
                j.fraction_done = float(q_frac[row, i])

    # ------------------------------------------------------------------
    # accrual: scalar oracle and fused batch, identical per-cell math
    # ------------------------------------------------------------------

    def running_rows(self, host_id: int) -> np.ndarray:
        i = self.index[host_id]
        return np.flatnonzero(self.q_running[: self.q_count[i], i])

    def advance_host(self, host_id: int, t: float) -> None:
        """Scalar-oracle accrual for one host's running set: clamped
        charge of ``min(dt, actual_total - accrued)`` per running job, in
        queue-row order."""
        i = self.index.get(host_id)
        if i is None:
            return
        last = self.last_update[i]
        self.last_update[i] = t
        if not self.available[i] or not self.alive[i]:
            return
        cnt = int(self.q_count[i])
        if cnt == 0:
            return
        dt = t - last
        if dt <= 0:
            return
        rows = np.flatnonzero(self.q_running[:cnt, i])
        if rows.size == 0:
            return
        self._touch(i)  # mutates q_runtime/q_frac/busy below
        client = self.clients[i]
        q_runtime = self.q_runtime
        q_total = self.q_total
        jobs = self.queue_jobs[i]
        for row in rows:
            cj = jobs[row]
            total = q_total[row, i]
            rem = total - q_runtime[row, i]
            if rem < 0.0:
                rem = 0.0
            eff = dt if dt < rem else rem
            run = q_runtime[row, i] + eff
            q_runtime[row, i] = run
            cj.runtime = float(run)
            denom = total if total > 1e-9 else 1e-9
            frac = run / denom
            if frac > 1.0:
                frac = 1.0
            self.q_frac[row, i] = frac
            cj.fraction_done = float(frac)
            self.busy[i] += eff * self.q_usage[ResourceType.CPU][row, i]
            if client is not None:
                # REC debiting (§6.1): priorities must move with usage —
                # clamped to the work actually performed
                client.rec.debit(cj.project, eff * self.q_weight[row, i], t)

    def advance_batch(self, host_ids: Sequence[int], t: float) -> None:
        """Fused accrual for all hosts sharing an event time: one clamped
        array pass per occupied queue row, touching each (row, host) cell
        with the same IEEE operations — in the same per-cell order — as
        :meth:`advance_host`. Multi-project hosts (whose REC debits must
        stay per-job sequential to be bit-identical) are routed through the
        scalar path; the simulator's single-project populations never are."""
        if not host_ids:
            return
        index = self.index
        fused: List[int] = []
        for h in host_ids:
            i = index.get(h)
            if i is None:
                continue
            if self.multi[i]:
                self.advance_host(h, t)
            else:
                fused.append(i)
        if not fused:
            return
        idx = np.fromiter(fused, np.int64, len(fused))
        dt = t - self.last_update[idx]
        self.last_update[idx] = t
        act = (
            self.available[idx]
            & self.alive[idx]
            & (dt > 0.0)
            & (self.q_count[idx] > 0)
        )
        if not act.any():
            return
        sub = idx[act]
        dts = dt[act]
        debit, touched = self._advance_cols(sub, dts)
        if touched.any():
            clients = self.clients
            projects = self.project
            for j in np.flatnonzero(touched):
                i = int(sub[j])
                c = clients[i]
                if c is not None and projects[i] is not None:
                    c.rec.debit(projects[i], float(debit[j]), t)

    def _advance_cols(self, sub: np.ndarray, dts: np.ndarray):
        """The fused accrual pass over active dense slots ``sub``: returns
        (per-slot REC debit totals, touched mask). Backend-dispatched —
        this is the kernel the 1M-host bench times in isolation."""
        if self._mirror is not None:
            # device accrual: same per-cell IEEE ops and k-sequential
            # accumulation order as the loop below, with the eff·usage and
            # eff·weight products staged in their own jit (core.jax_backend)
            debit, touched = self._mirror.advance(self, sub, dts)
        else:
            K = int(self.q_count[sub].max())
            cpu_u = self.q_usage[ResourceType.CPU]
            debit = np.zeros(len(sub), dtype=np.float64)
            touched = np.zeros(len(sub), dtype=bool)
            for k in range(K):
                m = self.q_running[k, sub]
                if not m.any():
                    continue
                s2 = sub[m]
                d2 = dts[m]
                tot = self.q_total[k, s2]
                run = self.q_runtime[k, s2]
                rem = tot - run
                rem = np.where(rem < 0.0, 0.0, rem)
                eff = np.where(d2 < rem, d2, rem)
                run = run + eff
                self.q_runtime[k, s2] = run
                denom = np.where(tot > 1e-9, tot, 1e-9)
                frac = run / denom
                self.q_frac[k, s2] = np.where(frac > 1.0, 1.0, frac)
                self.busy[s2] += eff * cpu_u[k, s2]
                debit[m] += eff * self.q_weight[k, s2]
                touched |= m
        return debit, touched

    # ------------------------------------------------------------------
    # completion detection
    # ------------------------------------------------------------------

    def completed_rows(self, host_id: int) -> np.ndarray:
        """Queue rows of running jobs that have accrued their full cost."""
        i = self.index[host_id]
        cnt = int(self.q_count[i])
        if cnt == 0:
            return np.zeros(0, dtype=np.int64)
        col = slice(0, cnt)
        return np.flatnonzero(
            self.q_running[col, i]
            & (self.q_runtime[col, i] >= self.q_total[col, i] - 1e-6)
        )

    def completed_rows_batch(
        self, host_ids: Sequence[int]
    ) -> Dict[int, np.ndarray]:
        """Fused completion detection: one mask over the accrual matrix for
        every given host, returned as per-host row arrays."""
        index = self.index
        live = [(h, index[h]) for h in host_ids if h in index]
        if not live:
            return {}
        idx = np.fromiter((i for _, i in live), np.int64, len(live))
        counts = self.q_count[idx]
        K = int(counts.max()) if len(idx) else 0
        if K == 0:
            return {h: np.zeros(0, dtype=np.int64) for h, _ in live}
        if self._mirror is not None:
            sub = self._mirror.completed_mask(self, idx, counts)[:K]
        else:
            sub = self.q_running[:K, idx] & (
                self.q_runtime[:K, idx] >= self.q_total[:K, idx] - 1e-6
            )
            sub &= np.arange(K)[:, None] < counts[None, :]
        out: Dict[int, np.ndarray] = {}
        rows, cols = np.nonzero(sub.T)  # host-major
        split = np.searchsorted(rows, np.arange(len(idx) + 1))
        for j, (h, _) in enumerate(live):
            out[h] = cols[split[j]: split[j + 1]]
        return out

    # ------------------------------------------------------------------
    # metric totals (shared by both simulator modes)
    # ------------------------------------------------------------------

    def add_capacity(self, dt: float) -> None:
        n = self.n
        alive = self.alive[:n]
        self.capacity[:n][alive] += self.cap_ncpu[:n][alive] * dt

    def busy_total(self) -> float:
        return float(np.add.reduce(self.busy[: self.n]))

    def flops_total(self) -> float:
        return float(np.add.reduce(self.flops[: self.n]))

    def capacity_total(self) -> float:
        return float(np.add.reduce(self.capacity[: self.n]))

    # ------------------------------------------------------------------
    # invariants (the simulator's audit calls this, like store.check_invariants)
    # ------------------------------------------------------------------

    def check_invariants(self, strict_dynamic: bool = False) -> None:
        """Column <-> object agreement. ``strict_dynamic`` additionally
        checks accrual columns against object attributes (valid in scalar
        mode, where both are advanced together; the vectorized loop leaves
        object runtime/fraction intentionally stale until completion)."""
        for h, i in self.index.items():
            cnt = int(self.q_count[i])
            jobs = self.queue_jobs[i]
            assert len(jobs) == cnt, f"host {h}: queue length mismatch"
            if not self.alive[i]:
                assert cnt == 0, f"churned host {h} retains queue rows"
                assert self.clients[i] is None, f"churned host {h} retains client"
                continue
            assert self.row_of[i] == {
                j.instance_id: r for r, j in enumerate(jobs)
            }, f"host {h}: row index mismatch"
            for r, j in enumerate(jobs):
                assert j.state != _DONE, f"host {h}: DONE job resident in queue"
                assert self.q_running[r, i] == (j.state == _RUNNING), (
                    f"host {h} row {r}: run-state column stale"
                )
                assert self.q_dl[r, i] == j.deadline
                assert self.q_estf[r, i] == j.est_flops
                if strict_dynamic:
                    assert self.q_runtime[r, i] == j.runtime, (
                        f"host {h} row {r}: runtime column diverged"
                    )
                    assert self.q_frac[r, i] == j.fraction_done
            # freed tail must be exactly zero (engine padding contract)
            if cnt < self._q:
                assert not self.q_running[cnt:, i].any()
                assert not self.q_estf[cnt:, i].any()
