"""Adaptive replication (§3.4).

"The BOINC server maintains, for each (host, app version) pair, a count N of
the number of consecutive jobs that were validated by replication. Once N
exceeds a threshold, jobs sent to that host with that app version are
replicated only some of the time; the probability of replication goes to
zero as N increases. Adaptive replication can achieve a low bound on the
error rate ... while imposing only a small throughput overhead."

Reputation is kept at (host, app version) granularity because "some
computers are reliable for CPU jobs but unreliable for GPU jobs".

**Array backing.** The reputation table is a dense int64 array over
interned (host, app version) indices rather than a per-pair dict, so the
batch validation engine can reset/increment a whole tick's worth of
validation outcomes in fused passes (:meth:`apply_events`) and the
scheduler can draw a tick's replication decisions as one RNG batch
(:meth:`prefetch_draws` / :meth:`should_replicate_batch`). The scalar
methods (``on_validated`` / ``on_invalid`` / ``should_replicate``) operate
on the same table, one cell at a time, and consume the same RNG stream —
batched and sequential use are therefore interchangeable mid-run.
"""
from __future__ import annotations

import random
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Sequence, Tuple

import numpy as np


@dataclass
class AdaptiveReplication:
    """Per-(host, app-version) reputation and replication decisions."""

    threshold: int = 10  # N must exceed this before replication is relaxed
    min_probability: float = 0.01  # floor: spot checks never fully stop
    seed: int = 0
    _rng: random.Random = field(default=None, repr=False)  # type: ignore[assignment]
    _host_idx: Dict[int, int] = field(default_factory=dict, repr=False)
    _ver_idx: Dict[int, int] = field(default_factory=dict, repr=False)
    _table: np.ndarray = field(default=None, repr=False)  # type: ignore[assignment]
    # RNG draws prefetched by prefetch_draws; consumed FIFO, so batched and
    # per-call users see the identical stream the bare RNG would produce
    _draws: Deque[float] = field(default_factory=deque, repr=False)

    def __post_init__(self) -> None:
        self._rng = random.Random(self.seed)
        if self._table is None:
            self._table = np.zeros((0, 0), dtype=np.int64)

    # ---- interning / table growth ----

    def _index(self, host_id: int, app_version_id: int) -> Tuple[int, int]:
        hi = self._host_idx.get(host_id)
        if hi is None:
            hi = self._host_idx[host_id] = len(self._host_idx)
        vi = self._ver_idx.get(app_version_id)
        if vi is None:
            vi = self._ver_idx[app_version_id] = len(self._ver_idx)
        rows, cols = self._table.shape
        if hi >= rows or vi >= cols:
            grown = np.zeros(
                (max(rows * 2, hi + 1, 16), max(cols * 2, vi + 1, 16)),
                dtype=np.int64,
            )
            grown[:rows, :cols] = self._table
            self._table = grown
        return hi, vi

    @property
    def consecutive_valid(self) -> Dict[Tuple[int, int], int]:
        """Read-only dict *snapshot* of the dense table (nonzero
        reputations), for tests/demos/stats exports — mutations go through
        ``on_validated``/``on_invalid``/``apply_events``. O(nonzero
        cells)."""
        hosts = {hi: h for h, hi in self._host_idx.items()}
        vers = {vi: v for v, vi in self._ver_idx.items()}
        return {
            (hosts[int(hi)], vers[int(vi)]): int(self._table[hi, vi])
            for hi, vi in zip(*np.nonzero(self._table))
        }

    def key(self, host_id: int, app_version_id: int) -> Tuple[int, int]:
        return (host_id, app_version_id)

    # ---- scalar path (one cell at a time) ----

    def reputation(self, host_id: int, app_version_id: int) -> int:
        hi = self._host_idx.get(host_id)
        vi = self._ver_idx.get(app_version_id)
        if hi is None or vi is None:
            return 0
        return int(self._table[hi, vi])

    def replication_probability(self, host_id: int, app_version_id: int) -> float:
        """P(replicate a job sent to this host with this version)."""
        n = self.reputation(host_id, app_version_id)
        if n <= self.threshold:
            return 1.0
        # goes to zero as N increases, floored at min_probability
        return max(self.min_probability, self.threshold / float(n))

    def should_replicate(self, host_id: int, app_version_id: int) -> bool:
        p = self.replication_probability(host_id, app_version_id)
        return self._next_draw() < p

    def on_validated(self, host_id: int, app_version_id: int) -> None:
        hi, vi = self._index(host_id, app_version_id)
        self._table[hi, vi] += 1

    def on_invalid(self, host_id: int, app_version_id: int) -> None:
        """Any invalid/errored result resets reputation to zero."""
        hi, vi = self._index(host_id, app_version_id)
        self._table[hi, vi] = 0

    def forget_host(self, host_id: int) -> None:
        """Churn cleanup (§4): zero a departed host's reputation row. The
        dense row index stays interned (late-arriving results may still
        re-earn entries harmlessly), but the accumulated counts are
        cleared — a returning host id starts from zero reputation."""
        hi = self._host_idx.get(host_id)
        if hi is not None and hi < self._table.shape[0]:
            self._table[hi, :] = 0

    def expected_overhead(self, host_id: int, app_version_id: int) -> float:
        """Expected replication factor for this pair: 1 + p (one extra
        instance with probability p). The paper's claim is this -> ~1."""
        return 1.0 + self.replication_probability(host_id, app_version_id)

    # ---- RNG draw batching ----

    def _next_draw(self) -> float:
        return self._draws.popleft() if self._draws else self._rng.random()

    def prefetch_draws(self, n: int) -> None:
        """Pull ``n`` uniforms from the RNG now; subsequent decisions pop
        them FIFO. Because the cache preserves stream order, any prefetch
        size leaves every decision's draw identical to unbatched use."""
        if n > 0:
            self._draws.extend(self._rng.random() for _ in range(n))

    # ---- batched path (the validation engine / batch scheduler) ----

    def _gather_indices(
        self, host_ids: Sequence[int], ver_ids: Sequence[int]
    ) -> Tuple[np.ndarray, np.ndarray]:
        hidx = self._host_idx
        vidx = self._ver_idx
        hi = np.fromiter((hidx.get(h, -1) for h in host_ids), np.int64, len(host_ids))
        vi = np.fromiter((vidx.get(v, -1) for v in ver_ids), np.int64, len(ver_ids))
        return hi, vi

    def reputations(
        self, host_ids: Sequence[int], ver_ids: Sequence[int]
    ) -> np.ndarray:
        """Vectorized reputation gather (unknown pairs read 0)."""
        hi, vi = self._gather_indices(host_ids, ver_ids)
        known = (hi >= 0) & (vi >= 0)
        out = np.zeros(len(host_ids), dtype=np.int64)
        if known.any():
            out[known] = self._table[hi[known], vi[known]]
        return out

    def replication_probabilities(
        self, host_ids: Sequence[int], ver_ids: Sequence[int]
    ) -> np.ndarray:
        n = self.reputations(host_ids, ver_ids)
        p = np.ones(len(n), dtype=np.float64)
        relaxed = n > self.threshold
        if relaxed.any():
            p[relaxed] = np.maximum(
                self.min_probability, self.threshold / n[relaxed].astype(np.float64)
            )
        return p

    def should_replicate_batch(
        self, host_ids: Sequence[int], ver_ids: Sequence[int]
    ) -> np.ndarray:
        """One decision per pair, consuming one draw per pair in order —
        element i equals ``should_replicate(host_ids[i], ver_ids[i])``."""
        p = self.replication_probabilities(host_ids, ver_ids)
        draws = np.fromiter(
            (self._next_draw() for _ in range(len(p))), np.float64, len(p)
        )
        return draws < p

    def apply_events(
        self,
        host_ids: Sequence[int],
        ver_ids: Sequence[int],
        valid: Sequence[bool],
    ) -> None:
        """Apply an *ordered* sequence of validation outcomes in one fused
        pass: element i is ``on_validated(host_ids[i], ver_ids[i])`` when
        ``valid[i]`` else ``on_invalid(...)``, and the final table state is
        identical to applying them one by one. Per pair, the closed form
        is: the count of valid events after the pair's last invalid event,
        added to the prior reputation only if the pair saw no invalid.
        """
        m = len(host_ids)
        if m == 0:
            return
        hidx = self._host_idx
        vidx = self._ver_idx
        pairs: List[Tuple[int, int]] = []
        for h, v in zip(host_ids, ver_ids):
            hi = hidx.get(h)
            vi = vidx.get(v)
            if hi is None or vi is None:
                hi, vi = self._index(h, v)
            pairs.append((hi, vi))
        ncols = self._table.shape[1]
        flat = np.fromiter((hi * ncols + vi for hi, vi in pairs), np.int64, m)
        ok = np.asarray(valid, dtype=bool)
        seq = np.arange(m, dtype=np.int64)
        order = np.argsort(flat, kind="stable")
        fs = flat[order]
        starts = np.flatnonzero(np.r_[True, fs[1:] != fs[:-1]])
        counts = np.diff(np.r_[starts, m])
        gids = np.repeat(np.arange(len(starts)), counts)
        inv_seq = np.where(~ok, seq, -1)[order]
        last_inv = np.maximum.reduceat(inv_seq, starts)
        valid_after = ok[order] & (seq[order] > last_inv[gids])
        n_after = np.bincount(gids, weights=valid_after, minlength=len(starts))
        ukeys = fs[starts]
        flat_table = self._table.reshape(-1)
        base = flat_table[ukeys]
        flat_table[ukeys] = np.where(
            last_inv >= 0, n_after, base + n_after
        ).astype(np.int64)
