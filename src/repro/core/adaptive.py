"""Adaptive replication (§3.4).

"The BOINC server maintains, for each (host, app version) pair, a count N of
the number of consecutive jobs that were validated by replication. Once N
exceeds a threshold, jobs sent to that host with that app version are
replicated only some of the time; the probability of replication goes to
zero as N increases. Adaptive replication can achieve a low bound on the
error rate ... while imposing only a small throughput overhead."

Reputation is kept at (host, app version) granularity because "some
computers are reliable for CPU jobs but unreliable for GPU jobs".
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, Tuple


@dataclass
class AdaptiveReplication:
    """Per-(host, app-version) reputation and replication decisions."""

    threshold: int = 10  # N must exceed this before replication is relaxed
    min_probability: float = 0.01  # floor: spot checks never fully stop
    seed: int = 0
    consecutive_valid: Dict[Tuple[int, int], int] = field(default_factory=dict)
    _rng: random.Random = field(default=None, repr=False)  # type: ignore[assignment]

    def __post_init__(self) -> None:
        self._rng = random.Random(self.seed)

    def key(self, host_id: int, app_version_id: int) -> Tuple[int, int]:
        return (host_id, app_version_id)

    def reputation(self, host_id: int, app_version_id: int) -> int:
        return self.consecutive_valid.get(self.key(host_id, app_version_id), 0)

    def replication_probability(self, host_id: int, app_version_id: int) -> float:
        """P(replicate a job sent to this host with this version)."""
        n = self.reputation(host_id, app_version_id)
        if n <= self.threshold:
            return 1.0
        # goes to zero as N increases, floored at min_probability
        return max(self.min_probability, self.threshold / float(n))

    def should_replicate(self, host_id: int, app_version_id: int) -> bool:
        p = self.replication_probability(host_id, app_version_id)
        return self._rng.random() < p

    def on_validated(self, host_id: int, app_version_id: int) -> None:
        k = self.key(host_id, app_version_id)
        self.consecutive_valid[k] = self.consecutive_valid.get(k, 0) + 1

    def on_invalid(self, host_id: int, app_version_id: int) -> None:
        """Any invalid/errored result resets reputation to zero."""
        self.consecutive_valid[self.key(host_id, app_version_id)] = 0

    def expected_overhead(self, host_id: int, app_version_id: int) -> float:
        """Expected replication factor for this pair: 1 + p (one extra
        instance with probability p). The paper's claim is this -> ~1."""
        return 1.0 + self.replication_probability(host_id, app_version_id)
