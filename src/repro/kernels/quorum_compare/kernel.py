"""Fuzzy quorum comparison as a Pallas TPU kernel.

This is the hardware adaptation of the paper's validator hot loop (§3.4):
at gradient scale, deciding whether two replicas' results "agree within
tolerances" is a bandwidth-bound reduction over billions of elements. The
kernel counts out-of-tolerance elements (|a-b| > atol + rtol*|b|) per block
and accumulates into a scalar — one pass over both operands, no giant bool
intermediates in HBM.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quorum_kernel(a_ref, b_ref, count_ref, sq_ref, *, rtol: float, atol: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    diff = jnp.abs(a - b)
    bad = diff > (atol + rtol * jnp.abs(b))
    count_ref[0, 0] += jnp.sum(bad.astype(jnp.float32))
    sq_ref[0, 0] += jnp.sum(diff * diff)


def quorum_compare_kernel(
    a: jax.Array,  # (rows, d) — flattened payload
    b: jax.Array,
    *,
    rtol: float = 1e-5,
    atol: float = 1e-8,
    block_rows: int = 1024,
    interpret: bool = False,
):
    rows, d = a.shape
    assert rows % block_rows == 0
    kernel = functools.partial(_quorum_kernel, rtol=rtol, atol=atol)
    kwargs: dict[str, Any] = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)
        )
    count, sq = pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
            pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda r: (0, 0)),
            pl.BlockSpec((1, 1), lambda r: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
        name="quorum_compare",
        **kwargs,
    )(a, b)
    return count[0, 0], sq[0, 0]
