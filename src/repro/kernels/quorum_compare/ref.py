"""Pure-jnp oracle for the quorum_compare kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quorum_compare_ref(a: jax.Array, b: jax.Array, rtol: float = 1e-5, atol: float = 1e-8):
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    diff = jnp.abs(af - bf)
    bad = diff > (atol + rtol * jnp.abs(bf))
    return jnp.sum(bad.astype(jnp.float32)), jnp.sum(diff * diff)
