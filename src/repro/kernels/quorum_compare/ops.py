"""Jitted wrapper: flattens arbitrary payload pytrees and reports the
out-of-tolerance fraction + L2 distance — the fuzzy comparator the grid
runtime's validator uses on gradient/logit replicas."""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from .kernel import quorum_compare_kernel

_LANES = 256


@functools.partial(jax.jit, static_argnames=("rtol", "atol", "interpret"))
def quorum_compare(
    a: jax.Array,
    b: jax.Array,
    *,
    rtol: float = 1e-5,
    atol: float = 1e-8,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (n_bad, sum_sq_diff) over flattened inputs."""
    af = a.reshape(-1)
    bf = b.reshape(-1)
    n = af.shape[0]
    pad = (-n) % _LANES
    if pad:
        af = jnp.pad(af, (0, pad))
        bf = jnp.pad(bf, (0, pad))
    rows = af.shape[0] // _LANES
    af = af.reshape(rows, _LANES)
    bf = bf.reshape(rows, _LANES)
    br = min(1024, rows)
    rpad = (-rows) % br
    if rpad:
        af = jnp.pad(af, ((0, rpad), (0, 0)))
        bf = jnp.pad(bf, ((0, rpad), (0, 0)))
    return quorum_compare_kernel(
        af, bf, rtol=rtol, atol=atol, block_rows=br, interpret=interpret
    )


def tree_quorum_agree(
    tree_a: Any,
    tree_b: Any,
    *,
    rtol: float = 1e-4,
    atol: float = 1e-6,
    max_bad_fraction: float = 0.0,
    interpret: bool = True,
) -> bool:
    """Pytree-level fuzzy agreement — the validator comparator (§3.4)."""
    la = jax.tree_util.tree_leaves(tree_a)
    lb = jax.tree_util.tree_leaves(tree_b)
    if len(la) != len(lb):
        return False
    bad = 0.0
    total = 0
    for xa, xb in zip(la, lb):
        xa = jnp.asarray(xa)
        xb = jnp.asarray(xb)
        if xa.shape != xb.shape:
            return False
        nb, _ = quorum_compare(xa, xb, rtol=rtol, atol=atol, interpret=interpret)
        bad += float(nb)
        total += xa.size
    if total == 0:
        return True
    return (bad / total) <= max_bad_fraction
