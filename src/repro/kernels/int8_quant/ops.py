"""Jitted wrappers: array-shaped round trip used by optim/compression.py."""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from .kernel import int8_dequantize_kernel, int8_quantize_kernel

_LANES = 256


def _to_rows(x: jax.Array) -> Tuple[jax.Array, int, Tuple[int, ...]]:
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _LANES
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, _LANES), n, shape


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def int8_quantize(x: jax.Array, *, block_rows: int = 256, interpret: bool = False):
    rows2d, n, shape = _to_rows(x)
    rows = rows2d.shape[0]
    br = min(block_rows, rows)
    rpad = (-rows) % br
    if rpad:
        rows2d = jnp.pad(rows2d, ((0, rpad), (0, 0)))
    q, scales = int8_quantize_kernel(rows2d, block_rows=br, interpret=interpret)
    return q, scales


@functools.partial(
    jax.jit, static_argnames=("block_rows", "n", "shape", "out_dtype", "interpret")
)
def int8_dequantize(
    q: jax.Array,
    scales: jax.Array,
    *,
    n: int,
    shape: Tuple[int, ...],
    block_rows: int = 256,
    out_dtype: Any = jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    rows = q.shape[0]
    br = min(block_rows, rows)
    x = int8_dequantize_kernel(q, scales, block_rows=br, out_dtype=out_dtype, interpret=interpret)
    return x.reshape(-1)[:n].reshape(shape)


def quantize_dequantize(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Round-trip helper (what the compression path applies per shard)."""
    q, s = int8_quantize(x, interpret=interpret)
    return int8_dequantize(
        q, s, n=x.size, shape=tuple(x.shape), out_dtype=x.dtype, interpret=interpret
    )
