"""Block-scaled int8 quantize/dequantize Pallas kernels.

Used by the cross-pod gradient-compression path (optim/compression.py):
gradients are quantized per (block_rows x d) tile with an f32 scale before
the "pod"-axis reduction, cutting DCN bytes 4x. Deterministic
round-to-nearest-even (interpret-safe); the bias is absorbed by error
feedback in the optimizer.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quant_kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[0, 0] = scale


def _dequant_kernel(q_ref, scale_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * scale_ref[0, 0]).astype(x_ref.dtype)


def _grid_kwargs(interpret: bool) -> dict[str, Any]:
    if interpret:
        return {}
    return {
        "compiler_params": pltpu.CompilerParams(dimension_semantics=("parallel",))
    }


def int8_quantize_kernel(
    x: jax.Array,  # (rows, d)
    *,
    block_rows: int = 256,
    interpret: bool = False,
):
    rows, d = x.shape
    assert rows % block_rows == 0
    nb = rows // block_rows
    return pl.pallas_call(
        _quant_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda r: (r, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
            pl.BlockSpec((1, 1), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, d), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
        name="int8_quantize",
        **_grid_kwargs(interpret),
    )(x)


def int8_dequantize_kernel(
    q: jax.Array,  # (rows, d) int8
    scales: jax.Array,  # (nb, 1) f32
    *,
    block_rows: int = 256,
    out_dtype: Any = jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    rows, d = q.shape
    nb = rows // block_rows
    return pl.pallas_call(
        _dequant_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
            pl.BlockSpec((1, 1), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), out_dtype),
        interpret=interpret,
        name="int8_dequantize",
        **_grid_kwargs(interpret),
    )(q, scales)
