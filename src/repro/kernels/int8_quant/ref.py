"""Pure-jnp oracle for the int8 block-quant kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_quantize_ref(x: jax.Array, block_rows: int):
    rows, d = x.shape
    nb = rows // block_rows
    xb = x.astype(jnp.float32).reshape(nb, block_rows, d)
    amax = jnp.max(jnp.abs(xb), axis=(1, 2), keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(rows, d), scale.reshape(nb, 1)


def int8_dequantize_ref(q: jax.Array, scales: jax.Array, block_rows: int, out_dtype=jnp.float32):
    rows, d = q.shape
    nb = rows // block_rows
    qb = q.astype(jnp.float32).reshape(nb, block_rows, d)
    x = qb * scales.reshape(nb, 1, 1)
    return x.reshape(rows, d).astype(out_dtype)
