"""Jitted wrapper: flattens (..., d) to rows, pads to the row-block size."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import rmsnorm_kernel


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(
    x: jax.Array,
    scale: jax.Array,
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    shape = x.shape
    d = shape[-1]
    xf = x.reshape(-1, d)
    rows = xf.shape[0]
    br = min(block_rows, rows) if rows else 1
    pad = (-rows) % br
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = rmsnorm_kernel(xf, scale, eps=eps, block_rows=br, interpret=interpret)
    if pad:
        out = out[:rows]
    return out.reshape(shape)
