"""Fused RMSNorm Pallas kernel (bandwidth-bound: one HBM read, one write).

Grid over row blocks; each instance normalizes a (block_rows, d) tile in
VMEM. f32 statistics regardless of input dtype.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_kernel(
    x: jax.Array,  # (rows, d)
    scale: jax.Array,  # (d,)
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    rows, d = x.shape
    assert rows % block_rows == 0, (rows, block_rows)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    kwargs: dict[str, Any] = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel",)
        )
    return pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
            pl.BlockSpec((d,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
        name="rmsnorm",
        **kwargs,
    )(x, scale)
