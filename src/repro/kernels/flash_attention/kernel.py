"""Flash attention (forward) as a Pallas TPU kernel.

Block-tiled online-softmax attention with GQA sharing expressed through
BlockSpec index maps (no KV replication in HBM: query head h reads KV head
h // group_size). Grid = (batch, q_heads, q_blocks, kv_blocks); the kv axis
is the innermost, sequential ("arbitrary") dimension and carries the running
(m, l, acc) statistics in VMEM scratch. Causal blocks above the diagonal are
skipped entirely (no wasted MXU work), and the diagonal block is masked with
an iota comparison.

Tiling: block_q x head_dim and block_k x head_dim tiles live in VMEM; with
the default 128x128 blocks and D<=128 the working set is
  q (128*128) + k (128*128) + v (128*128) + acc/m/l  ~ 3.3 f32-MB << 16MB VMEM
and every matmul is MXU-aligned (128-multiples).
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    sm_scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
    kv_len: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        s = s * sm_scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        if kv_len % block_k:
            s = jnp.where(kpos < kv_len, s, NEG_INF)  # mask padded keys

        m_prev = m_ref[...]  # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    if causal:
        # skip blocks strictly above the diagonal
        needed = k_start <= q_start + block_q - 1
        pl.when(needed)(_body)
        last_needed = jnp.minimum(
            n_kv_blocks - 1, (q_start + block_q - 1) // block_k
        )
        is_last = ki == last_needed
    else:
        _body()
        is_last = ki == n_kv_blocks - 1

    @pl.when(is_last)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, KV, Sk, D)
    v: jax.Array,  # (B, KV, Sk, D)
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    kv_len: int | None = None,
) -> jax.Array:
    b, h, sq, d = q.shape
    kv, sk = k.shape[1], k.shape[2]
    assert h % kv == 0, (h, kv)
    group = h // kv
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    nq = sq // block_q
    nk = sk // block_k

    kernel = functools.partial(
        _flash_kernel,
        sm_scale=sm_scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=nk,
        kv_len=kv_len if kv_len is not None else sk,
    )
    grid = (b, h, nq, nk)
    kwargs: dict[str, Any] = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, q_, k_: (b_, h_ // group, k_, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, q_, k_: (b_, h_ // group, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
        name="flash_attention_fwd",
        **kwargs,
    )(q, k, v)
