"""Pure-jnp oracle for the flash_attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, KV, Sk, D)
    v: jax.Array,  # (B, KV, Sk, D)
    *,
    causal: bool = True,
    sm_scale: float | None = None,
) -> jax.Array:
    b, h, sq, d = q.shape
    kv, sk = k.shape[1], k.shape[2]
    group = h // kv
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) * sm_scale
    if causal:
        mask = jnp.arange(sk)[None, :] <= (jnp.arange(sq)[:, None] + (sk - sq))
        # when sq == sk this is the standard causal mask
        if sq == sk:
            mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, vf)
    return out.astype(q.dtype)
