"""Jitted public wrapper for the flash_attention Pallas kernel.

Handles layout (model code uses (B, S, H, D); the kernel wants (B, H, S, D)),
sequence padding to block multiples, and head_dim padding to the 128-lane
MXU width. ``interpret=True`` on CPU (tests); compiled path on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_kernel


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,  # (B, S, H, D) — model layout
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,  # (B, S, KV, D)
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, s, h, d = q.shape
    import math

    sm_scale = 1.0 / math.sqrt(d)  # scale with the TRUE head dim, pre-padding
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)

    # pad head_dim to the 128 lane width
    dpad = (-d) % 128 if d < 128 else (-d) % 128
    if dpad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, 0), (0, dpad)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, 0), (0, dpad)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, 0), (0, dpad)))
    # pad sequence to block multiples; padded keys are masked out by causality
    # for the padded-query rows, and sliced away for keys via masking below
    spad = (-s) % block_q
    if spad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, spad), (0, 0)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, spad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, spad), (0, 0)))

    out = flash_attention_kernel(
        qt,
        kt,
        vt,
        causal=causal,
        sm_scale=sm_scale,
        block_q=block_q,
        block_k=block_k,
        interpret=interpret,
        kv_len=s,
    )
    if spad:
        out = out[:, :, :s, :]
    if dpad:
        out = out[..., :d]
    return jnp.moveaxis(out, 1, 2)
