"""Pallas TPU kernels for the compute hot spots.

Each subpackage: kernel.py (pl.pallas_call + BlockSpec tiling), ops.py
(jitted public wrapper), ref.py (pure-jnp oracle). All validated in
interpret mode on CPU (tests/test_kernels.py); TPU is the target.

  flash_attention — GQA/causal blockwise online-softmax attention
  ssd_scan        — Mamba-2 SSD chunked scan (dual quadratic form)
  rmsnorm         — fused RMSNorm
  swiglu          — fused SwiGLU gate
  quorum_compare  — validator fuzzy-agreement reduction (§3.4 hot loop)
  int8_quant      — block-scaled int8 quant/dequant (grad compression)
"""
