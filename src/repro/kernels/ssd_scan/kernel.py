"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

Grid = (batch, heads, chunks); the chunk axis is sequential ("arbitrary")
and carries the (P, N) recurrent state in VMEM scratch. Within a chunk the
SSD dual (quadratic) form turns the recurrence into three MXU matmuls:

  CB     = C   @ B^T                  (Q,N)x(N,Q) -> (Q,Q)
  y_intra= (CB * L * dt_j) @ x        (Q,Q)x(Q,P) -> (Q,P)
  y_inter= exp(cum) * (C @ state^T)   (Q,N)x(N,P) -> (Q,P)
  state' = exp(total)*state + x^T_w @ B   (P,Q)x(Q,N) -> (P,N)

With chunk Q=128/256, P=64..128, N=64..128 all operands are VMEM-resident
(< 0.5 MB per tile) and MXU-aligned after the wrapper pads P/N to 128 lanes.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,  # (1,1,Q,P)
    dt_ref,  # (1,1,Q,1)
    a_ref,  # (1,1)
    b_ref,  # (1,1,Q,N)
    c_ref,  # (1,1,Q,N)
    y_ref,  # (1,1,Q,P)
    state_out_ref,  # (1,1,P,N)
    state_ref,  # scratch (P,N) f32
    *,
    n_chunks: int,
    block_q: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)  # (Q,P)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (Q,1)
    a_scalar = a_ref[0, 0].astype(jnp.float32)  # ()
    bm = b_ref[0, 0].astype(jnp.float32)  # (Q,N)
    cm = c_ref[0, 0].astype(jnp.float32)  # (Q,N)

    a = dt[:, 0] * a_scalar  # (Q,)
    cum = jnp.cumsum(a)  # (Q,)
    total = cum[-1]

    # L[i,j] = exp(cum_i - cum_j) for i >= j else 0
    diff = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (block_q, block_q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (block_q, block_q), 1)
    L = jnp.where(tri, jnp.exp(diff), 0.0)

    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    scores = cb * L * dt[None, :, 0]
    y_intra = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (Q,P)

    state = state_ref[...]  # (P,N)
    y_inter = jax.lax.dot_general(cm, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (Q,P)
    y_inter = y_inter * jnp.exp(cum)[:, None]

    # state update
    w = (dt[:, 0] * jnp.exp(total - cum))[:, None]  # (Q,1)
    xw = x * w  # (Q,P)
    contrib = jax.lax.dot_general(xw, bm, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (P,N)
    new_state = state * jnp.exp(total) + contrib
    state_ref[...] = new_state

    y_ref[0, 0, ...] = (y_intra + y_inter).astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _write_state():
        state_out_ref[0, 0, ...] = new_state.astype(state_out_ref.dtype)


def ssd_scan_kernel(
    x: jax.Array,  # (B, H, S, P)
    dt: jax.Array,  # (B, H, S, 1)
    A: jax.Array,  # (H, 1)
    Bm: jax.Array,  # (B, G, S, N)
    Cm: jax.Array,  # (B, G, S, N)
    *,
    block_q: int = 128,
    interpret: bool = False,
):
    b, h, s, p = x.shape
    g, n = Bm.shape[1], Bm.shape[3]
    rep = h // g
    assert s % block_q == 0, (s, block_q)
    nc = s // block_q

    kernel = functools.partial(_ssd_kernel, n_chunks=nc, block_q=block_q)
    kwargs: dict[str, Any] = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    y, final_state = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, p), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, block_q, 1), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1), lambda b_, h_, c_: (h_, 0)),
            pl.BlockSpec((1, 1, block_q, n), lambda b_, h_, c_: (b_, h_ // rep, c_, 0)),
            pl.BlockSpec((1, 1, block_q, n), lambda b_, h_, c_: (b_, h_ // rep, c_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, p), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
        name="ssd_scan",
        **kwargs,
    )(x, dt, A, Bm, Cm)
    return y, final_state
