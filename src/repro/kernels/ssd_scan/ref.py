"""Sequential-recurrence oracle for the SSD kernel (a *different* algorithm
from the kernel's chunked dual form, making the allclose check meaningful):

  s_t = exp(dt_t * A) * s_{t-1} + dt_t * (B_t (x) x_t)
  y_t = C_t . s_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
):
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)  # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(state, t):
        decay = jnp.exp(dtf[:, t] * Af[None, :])  # (B,H)
        upd = (dtf[:, t, :, None] * xf[:, t])[..., None] * Bh[:, t, :, None, :]
        state = state * decay[..., None, None] + upd  # (B,H,P,N)
        y = jnp.einsum("bhn,bhpn->bhp", Ch[:, t], state)
        return state, y

    state0 = jnp.zeros((b, h, p, n), jnp.float32)
    final, ys = jax.lax.scan(step, state0, jnp.arange(s))
    y = jnp.moveaxis(ys, 0, 1)  # (B,S,H,P)
    return y.astype(x.dtype), final
