"""Jitted wrapper for the SSD Pallas kernel: model layout -> kernel layout,
chunk padding (dt=0 padding is an exact no-op on the recurrence)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_scan_kernel


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def ssd_scan(
    x: jax.Array,  # (B, S, H, P) — model layout
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    *,
    block_q: int = 128,
    interpret: bool = False,
):
    b, s, h, p = x.shape
    pad = (-s) % block_q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    xt = jnp.moveaxis(x, 1, 2)  # (B,H,S,P)
    dtt = jnp.moveaxis(dt, 1, 2)[..., None]  # (B,H,S,1)
    bt = jnp.moveaxis(Bm, 1, 2)  # (B,G,S,N)
    ct = jnp.moveaxis(Cm, 1, 2)
    y, final_state = ssd_scan_kernel(
        xt, dtt, A[:, None], bt, ct, block_q=block_q, interpret=interpret
    )
    y = jnp.moveaxis(y, 1, 2)
    if pad:
        y = y[:, :s]
    return y, final_state
