"""Pure-jnp oracle for the swiglu kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def swiglu_ref(gate: jax.Array, up: jax.Array) -> jax.Array:
    gf = gate.astype(jnp.float32)
    return (jax.nn.silu(gf) * up.astype(jnp.float32)).astype(gate.dtype)
