"""Jitted wrapper for the fused SwiGLU kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import swiglu_kernel


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def swiglu(
    gate: jax.Array,
    up: jax.Array,
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    shape = gate.shape
    f = shape[-1]
    g = gate.reshape(-1, f)
    u = up.reshape(-1, f)
    rows = g.shape[0]
    br = min(block_rows, rows) if rows else 1
    pad = (-rows) % br
    if pad:
        g = jnp.pad(g, ((0, pad), (0, 0)))
        u = jnp.pad(u, ((0, pad), (0, 0)))
    out = swiglu_kernel(g, u, block_rows=br, interpret=interpret)
    if pad:
        out = out[:rows]
    return out.reshape(shape)
