"""Fused SwiGLU Pallas kernel: out = silu(gate) * up, one pass over HBM."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _swiglu_kernel(g_ref, u_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    o_ref[...] = (g * jax.lax.logistic(g) * u).astype(o_ref.dtype)


def swiglu_kernel(
    gate: jax.Array,  # (rows, f)
    up: jax.Array,  # (rows, f)
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    rows, f = gate.shape
    assert rows % block_rows == 0
    kwargs: dict[str, Any] = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel",)
        )
    return pl.pallas_call(
        _swiglu_kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, f), lambda r: (r, 0)),
            pl.BlockSpec((block_rows, f), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, f), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, f), gate.dtype),
        interpret=interpret,
        name="swiglu",
        **kwargs,
    )(gate, up)
