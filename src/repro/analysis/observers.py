"""index-bypass: no untracked writes to IndexObserved row fields.

``Job``/``JobInstance`` route tracked-field assignment through
``JobStore._on_field_change`` so the mutation-time indexes stay exact.
Writing those fields via ``object.__setattr__(inst, "state", ...)`` or
``inst.__dict__["state"] = ...`` skips the observer: the row changes, the
index doesn't, and ``check_invariants``'s oracle scan fires much later —
far from the cause.

Flagged shapes (outside ``config.BYPASS_MODULE_WHITELIST`` — the mixin
itself and the store's sanctioned fused bulk writers):

  * ``object.__setattr__(x, "<tracked>", v)``;
  * ``x.__dict__["<tracked>"] = v`` (and ``.update({...})`` with tracked
    keys).

Untracked fields (``claimed_credit``, ``granted_credit``, ``_store``)
may use either form freely — only names in ``config.TRACKED_FIELDS``
carry index obligations.
"""
from __future__ import annotations

import ast
from typing import Dict, List

from . import config
from .astutil import ScopedVisitor, dotted
from .findings import Finding


class _BypassVisitor(ScopedVisitor):
    def __init__(self, path: str) -> None:
        super().__init__()
        self.path = path
        self.findings: List[Finding] = []

    def _emit(self, node: ast.AST, field: str, what: str) -> None:
        self.findings.append(
            Finding(
                path=self.path,
                line=node.lineno,
                col=node.col_offset,
                rule=config.RULE_BYPASS,
                symbol=f"{self.qualname}:{field}",
                message=(
                    f"{what} writes tracked field '{field}' without notifying the "
                    f"store observer — violates the contract "
                    f"({config.RULE_CONTRACTS[config.RULE_BYPASS]}). "
                    f"Assign the attribute normally, or move the bulk write into "
                    f"a whitelisted store module ({list(config.BYPASS_MODULE_WHITELIST)}) "
                    f"where the index update is fused in."
                ),
            )
        )

    def visit_Call(self, node: ast.Call) -> None:
        if dotted(node.func) == "object.__setattr__" and len(node.args) >= 2:
            name = node.args[1]
            if (
                isinstance(name, ast.Constant)
                and isinstance(name.value, str)
                and name.value in config.TRACKED_FIELDS
            ):
                self._emit(node, name.value, "object.__setattr__")
        # x.__dict__.update({...})
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr == "update"
            and isinstance(node.func.value, ast.Attribute)
            and node.func.value.attr == "__dict__"
            and node.args
            and isinstance(node.args[0], ast.Dict)
        ):
            for k in node.args[0].keys:
                if (
                    isinstance(k, ast.Constant)
                    and isinstance(k.value, str)
                    and k.value in config.TRACKED_FIELDS
                ):
                    self._emit(node, k.value, "__dict__.update")
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        for tgt in node.targets:
            if (
                isinstance(tgt, ast.Subscript)
                and isinstance(tgt.value, ast.Attribute)
                and tgt.value.attr == "__dict__"
                and isinstance(tgt.slice, ast.Constant)
                and isinstance(tgt.slice.value, str)
                and tgt.slice.value in config.TRACKED_FIELDS
            ):
                self._emit(node, tgt.slice.value, "__dict__[...] assignment")
        self.generic_visit(node)


def check(path: str, tree: ast.Module, imports: Dict[str, str]) -> List[Finding]:
    posix = path.replace("\\", "/")
    if any(posix.endswith(suf) for suf in config.BYPASS_MODULE_WHITELIST):
        return []
    v = _BypassVisitor(path)
    v.visit(tree)
    return v.findings
