"""frozen-mut: no mutation of frozen value dataclasses outside __post_init__.

Frozen classes are the union of ``config.KNOWN_FROZEN_CLASSES`` and every
``@dataclass(frozen=True)`` definition discovered in the scanned tree
(the engine passes that set in). Three shapes are flagged:

  * attribute assignment (plain or augmented) through a variable whose
    annotation names a frozen class (parameter annotations and local
    ``AnnAssign`` both count) — this would raise FrozenInstanceError at
    runtime, but the lint catches it before a rarely-run branch does;
  * ``object.__setattr__(self, ...)`` inside a frozen class's methods,
    except ``__post_init__`` (the sanctioned construction-time escape);
  * ``object.__setattr__(x, ...)`` where ``x`` is frozen-annotated.

``dataclasses.replace(spec, ...)`` is the sanctioned way to derive a
modified spec; the finding message says so.
"""
from __future__ import annotations

import ast
from typing import Dict, FrozenSet, List, Optional

from . import config
from .astutil import ScopedVisitor, dotted, is_frozen_dataclass
from .findings import Finding


def discover_frozen(tree: ast.Module) -> FrozenSet[str]:
    return frozenset(
        n.name
        for n in ast.walk(tree)
        if isinstance(n, ast.ClassDef) and is_frozen_dataclass(n)
    )


def _annotation_name(node: Optional[ast.AST]) -> Optional[str]:
    if node is None:
        return None
    try:
        text = ast.unparse(node)
    except Exception:  # pragma: no cover
        return None
    # Optional["ScenarioSpec"] / "ScenarioSpec" / ScenarioSpec
    text = text.strip("\"'")
    for wrapper in ("Optional[", "Final["):
        if text.startswith(wrapper) and text.endswith("]"):
            text = text[len(wrapper):-1].strip("\"'")
    return text.split(".")[-1] or None


class _FrozenVisitor(ScopedVisitor):
    def __init__(self, path: str, frozen: FrozenSet[str], tree: ast.Module) -> None:
        super().__init__()
        self.path = path
        self.frozen = frozen
        self.findings: List[Finding] = []
        #: per-function annotated-variable maps, keyed by id(funcnode)
        self._var_types: List[Dict[str, str]] = [{}]
        #: class defs that are frozen, by name, for the self case
        self._frozen_classes = {
            n.name
            for n in ast.walk(tree)
            if isinstance(n, ast.ClassDef)
            and (is_frozen_dataclass(n) or n.name in frozen)
        }

    # -- scope bookkeeping ------------------------------------------------

    def _visit_func(self, node) -> None:
        scope: Dict[str, str] = {}
        for arg in list(node.args.args) + list(node.args.kwonlyargs):
            t = _annotation_name(arg.annotation)
            if t in self.frozen:
                scope[arg.arg] = t
        self._var_types.append(scope)
        super()._visit_func(node)
        self._var_types.pop()

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if isinstance(node.target, ast.Name):
            t = _annotation_name(node.annotation)
            if t in self.frozen:
                self._var_types[-1][node.target.id] = t
        self.generic_visit(node)

    def _frozen_type_of(self, name: str) -> Optional[str]:
        for scope in reversed(self._var_types):
            if name in scope:
                return scope[name]
        return None

    # -- findings ---------------------------------------------------------

    def _emit(self, node: ast.AST, cls: str, attr: str, what: str) -> None:
        self.findings.append(
            Finding(
                path=self.path,
                line=node.lineno,
                col=node.col_offset,
                rule=config.RULE_FROZEN,
                symbol=f"{cls}.{attr}",
                message=(
                    f"{what} mutates frozen {cls} outside __post_init__ — "
                    f"violates the contract ({config.RULE_CONTRACTS[config.RULE_FROZEN]}). "
                    f"Derive a new spec with dataclasses.replace(...) instead; "
                    f"construction-time writes belong in __post_init__ "
                    f"(the whitelisted scope)."
                ),
            )
        )

    def _check_store(self, target: ast.AST, node: ast.AST) -> None:
        if isinstance(target, ast.Attribute) and isinstance(target.value, ast.Name):
            t = self._frozen_type_of(target.value.id)
            if t is not None and self.enclosing_function != "__post_init__":
                self._emit(
                    node, t, target.attr,
                    f"assignment to {target.value.id}.{target.attr}",
                )

    def visit_Assign(self, node: ast.Assign) -> None:
        for tgt in node.targets:
            self._check_store(tgt, node)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_store(node.target, node)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        if (dotted(node.func) == "object.__setattr__") and node.args:
            first = node.args[0]
            attr = (
                node.args[1].value
                if len(node.args) > 1
                and isinstance(node.args[1], ast.Constant)
                and isinstance(node.args[1].value, str)
                else "<dynamic>"
            )
            if isinstance(first, ast.Name):
                if first.id == "self":
                    cls = self.enclosing_class
                    if (
                        cls in self._frozen_classes
                        and self.enclosing_function != "__post_init__"
                    ):
                        self._emit(
                            node, cls or "<class>", attr,
                            "object.__setattr__(self, ...)",
                        )
                else:
                    t = self._frozen_type_of(first.id)
                    if t is not None and self.enclosing_function != "__post_init__":
                        self._emit(
                            node, t, attr,
                            f"object.__setattr__({first.id}, ...)",
                        )
        self.generic_visit(node)


def check(
    path: str,
    tree: ast.Module,
    imports: Dict[str, str],
    frozen: FrozenSet[str] = frozenset(),
) -> List[Finding]:
    all_frozen = frozenset(config.KNOWN_FROZEN_CLASSES) | frozen | discover_frozen(tree)
    v = _FrozenVisitor(path, all_frozen, tree)
    v.visit(tree)
    return v.findings
