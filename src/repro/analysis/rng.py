"""rng-discipline: no draws outside seeded entry points and draw caches.

Flags three shapes, all of which desynchronize the scalar and vector
engines' draw sequences (or make a run unreproducible outright):

  * module-level draws on the process-global stream
    (``random.random()``, ``random.shuffle(...)``, ...);
  * unseeded RNG construction (``random.Random()`` with no seed,
    ``random.SystemRandom(...)``, zero-argument ``np.random.default_rng()``);
  * legacy/hidden-state numpy RNG (``np.random.RandomState``,
    ``np.random.rand``, ``np.random.seed``, ...).

Seeded ``random.Random(seed)`` construction and the
``np.random.SeedSequence``/``default_rng(seed)``/``Generator`` family are
the sanctioned seed-entry points (``config.NP_SEED_ENTRY``); drawing from
an rng *object* (a parameter or a seeded ``self._rng``) is always fine —
the object's provenance is what the seed-entry rule pins down. Modules in
``config.RNG_MODULE_WHITELIST`` (draw-cache hosts) are exempt wholesale.
"""
from __future__ import annotations

import ast
from typing import Dict, List

from . import config
from .astutil import ScopedVisitor, dotted, resolve
from .findings import Finding


class _RngVisitor(ScopedVisitor):
    def __init__(self, path: str, imports: Dict[str, str]) -> None:
        super().__init__()
        self.path = path
        self.imports = imports
        self.findings: List[Finding] = []

    def _emit(self, node: ast.AST, symbol: str, what: str, fix: str) -> None:
        self.findings.append(
            Finding(
                path=self.path,
                line=node.lineno,
                col=node.col_offset,
                rule=config.RULE_RNG,
                symbol=f"{self.qualname}:{symbol}",
                message=(
                    f"{what} breaks the contract ({config.RULE_CONTRACTS[config.RULE_RNG]}). "
                    f"{fix} Whitelist: seed-entry constructors "
                    f"{sorted(config.NP_SEED_ENTRY)} and seeded random.Random(seed); "
                    f"draw-cache modules: {list(config.RNG_MODULE_WHITELIST) or 'none'}."
                ),
            )
        )

    def visit_Call(self, node: ast.Call) -> None:
        chain = dotted(node.func)
        if chain is not None:
            full = resolve(chain, self.imports)
            parts = full.split(".")
            if parts[0] == "random" and len(parts) == 2:
                fn = parts[1]
                if fn in config.RNG_GLOBAL_DRAWS:
                    self._emit(
                        node,
                        f"random.{fn}",
                        f"module-level draw random.{fn}() on the global stream",
                        "Thread a seeded random.Random through the caller instead.",
                    )
                elif fn == "Random" and not node.args and not node.keywords:
                    self._emit(
                        node,
                        "random.Random()",
                        "unseeded random.Random() (seeds from OS entropy)",
                        "Pass an explicit integer seed.",
                    )
                elif fn == "SystemRandom":
                    self._emit(
                        node,
                        "random.SystemRandom",
                        "random.SystemRandom (OS entropy; never reproducible)",
                        "Use seeded random.Random(seed).",
                    )
            elif parts[:2] == ["numpy", "random"] and len(parts) == 3:
                fn = parts[2]
                if fn not in config.NP_SEED_ENTRY:
                    self._emit(
                        node,
                        f"np.random.{fn}",
                        f"legacy/hidden-state numpy RNG np.random.{fn}",
                        "Use np.random.default_rng(np.random.SeedSequence([...])) "
                        "or derive constants by hashing (no RNG namespace).",
                    )
                elif fn == "default_rng" and not node.args and not node.keywords:
                    self._emit(
                        node,
                        "np.random.default_rng()",
                        "unseeded np.random.default_rng() (seeds from OS entropy)",
                        "Pass a SeedSequence or integer seed.",
                    )
        self.generic_visit(node)


def check(path: str, tree: ast.Module, imports: Dict[str, str]) -> List[Finding]:
    posix = path.replace("\\", "/")
    if any(posix.endswith(suf) for suf in config.RNG_MODULE_WHITELIST):
        return []
    v = _RngVisitor(path, imports)
    v.visit(tree)
    return v.findings
