"""Small shared AST helpers for the reprolint checkers."""
from __future__ import annotations

import ast
from typing import Dict, List, Optional


def dotted(node: ast.AST) -> Optional[str]:
    """Render a Name/Attribute chain as ``a.b.c`` (None for anything else)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def import_map(tree: ast.Module) -> Dict[str, str]:
    """Local name -> canonical dotted module/object path.

    Covers ``import random``, ``import numpy as np``,
    ``from numpy import random as npr`` and
    ``from random import choice`` — enough to resolve the RNG namespaces
    this repo's rules care about.
    """
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                local = alias.asname or alias.name.split(".")[0]
                target = alias.name if alias.asname else alias.name.split(".")[0]
                out[local] = target
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for alias in node.names:
                local = alias.asname or alias.name
                out[local] = f"{node.module}.{alias.name}"
    return out


def resolve(chain: str, imports: Dict[str, str]) -> str:
    """Rewrite the root of a dotted chain through the import map, then
    canonicalize the numpy alias (``np.random.x`` -> ``numpy.random.x``)."""
    root, _, rest = chain.partition(".")
    base = imports.get(root, root)
    full = f"{base}.{rest}" if rest else base
    if full == "np" or full.startswith("np."):
        full = "numpy" + full[2:]
    return full


class ScopedVisitor(ast.NodeVisitor):
    """NodeVisitor that tracks the enclosing class/function qualname."""

    def __init__(self) -> None:
        self._stack: List[str] = []

    @property
    def qualname(self) -> str:
        return ".".join(self._stack) or "<module>"

    @property
    def enclosing_class(self) -> Optional[str]:
        return self._class_stack[-1] if getattr(self, "_class_stack", None) else None

    @property
    def enclosing_function(self) -> Optional[str]:
        return self._func_stack[-1] if getattr(self, "_func_stack", None) else None

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._stack.append(node.name)
        if not hasattr(self, "_class_stack"):
            self._class_stack: List[str] = []
        self._class_stack.append(node.name)
        self.generic_visit(node)
        self._class_stack.pop()
        self._stack.pop()

    def _visit_func(self, node) -> None:
        self._stack.append(node.name)
        if not hasattr(self, "_func_stack"):
            self._func_stack: List[str] = []
        self._func_stack.append(node.name)
        self.generic_visit(node)
        self._func_stack.pop()
        self._stack.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._visit_func(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._visit_func(node)


def is_frozen_dataclass(node: ast.ClassDef) -> bool:
    """True for ``@dataclass(frozen=True)`` (any dataclass alias spelling)."""
    for dec in node.decorator_list:
        if isinstance(dec, ast.Call):
            name = dotted(dec.func) or ""
            if name.split(".")[-1] == "dataclass":
                for kw in dec.keywords:
                    if (
                        kw.arg == "frozen"
                        and isinstance(kw.value, ast.Constant)
                        and kw.value.value is True
                    ):
                        return True
    return False
