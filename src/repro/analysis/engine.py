"""The reprolint driver: file collection, two-pass scan, baseline compare.

Pass 1 parses every file and collects the tree-wide frozen-class set (a
``ScenarioSpec`` parameter in ``simulator.py`` must be recognized even
though the class is defined in ``scenarios.py``). Pass 2 runs the five
checkers per file, applies inline suppressions, then partitions the
surviving findings against the baseline.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from . import floatops, frozen, observers, purge, rng
from .astutil import import_map
from .findings import (
    Finding,
    Report,
    is_suppressed,
    load_baseline,
    split_against_baseline,
    suppressed_rules_by_line,
)

_CHECKERS = (rng.check, purge.check, floatops.check, observers.check)


def collect_files(paths: Sequence[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(
                    d for d in dirnames if d not in ("__pycache__", ".git")
                )
                for name in sorted(filenames):
                    if name.endswith(".py"):
                        out.append(os.path.join(dirpath, name))
        elif p.endswith(".py"):
            out.append(p)
    # the analyzer does not lint itself (its config literals mention every
    # forbidden spelling)
    me = os.path.dirname(os.path.abspath(__file__))
    return [f for f in out if os.path.dirname(os.path.abspath(f)) != me]


def _relpath(path: str, root: Optional[str]) -> str:
    if root:
        try:
            return os.path.relpath(path, root).replace(os.sep, "/")
        except ValueError:  # pragma: no cover - cross-drive on windows
            pass
    return path.replace(os.sep, "/")


def run_checks(
    paths: Sequence[str],
    baseline_path: Optional[str] = None,
    root: Optional[str] = None,
) -> Report:
    """Run every reprolint rule over ``paths`` (files or directories).

    Returns a :class:`Report`; ``report.ok`` is False iff there are
    non-suppressed findings absent from the baseline.
    """
    files = collect_files(paths)
    parsed: List[Tuple[str, str, ast.Module, str]] = []  # (file, rel, tree, src)
    frozen_names: FrozenSet[str] = frozenset()
    for f in files:
        with open(f, "r", encoding="utf-8") as fh:
            src = fh.read()
        tree = ast.parse(src, filename=f)
        parsed.append((f, _relpath(f, root), tree, src))
        frozen_names = frozen_names | frozen.discover_frozen(tree)

    report = Report(files_scanned=len(parsed))
    all_findings: List[Finding] = []
    for _, rel, tree, src in parsed:
        imports = import_map(tree)
        file_findings: List[Finding] = []
        for checker in _CHECKERS:
            file_findings.extend(checker(rel, tree, imports))
        file_findings.extend(frozen.check(rel, tree, imports, frozen=frozen_names))
        table = suppressed_rules_by_line(src)
        for fnd in sorted(file_findings, key=lambda x: (x.line, x.col, x.rule)):
            if is_suppressed(fnd, table):
                report.suppressed.append(fnd)
            else:
                all_findings.append(fnd)

    report.findings = all_findings
    baseline = load_baseline(baseline_path) if baseline_path else []
    report.new, report.baselined, report.stale_baseline = split_against_baseline(
        all_findings, baseline
    )
    return report
