"""purge-complete: every per-host container has a purge-path clear.

Discovery (per class in a ``core/`` module):

  * container attributes — class-body ``AnnAssign`` whose annotation
    renders as a dict/defaultdict type, or ``self.x = {}/dict()/
    defaultdict(...)`` assignments in ``__init__``;
  * host-keyed evidence — the attribute name contains ``host``, or the
    module subscripts/``get``s/``pop``s the attribute with a key variable
    named like a host id (``config.HOST_KEY_NAMES``, or an attribute
    chain ending ``.host_id``).

Verification: some function whose name matches a purge-path fragment
(``config.PURGE_PATH_NAMES``) must reference the attribute. Referencing
is enough — deliberate retention (tombstoned ``world.index`` slots,
interned ``_host_idx`` rows) lives *inside* the purge path where the
decision is documented. Containers on per-tick ephemeral classes
(``config.PURGE_EPHEMERAL_CLASSES``) are exempt; permanent documented
exceptions (credit history kept per §7) use an inline
``# reprolint: ignore[purge-complete]`` on the declaration line.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from . import config
from .astutil import dotted
from .findings import Finding

_DICT_MARKERS = ("Dict[", "dict[", "defaultdict", "DefaultDict", "dict")


def _is_dict_annotation(node: ast.AST) -> bool:
    try:
        text = ast.unparse(node)
    except Exception:  # pragma: no cover - unparse failure
        return False
    return any(text.startswith(m) or f"[{m}" in text for m in _DICT_MARKERS)


def _is_dict_value(node: ast.AST) -> bool:
    if isinstance(node, ast.Dict):
        return True
    if isinstance(node, ast.Call):
        name = dotted(node.func) or ""
        leaf = name.split(".")[-1]
        if leaf in {"dict", "defaultdict", "OrderedDict"}:
            return True
        # dataclasses.field(default_factory=dict/defaultdict/...)
        if leaf == "field":
            for kw in node.keywords:
                if kw.arg == "default_factory":
                    f = kw.value
                    fname = dotted(f) or ""
                    if fname.split(".")[-1] in {"dict", "defaultdict", "OrderedDict"}:
                        return True
                    if isinstance(f, ast.Lambda) and _is_dict_value(f.body):
                        return True
    return False


def _host_key_expr(node: ast.AST) -> bool:
    """Does this subscript/argument expression look like a host id?"""
    if isinstance(node, ast.Name) and node.id in config.HOST_KEY_NAMES:
        return True
    if isinstance(node, ast.Attribute) and node.attr == "host_id":
        return True
    if isinstance(node, ast.Tuple):
        return any(_host_key_expr(e) for e in node.elts)
    return False


class _ClassInfo:
    def __init__(self, name: str) -> None:
        self.name = name
        #: attr -> (lineno, col)
        self.containers: Dict[str, Tuple[int, int]] = {}
        self.host_keyed: Set[str] = set()
        #: attrs referenced from inside purge-path functions
        self.purged: Set[str] = set()
        self.has_purge_path = False


def _is_purge_name(name: str) -> bool:
    low = name.lower()
    return any(frag in low for frag in config.PURGE_PATH_NAMES)


def _collect_class(cls: ast.ClassDef, info: _ClassInfo) -> None:
    # class-body annotated containers
    for stmt in cls.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
            if _is_dict_annotation(stmt.annotation) or (
                stmt.value is not None and _is_dict_value(stmt.value)
            ):
                info.containers[stmt.target.id] = (stmt.lineno, stmt.col_offset)
        elif isinstance(stmt, ast.Assign):
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Name) and stmt.value is not None and _is_dict_value(stmt.value):
                    info.containers[tgt.id] = (stmt.lineno, stmt.col_offset)

    # __init__ self.x = {} containers
    for stmt in cls.body:
        if isinstance(stmt, ast.FunctionDef) and stmt.name in ("__init__", "__post_init__"):
            for node in ast.walk(stmt):
                targets: List[ast.AST] = []
                if isinstance(node, ast.Assign) and _is_dict_value(node.value):
                    targets = list(node.targets)
                elif isinstance(node, ast.AnnAssign) and (
                    _is_dict_annotation(node.annotation)
                    or (node.value is not None and _is_dict_value(node.value))
                ):
                    targets = [node.target]
                for tgt in targets:
                    if (
                        isinstance(tgt, ast.Attribute)
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == "self"
                    ):
                        info.containers.setdefault(
                            tgt.attr, (node.lineno, node.col_offset)
                        )

    # evidence + purge references, scanning every method
    for stmt in cls.body:
        if not isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        purging = _is_purge_name(stmt.name)
        if purging:
            info.has_purge_path = True
        for node in ast.walk(stmt):
            attr: Optional[str] = None
            if (
                isinstance(node, ast.Subscript)
                and isinstance(node.value, ast.Attribute)
                and isinstance(node.value.value, ast.Name)
                and node.value.value.id == "self"
            ):
                attr = node.value.attr
                if _host_key_expr(node.slice):
                    info.host_keyed.add(attr)
            elif (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in {"get", "pop", "setdefault", "__contains__"}
                and isinstance(node.func.value, ast.Attribute)
                and isinstance(node.func.value.value, ast.Name)
                and node.func.value.value.id == "self"
            ):
                attr = node.func.value.attr
                if node.args and _host_key_expr(node.args[0]):
                    info.host_keyed.add(attr)
            if purging:
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Attribute) and isinstance(
                        sub.value, ast.Name
                    ) and sub.value.id == "self":
                        info.purged.add(sub.attr)

    # name heuristic
    for attr in info.containers:
        if config.HOST_NAME_FRAGMENT in attr.lower():
            info.host_keyed.add(attr)


def check(path: str, tree: ast.Module, imports: Dict[str, str]) -> List[Finding]:
    posix = path.replace("\\", "/")
    parts = posix.split("/")
    if not any(d in parts for d in config.PURGE_SCOPE_DIRS):
        return []

    findings: List[Finding] = []
    # module-level purge functions also count (e.g. free functions)
    module_purgers: List[ast.FunctionDef] = [
        n
        for n in tree.body
        if isinstance(n, ast.FunctionDef) and _is_purge_name(n.name)
    ]
    module_purged: Set[str] = set()
    for fn in module_purgers:
        for node in ast.walk(fn):
            if isinstance(node, ast.Attribute):
                module_purged.add(node.attr)

    for cls in [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]:
        if cls.name in config.PURGE_EPHEMERAL_CLASSES:
            continue
        info = _ClassInfo(cls.name)
        _collect_class(cls, info)
        for attr, (line, col) in sorted(info.containers.items()):
            if attr not in info.host_keyed:
                continue
            if attr in info.purged or attr in module_purged:
                continue
            findings.append(
                Finding(
                    path=path,
                    line=line,
                    col=col,
                    rule=config.RULE_PURGE,
                    symbol=f"{cls.name}.{attr}",
                    message=(
                        f"per-host container {cls.name}.{attr} has no clear in any "
                        f"purge path ({'/'.join(config.PURGE_PATH_NAMES[:3])}...) — "
                        f"violates the contract ({config.RULE_CONTRACTS[config.RULE_PURGE]}). "
                        f"Add a forget_host that pops the entry, or — for documented "
                        f"permanent retention (e.g. credit history per §7) — suppress "
                        f"with '# reprolint: ignore[{config.RULE_PURGE}]' on this line. "
                        f"Per-tick ephemeral classes belong in PURGE_EPHEMERAL_CLASSES."
                    ),
                )
            )
    return findings
