"""Finding/report data model, inline suppressions, and the baseline ratchet.

Baseline entries are keyed by ``(path, rule, symbol)`` — never by line
number — so unrelated edits to a file don't churn the baseline. The
ratchet direction is one-way: a finding missing from the baseline fails
the run ("no new findings"), and a baseline entry that no longer fires is
*stale* and must be deleted ("the baseline only shrinks").
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

BASELINE_VERSION = 1

_SUPPRESS_RE = re.compile(r"#\s*reprolint:\s*ignore(?:\[([a-z0-9_,\- ]+)\])?")


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location.

    ``symbol`` is the stable identity used for baseline matching (e.g.
    ``Coordinator.assignments`` or ``_mix_vector:np.random.RandomState``);
    ``message`` names the violated contract and the whitelist/suppression
    that would apply, run_parity-style.
    """

    path: str
    line: int
    col: int
    rule: str
    symbol: str
    message: str

    @property
    def baseline_key(self) -> Tuple[str, str, str]:
        return (self.path, self.rule, self.symbol)

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col} {self.rule} {self.message}"

    def to_dict(self) -> Dict[str, object]:
        return {
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "rule": self.rule,
            "symbol": self.symbol,
            "message": self.message,
        }


@dataclass
class Report:
    """The outcome of one analysis run."""

    findings: List[Finding] = field(default_factory=list)  # non-suppressed
    new: List[Finding] = field(default_factory=list)  # not in baseline
    baselined: List[Finding] = field(default_factory=list)
    suppressed: List[Finding] = field(default_factory=list)
    stale_baseline: List[Tuple[str, str, str]] = field(default_factory=list)
    files_scanned: int = 0

    @property
    def ok(self) -> bool:
        return not self.new

    def to_dict(self) -> Dict[str, object]:
        from .config import RULE_CONTRACTS

        return {
            "tool": "reprolint",
            "baseline_version": BASELINE_VERSION,
            "files_scanned": self.files_scanned,
            "rules": dict(RULE_CONTRACTS),
            "findings": [f.to_dict() for f in self.findings],
            "new": [f.to_dict() for f in self.new],
            "baselined": [f.to_dict() for f in self.baselined],
            "suppressed": [f.to_dict() for f in self.suppressed],
            "stale_baseline": [
                {"path": p, "rule": r, "symbol": s} for p, r, s in self.stale_baseline
            ],
            "ok": self.ok,
        }


# ---------------------------------------------------------------------------
# Inline suppressions
# ---------------------------------------------------------------------------


def suppressed_rules_by_line(source: str) -> Dict[int, Optional[frozenset]]:
    """Map 1-based line numbers to the rules suppressed on that line.

    ``# reprolint: ignore`` suppresses every rule on its line (value
    ``None``); ``# reprolint: ignore[rule-a,rule-b]`` suppresses only the
    listed rules.
    """
    out: Dict[int, Optional[frozenset]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        if m.group(1) is None:
            out[i] = None
        else:
            out[i] = frozenset(r.strip() for r in m.group(1).split(",") if r.strip())
    return out


def is_suppressed(finding: Finding, table: Dict[int, Optional[frozenset]]) -> bool:
    rules = table.get(finding.line, "absent")
    if rules == "absent":
        return False
    return rules is None or finding.rule in rules  # type: ignore[operator]


# ---------------------------------------------------------------------------
# Baseline I/O
# ---------------------------------------------------------------------------


def load_baseline(path: str) -> List[Tuple[str, str, str]]:
    with open(path, "r", encoding="utf-8") as f:
        data = json.load(f)
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"baseline {path}: unsupported version {data.get('version')!r}"
        )
    out = []
    for e in data.get("findings", []):
        out.append((e["path"], e["rule"], e["symbol"]))
    return out


def dump_baseline(path: str, findings: Sequence[Finding]) -> None:
    entries = sorted(
        {f.baseline_key for f in findings}
    )
    data = {
        "version": BASELINE_VERSION,
        "comment": (
            "Grandfathered reprolint findings. Ratchet: entries may only be "
            "removed (after fixing or inline-suppressing the finding), never "
            "added — new findings must be fixed, not baselined."
        ),
        "findings": [
            {"path": p, "rule": r, "symbol": s} for p, r, s in entries
        ],
    }
    with open(path, "w", encoding="utf-8") as f:
        json.dump(data, f, indent=2, sort_keys=False)
        f.write("\n")


def split_against_baseline(
    findings: Sequence[Finding], baseline: Sequence[Tuple[str, str, str]]
) -> Tuple[List[Finding], List[Finding], List[Tuple[str, str, str]]]:
    """Partition into (new, baselined) and compute stale baseline entries."""
    bset = set(baseline)
    new: List[Finding] = []
    old: List[Finding] = []
    seen: set = set()
    for f in findings:
        if f.baseline_key in bset:
            old.append(f)
            seen.add(f.baseline_key)
        else:
            new.append(f)
    stale = sorted(bset - seen)
    return new, old, stale
