"""``python -m repro.analysis`` — the reprolint CLI.

Exit codes: 0 clean (or every finding baselined/suppressed), 1 when new
findings exist (or, with ``--fail-on-stale``, when baseline entries no
longer fire — the shrink ratchet), 2 on usage errors.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

from .engine import run_checks
from .findings import dump_baseline

DEFAULT_BASELINE = "reprolint_baseline.json"


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint: check the repo's parity/RNG/purge contracts",
    )
    ap.add_argument("paths", nargs="+", help="files or directories to scan")
    ap.add_argument(
        "--baseline",
        default=None,
        help=f"baseline JSON of grandfathered findings (default: "
        f"./{DEFAULT_BASELINE} when present)",
    )
    ap.add_argument(
        "--no-baseline",
        action="store_true",
        help="ignore any baseline file; every finding counts as new",
    )
    ap.add_argument(
        "--write-baseline",
        metavar="FILE",
        default=None,
        help="write the current finding set as the new baseline and exit 0",
    )
    ap.add_argument(
        "--report",
        metavar="FILE",
        default=None,
        help="write the machine-readable JSON report (REPROLINT_report.json)",
    )
    ap.add_argument(
        "--format",
        choices=("text", "json"),
        default="text",
        help="stdout format (default text: path:line:col rule-id message)",
    )
    ap.add_argument(
        "--fail-on-stale",
        action="store_true",
        help="also fail when baseline entries no longer fire (they must be "
        "deleted — the baseline only shrinks)",
    )
    args = ap.parse_args(argv)

    baseline = args.baseline
    if baseline is None and not args.no_baseline and os.path.exists(DEFAULT_BASELINE):
        baseline = DEFAULT_BASELINE
    if args.no_baseline:
        baseline = None

    try:
        report = run_checks(args.paths, baseline_path=baseline)
    except (OSError, SyntaxError, ValueError) as e:
        print(f"reprolint: error: {e}", file=sys.stderr)
        return 2

    if args.write_baseline:
        dump_baseline(args.write_baseline, report.findings)
        print(
            f"reprolint: wrote {len(report.findings)} finding(s) to "
            f"{args.write_baseline}"
        )
        return 0

    if args.report:
        with open(args.report, "w", encoding="utf-8") as f:
            json.dump(report.to_dict(), f, indent=2)
            f.write("\n")

    if args.format == "json":
        json.dump(report.to_dict(), sys.stdout, indent=2)
        print()
    else:
        for f in report.new:
            print(f.format())
        for f in report.baselined:
            print(f"{f.format()} [baselined]")
        for p, r, s in report.stale_baseline:
            print(f"reprolint: stale baseline entry {p} {r} {s} — delete it")
        print(
            f"reprolint: {report.files_scanned} files, "
            f"{len(report.new)} new, {len(report.baselined)} baselined, "
            f"{len(report.suppressed)} suppressed, "
            f"{len(report.stale_baseline)} stale"
        )

    if report.new:
        return 1
    if args.fail_on_stale and report.stale_baseline:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
