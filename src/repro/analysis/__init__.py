"""reprolint — the AST-based invariant checker for this repo's contracts.

Every engine here is trusted only because of a handful of hand-enforced
contracts: RNG-stream neutrality across the scalar/vector paths,
IEEE-order float-op mirroring in the batch engines, churn-purges-
everything per-host hygiene, frozen scenario specs, and observer-routed
store mutations. PRs 1–7 each re-discovered violations of these by
debugging parity failures after the fact; reprolint checks them
mechanically, before a failure localizes them for you.

Usage::

    from repro.analysis import run_checks
    report = run_checks(["src/repro"], baseline_path="reprolint_baseline.json")
    assert report.ok, [f.format() for f in report.new]

or from the command line::

    python -m repro.analysis src/repro --baseline reprolint_baseline.json

Rules (stdlib ``ast`` only — no new runtime deps):

===============  =========================================================
rule id          contract
===============  =========================================================
rng-discipline   draws only via seeded entry points / draw caches
purge-complete   per-host containers cleared on forget_host/churn paths
parity-float     batch engines fold floats in the scalar loop's order
frozen-mut       frozen specs immutable outside __post_init__
index-bypass     tracked store-row fields never written past the observer
===============  =========================================================
"""
from .config import ALL_RULES, RULE_CONTRACTS
from .engine import run_checks
from .findings import Finding, Report, dump_baseline, load_baseline

__all__ = [
    "ALL_RULES",
    "Finding",
    "Report",
    "RULE_CONTRACTS",
    "dump_baseline",
    "load_baseline",
    "run_checks",
]
