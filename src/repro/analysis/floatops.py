"""parity-float: engine files must mirror the scalar loop's float-op order.

Scope is ``config.FLOAT_SCOPE_PATTERNS`` (the ``batch_*.py`` engines and
``world.py``) — the modules whose outputs are asserted bit-equal to a
scalar oracle. Two shapes are flagged:

  * unordered reductions: ``np.sum``/``np.mean``/``np.prod`` (and the
    ``.sum()``/``.mean()``/``.prod()`` methods, plus ``math.fsum``) use
    pairwise/compensated summation whose fold order differs from the
    scalar loop's sequential accumulation — use
    ``np.add.reduce``-style ordered folds instead;
  * raw-set iteration feeding accumulation: ``for x in {...}`` /
    ``set(...)`` / ``frozenset(...)`` with a ``+=`` in the body folds in
    hash order, which varies with insertion history — iterate
    ``sorted(...)`` (the clean twin) so the fold order is pinned.
"""
from __future__ import annotations

import ast
import fnmatch
import os
from typing import Dict, List

from . import config
from .astutil import ScopedVisitor, dotted, resolve
from .findings import Finding

_BAD_METHODS = frozenset({"sum", "mean", "prod"})


def _is_raw_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        name = dotted(node.func) or ""
        if name.split(".")[-1] in {"set", "frozenset"}:
            return True
        # x.union(...), a | b on sets are out of heuristic reach; keys()
        # views of dicts are insertion-ordered and fine.
    return False


class _FloatVisitor(ScopedVisitor):
    def __init__(self, path: str, imports: Dict[str, str]) -> None:
        super().__init__()
        self.path = path
        self.imports = imports
        self.findings: List[Finding] = []

    def _emit(self, node: ast.AST, symbol: str, what: str) -> None:
        self.findings.append(
            Finding(
                path=self.path,
                line=node.lineno,
                col=node.col_offset,
                rule=config.RULE_FLOAT,
                symbol=f"{self.qualname}:{symbol}",
                message=(
                    f"{what} — violates the contract "
                    f"({config.RULE_CONTRACTS[config.RULE_FLOAT]}). "
                    f"Use {config.FLOAT_GOOD_FORMS}, or iterate sorted(...) "
                    f"for pinned fold order. Integer-only reductions may "
                    f"suppress with '# reprolint: ignore[{config.RULE_FLOAT}]'."
                ),
            )
        )

    def visit_Call(self, node: ast.Call) -> None:
        chain = dotted(node.func)
        matched_module_form = False
        if chain is not None:
            full = resolve(chain, self.imports)
            parts = full.split(".")
            if parts[0] == "numpy" and len(parts) == 2 and parts[1] in config.FLOAT_BAD_NUMPY:
                matched_module_form = True
                self._emit(
                    node,
                    f"np.{parts[1]}",
                    f"unordered reduction np.{parts[1]} (pairwise summation; "
                    f"fold order differs from the scalar loop)",
                )
            elif full == "math.fsum":
                matched_module_form = True
                self._emit(
                    node,
                    "math.fsum",
                    "math.fsum (compensated summation; not the scalar loop's fold)",
                )
        if (
            not matched_module_form
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in _BAD_METHODS
        ):
            self._emit(
                node,
                f".{node.func.attr}()",
                f"unordered reduction .{node.func.attr}() on an array expression",
            )
        self.generic_visit(node)

    def visit_For(self, node: ast.For) -> None:
        if _is_raw_set_expr(node.iter):
            for sub in node.body:
                for inner in ast.walk(sub):
                    if isinstance(inner, ast.AugAssign) and isinstance(
                        inner.op, (ast.Add, ast.Sub, ast.Mult)
                    ):
                        self._emit(
                            node,
                            "set-iter-accum",
                            "iteration over an unordered set feeding accumulation "
                            "(hash order varies with insertion history)",
                        )
                        self.generic_visit(node)
                        return
        self.generic_visit(node)


def in_scope(path: str) -> bool:
    base = os.path.basename(path)
    return any(fnmatch.fnmatch(base, pat) for pat in config.FLOAT_SCOPE_PATTERNS)


def check(path: str, tree: ast.Module, imports: Dict[str, str]) -> List[Finding]:
    if not in_scope(path):
        return []
    v = _FloatVisitor(path, imports)
    v.visit(tree)
    return v.findings
