"""reprolint rule configuration: rule ids, whitelists, and scopes.

Every whitelist here is *policy*, not mechanism — the checkers consult
these tables so that the sanctioned escape hatches are enumerated in one
reviewable place. A finding's message names the whitelist that would have
applied, mirroring how ``run_parity`` localizes a divergence to the axis
that introduced it.
"""
from __future__ import annotations

# ---------------------------------------------------------------------------
# Rule ids (stable: baselines and suppression comments reference these)
# ---------------------------------------------------------------------------

RULE_RNG = "rng-discipline"
RULE_PURGE = "purge-complete"
RULE_FLOAT = "parity-float"
RULE_FROZEN = "frozen-mut"
RULE_BYPASS = "index-bypass"

ALL_RULES = (RULE_RNG, RULE_PURGE, RULE_FLOAT, RULE_FROZEN, RULE_BYPASS)

RULE_CONTRACTS = {
    RULE_RNG: (
        "RNG-stream neutrality: scalar and vector engines must consume "
        "identical draw sequences, so every draw goes through a seeded "
        "random.Random(seed) entry point, an ExpDrawCache-style prefetch "
        "cache, or an integer-salted scenario generator"
    ),
    RULE_PURGE: (
        "purge completeness: churn/Sybil scenarios require that every "
        "per-host keyed container is cleared by a forget_host/remove_host/"
        "purge path when the host departs"
    ),
    RULE_FLOAT: (
        "IEEE-order float-op mirroring: batch engines must fold in the "
        "scalar loop's cell order (np.add.reduce-style) — unordered "
        "reductions and raw-set iteration feeding float accumulation "
        "break bit-equality with the oracle"
    ),
    RULE_FROZEN: (
        "frozen-spec immutability: ScenarioSpec/layer dataclasses are "
        "value objects; mutation outside __post_init__ invalidates the "
        "pure (spec, seed) -> population contract"
    ),
    RULE_BYPASS: (
        "index-observer coverage: IndexObserved-tracked row fields must "
        "be written through normal attribute assignment so the store's "
        "mutation-time indexes stay honest with check_invariants"
    ),
}

# ---------------------------------------------------------------------------
# rng-discipline
# ---------------------------------------------------------------------------

#: module-level draws on the process-global stream — never reproducible
#: across engine orderings, so never allowed.
RNG_GLOBAL_DRAWS = frozenset(
    {
        "random",
        "randint",
        "randrange",
        "choice",
        "choices",
        "shuffle",
        "sample",
        "uniform",
        "triangular",
        "gauss",
        "normalvariate",
        "lognormvariate",
        "expovariate",
        "vonmisesvariate",
        "gammavariate",
        "betavariate",
        "paretovariate",
        "weibullvariate",
        "getrandbits",
        "randbytes",
        "seed",
        "setstate",
    }
)

#: numpy.random names that are seed-entry *constructors* (they build an
#: explicitly-seeded generator rather than drawing from hidden state).
#: Everything else under numpy.random — RandomState, rand, randn, seed,
#: the legacy module-level draws — is flagged.
NP_SEED_ENTRY = frozenset(
    {
        "SeedSequence",
        "default_rng",
        "Generator",
        "BitGenerator",
        "PCG64",
        "PCG64DXSM",
        "Philox",
        "MT19937",
        "SFC64",
    }
)

#: path suffixes (posix) of modules exempt from rng-discipline: the
#: sanctioned draw-cache / seed-entry modules named by the contract.
#: Empty on purpose — world.py's ExpDrawCache and scenarios.py's salted
#: generators already satisfy the rule structurally (seeded
#: random.Random(seed) construction + caller-supplied rng parameters),
#: so no module needs a blanket exemption today. Add a suffix here only
#: with a comment naming the draw-cache it hosts.
RNG_MODULE_WHITELIST: tuple = ()

# ---------------------------------------------------------------------------
# purge-complete
# ---------------------------------------------------------------------------

#: only files under these directories hold long-lived per-host server
#: state; runtime/ and models/ are per-process training code.
PURGE_SCOPE_DIRS = ("core",)

#: a container attribute counts as purged if any function/method whose
#: name matches one of these fragments references it.
PURGE_PATH_NAMES = (
    "forget_host",
    "remove_host",
    "forget_volunteer",
    "purge",
    "churn",
    "detach",
    "clear",
    "evict",
    "reset",
)

#: variable names that identify a subscript key as a host id.
HOST_KEY_NAMES = frozenset({"host_id", "hid", "hostid", "host"})

#: attribute-name fragments that mark a container as host-keyed even
#: without subscript evidence.
HOST_NAME_FRAGMENT = "host"

#: classes that are per-tick ephemerals (rebuilt from scratch every
#: engine pass): their containers die with the tick, so churn cannot
#: leak through them. Listed by class name.
PURGE_EPHEMERAL_CLASSES = frozenset(
    {
        "ValidationPlan",  # batch_validate: one transitioner tick
        "WRRResult",  # client: one WRR simulation pass
    }
)

# ---------------------------------------------------------------------------
# parity-float
# ---------------------------------------------------------------------------

#: file-name patterns (fnmatch, basename) where the engine/oracle
#: bit-equality contract applies.
FLOAT_SCOPE_PATTERNS = ("batch_*.py", "world.py")

#: unordered numpy reductions (pairwise/tree summation — order differs
#: from the scalar loop's sequential fold).
FLOAT_BAD_NUMPY = frozenset({"sum", "mean", "prod", "average", "nansum", "nanmean", "nanprod"})

#: the order-mirroring alternatives the message recommends.
FLOAT_GOOD_FORMS = "np.add.reduce / np.minimum.reduce / np.bincount-style sequential folds"

# ---------------------------------------------------------------------------
# frozen-mut
# ---------------------------------------------------------------------------

#: frozen value classes that may be defined outside the scanned path set
#: (the scanner also auto-discovers @dataclass(frozen=True) definitions
#: in the scanned files and unions them in).
KNOWN_FROZEN_CLASSES = frozenset(
    {
        "ScenarioSpec",
        "TraceReplay",
        "Outage",
        "Clique",
        "Sybil",
        "CreditFarm",
        "DefensePolicy",
        "Platform",
    }
)

# ---------------------------------------------------------------------------
# index-bypass
# ---------------------------------------------------------------------------

#: IndexObserved-tracked field names. Keep in sync with
#: ``repro.core.types.Job._TRACKED | JobInstance._TRACKED``
#: (tests/test_reprolint.py asserts this equality).
TRACKED_FIELDS = frozenset(
    {
        "state",
        "transition_flag",
        "assimilated",
        "files_deleted",
        "deadline",
        "host_id",
        "outcome",
        "validate_state",
    }
)

#: path suffixes (posix) sanctioned to bypass the observer:
#:   * core/types.py — the IndexObserved mixin itself (its __setattr__
#:     terminates the observer chain with object.__setattr__);
#:   * core/store.py — the store's fused bulk writers
#:     (clear_transition_flags / finish_jobs / set_validate_states) and
#:     the _store wiring in submit_job/create_instance/purge_job, which
#:     update the indexes inline and are covered by check_invariants.
BYPASS_MODULE_WHITELIST = ("core/types.py", "core/store.py")
