"""Deterministic, shardable synthetic data pipeline.

Jobs in the grid runtime reference data by (shard_id, step) — the BOINC
"input file" analogue — so any host can regenerate exactly the same
microbatch (locality scheduling makes shard affinity worthwhile, and
replicated instances of a step task see identical data, which is what makes
gradient replication validation meaningful).

The synthetic LM stream is a mixture of Zipfian unigrams and a copy task so
small models show a real, monotonically-decreasing loss.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    batch_size: int  # per-host microbatch
    seed: int = 0
    n_shards: int = 1
    input_mode: str = "tokens"  # tokens | embeds
    d_model: int = 0  # for embeds mode
    copy_fraction: float = 0.5  # fraction of each sequence that is copyable
    zipf_a: float = 1.2


def _rng_for(cfg: DataConfig, shard: int, step: int) -> np.random.Generator:
    # stable, collision-free stream per (seed, shard, step)
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, shard, step, 0xB01AC])
    )


def make_batch(cfg: DataConfig, shard: int, step: int) -> Dict[str, np.ndarray]:
    """Deterministic microbatch for (shard, step)."""
    rng = _rng_for(cfg, shard, step)
    b, s, v = cfg.batch_size, cfg.seq_len, cfg.vocab
    half = max(1, int(s * cfg.copy_fraction) // 2)
    # Zipfian prefix + copied suffix (learnable structure)
    ranks = rng.zipf(cfg.zipf_a, size=(b, s)).astype(np.int64)
    tokens = np.minimum(ranks, v - 1).astype(np.int32)
    tokens[:, s - half :] = tokens[:, s - 2 * half : s - half]
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = 0
    out: Dict[str, np.ndarray] = {"labels": labels}
    if cfg.input_mode == "embeds":
        emb_rng = _rng_for(cfg, shard, step + 1_000_003)
        out["embeds"] = emb_rng.standard_normal((b, s, cfg.d_model), dtype=np.float32)
    else:
        out["tokens"] = tokens
    return out


@dataclass
class DataShard:
    """Iterator view over one shard (a BOINC 'sticky file' unit)."""

    cfg: DataConfig
    shard: int
    step: int = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = make_batch(self.cfg, self.shard, self.step)
        self.step += 1
        return batch

    def shard_file_name(self) -> str:
        """The 'input file' name used for locality scheduling (§3.5)."""
        return f"data_shard_{self.cfg.seed}_{self.shard}.bin"


def global_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Concatenate all shards' microbatches (for single-process training)."""
    parts = [make_batch(cfg, sh, step) for sh in range(cfg.n_shards)]
    return {k: np.concatenate([p[k] for p in parts], axis=0) for k in parts[0]}
