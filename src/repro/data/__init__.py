from .pipeline import DataConfig, DataShard, global_batch, make_batch

__all__ = ["DataConfig", "DataShard", "global_batch", "make_batch"]
