from .pipeline import DataConfig, DataShard, global_batch, make_batch
from .traces import (
    Session,
    TraceFit,
    apply_outage,
    fit_trace,
    intervals_to_toggles,
    load_bundled_trace,
    load_trace,
    synthesize_toggles,
    toggles_to_intervals,
)

__all__ = [
    "DataConfig",
    "DataShard",
    "Session",
    "TraceFit",
    "apply_outage",
    "fit_trace",
    "global_batch",
    "intervals_to_toggles",
    "load_bundled_trace",
    "load_trace",
    "make_batch",
    "synthesize_toggles",
    "toggles_to_intervals",
]
