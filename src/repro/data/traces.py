"""Availability-trace loading, fitting, and replay synthesis.

"The Computational and Storage Potential of Volunteer Computing" measured
real volunteer host populations and found (a) heavy-tailed on/off session
lengths, (b) strong diurnal waves — hosts are online when their owners are
awake, so availability swings with local time-of-day — and (c) correlated
outages (whole sites or power regions dropping at once). The hand-written
``make_population`` model (exponential on/off with a flat rate) cannot
express any of these.

This module closes that gap for the scenario layer
(``repro.core.scenarios``):

  * :func:`load_bundled_trace` parses the small session trace shipped at
    ``host_sessions.csv`` (columns ``host, tz, start, duration``; a session
    is one contiguous online period);
  * :func:`fit_trace` fits lognormal on-session / off-gap distributions by
    log-moment matching and extracts a 24-bin diurnal profile (mean
    off-gap weight per local hour-of-day, normalized to mean 1.0);
  * :func:`synthesize_toggles` replays a fit into one host's absolute
    availability-toggle schedule — deterministic given the caller's
    ``random.Random`` — which plugs straight into
    ``HostSpec.avail_schedule`` (the simulator consumes scheduled toggles
    without touching its own RNG stream, so scalar/vector parity is
    untouched);
  * :func:`apply_outage` splices a correlated outage window (power cut,
    site failure) into a toggle schedule.

Everything here is pure: same inputs, same schedule, no module state.
"""
from __future__ import annotations

import math
import os
import random
from dataclasses import dataclass
from typing import List, NamedTuple, Optional, Sequence, Tuple

HOUR = 3600.0
DAY = 86400.0

_BUNDLED = os.path.join(os.path.dirname(__file__), "host_sessions.csv")


class Session(NamedTuple):
    """One contiguous online period of one traced host."""

    host: int
    tz: float  # timezone offset, hours
    start: float  # seconds since trace start
    duration: float  # seconds online


@dataclass(frozen=True)
class TraceFit:
    """Lognormal session model + diurnal profile fitted from a trace."""

    on_mu: float  # mean of log(on-session seconds)
    on_sigma: float
    off_mu: float  # mean of log(off-gap seconds)
    off_sigma: float
    # mean off-gap weight per local hour-of-day the gap *started* in,
    # normalized to mean 1.0 — the diurnal wave (long gaps start at night)
    diurnal: Tuple[float, ...]
    availability: float  # overall on-fraction of the trace
    n_sessions: int

    def median_on(self) -> float:
        return math.exp(self.on_mu)

    def median_off(self) -> float:
        return math.exp(self.off_mu)


def load_trace(path: str) -> List[Session]:
    """Parse a ``host,tz,start,duration`` session CSV (# comments allowed)."""
    out: List[Session] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#") or line.startswith("host,"):
                continue
            h, tz, s, d = line.split(",")
            out.append(Session(int(h), float(tz), float(s), float(d)))
    return out


def load_bundled_trace() -> List[Session]:
    """The small availability trace shipped with the repo."""
    return load_trace(_BUNDLED)


def _log_moments(xs: Sequence[float]) -> Tuple[float, float]:
    logs = [math.log(x) for x in xs if x > 0.0]
    n = len(logs)
    if n == 0:
        return 0.0, 0.0
    mu = sum(logs) / n
    var = sum((v - mu) ** 2 for v in logs) / max(n - 1, 1)
    return mu, math.sqrt(var)


def fit_trace(sessions: Sequence[Session]) -> TraceFit:
    """Fit the lognormal on/off model and the diurnal profile."""
    ons = [s.duration for s in sessions]
    offs: List[float] = []
    # per-hour off-gap sums/counts, keyed by the local hour the gap started
    hour_sum = [0.0] * 24
    hour_n = [0] * 24
    by_host: dict = {}
    for s in sessions:
        by_host.setdefault(s.host, []).append(s)
    span_on = 0.0
    span_total = 0.0
    for host_sessions in by_host.values():
        host_sessions.sort(key=lambda s: s.start)
        for a, b in zip(host_sessions, host_sessions[1:]):
            gap = b.start - (a.start + a.duration)
            if gap <= 0.0:
                continue
            offs.append(gap)
            local = ((a.start + a.duration) / HOUR + a.tz) % 24.0
            h = int(local)
            hour_sum[h] += gap
            hour_n[h] += 1
        first, last = host_sessions[0], host_sessions[-1]
        span_on += sum(s.duration for s in host_sessions)
        span_total += (last.start + last.duration) - first.start
    on_mu, on_sigma = _log_moments(ons)
    off_mu, off_sigma = _log_moments(offs)
    mean_gap = (sum(offs) / len(offs)) if offs else 1.0
    weights = [
        (hour_sum[h] / hour_n[h] / mean_gap) if hour_n[h] else 1.0
        for h in range(24)
    ]
    mean_w = sum(weights) / 24.0
    diurnal = tuple(w / mean_w for w in weights)
    return TraceFit(
        on_mu=on_mu,
        on_sigma=on_sigma,
        off_mu=off_mu,
        off_sigma=off_sigma,
        diurnal=diurnal,
        availability=span_on / span_total if span_total > 0 else 1.0,
        n_sessions=len(ons),
    )


def synthesize_toggles(
    fit: TraceFit,
    rng: random.Random,
    horizon: float,
    tz_offset: float = 0.0,
    scale: float = 1.0,
    diurnal: bool = True,
    start: float = 0.0,
    min_off: float = 60.0,
) -> Tuple[float, ...]:
    """Replay a fit into one host's absolute availability-toggle times.

    The host is online at ``start``; each returned time flips its state
    (off, on, off, ...). On-sessions and off-gaps are lognormal draws from
    the fit; with ``diurnal`` the off-gap is additionally weighted by the
    profile bin of the local hour the host went offline — the timezone
    wave. Deterministic given the ``rng`` state; draws nothing from any
    other stream.
    """
    t = start
    toggles: List[float] = []
    while True:
        on = scale * math.exp(rng.gauss(fit.on_mu, fit.on_sigma))
        t += on
        if t >= horizon:
            break
        toggles.append(t)  # -> off
        w = 1.0
        if diurnal:
            local = (t / HOUR + tz_offset) % 24.0
            w = fit.diurnal[int(local)]
        off = scale * math.exp(rng.gauss(fit.off_mu, fit.off_sigma)) * w
        t += max(off, min_off)
        if t >= horizon:
            break
        toggles.append(t)  # -> on
    return tuple(toggles)


def toggles_to_intervals(
    toggles: Sequence[float], horizon: float, start: float = 0.0
) -> List[Tuple[float, float]]:
    """Online intervals of a toggle schedule (host online at ``start``)."""
    out: List[Tuple[float, float]] = []
    t = start
    on = True
    for x in toggles:
        if on and x > t:
            out.append((t, x))
        t = x
        on = not on
    if on and horizon > t:
        out.append((t, horizon))
    return out


def intervals_to_toggles(
    intervals: Sequence[Tuple[float, float]], horizon: float
) -> Tuple[float, ...]:
    """Inverse of :func:`toggles_to_intervals`. The first interval must
    begin at 0 (the simulator registers hosts online); an end at or past
    the horizon stays on through it and emits no toggle."""
    assert intervals and intervals[0][0] == 0.0, "host must start online"
    out: List[float] = []
    for i, (a, b) in enumerate(intervals):
        if i > 0:
            out.append(a)  # off-gap ends: back on
        if b < horizon:
            out.append(b)  # session ends: go off
    return tuple(out)


def apply_outage(
    toggles: Sequence[float],
    outage_start: float,
    outage_end: float,
    horizon: float,
) -> Tuple[float, ...]:
    """Splice a forced-offline window into a toggle schedule.

    Subtracts ``[outage_start, outage_end)`` from the schedule's online
    intervals and re-derives the toggle times. ``outage_start`` must be
    positive: hosts register online at t=0 and the simulator has no
    start-offline representation.
    """
    assert 0.0 < outage_start < outage_end, "outage must start after t=0"
    clipped: List[Tuple[float, float]] = []
    for a, b in toggles_to_intervals(toggles, horizon):
        if b <= outage_start or a >= outage_end:
            clipped.append((a, b))
            continue
        if a < outage_start:
            clipped.append((a, outage_start))
        if b > outage_end:
            clipped.append((outage_end, b))
    if not clipped or clipped[0][0] != 0.0:
        # the host was (or is now) offline from t=0 — unrepresentable;
        # keep it online for a vanishing first instant instead
        eps = min(1.0, outage_start / 2.0)
        clipped.insert(0, (0.0, eps))
    return intervals_to_toggles(clipped, horizon)
