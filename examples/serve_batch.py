"""Batched serving with EDF admission: continuous batching over a shared KV
cache, requests admitted earliest-deadline-first (§10.7's low-latency
direction implemented as a working basic version).

    PYTHONPATH=src python examples/serve_batch.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params, model_spec
from repro.runtime import BatchServer, Request


def main() -> None:
    cfg = get_smoke_config("qwen3-0.6b")
    params = init_params(jax.random.PRNGKey(0), model_spec(cfg))
    server = BatchServer(cfg, params, batch_slots=4, max_seq=128)

    rng = np.random.default_rng(0)
    for i in range(12):
        server.submit(
            Request(
                id=i,
                prompt=rng.integers(0, cfg.vocab, size=int(rng.integers(4, 24))).astype(np.int32),
                max_new_tokens=12,
                deadline=float(rng.integers(1, 100)),  # EDF admission order
            )
        )
    metrics = server.run()
    print(f"requests served:   {metrics.requests_done}")
    print(f"tokens generated:  {metrics.tokens_generated}")
    print(f"decode steps:      {metrics.decode_steps} (batched x{server.slots})")
    print(f"throughput:        {metrics.tokens_per_s:.1f} tok/s (CPU)")
    print(f"mean latency:      {metrics.mean_latency:.2f} s")


if __name__ == "__main__":
    main()
