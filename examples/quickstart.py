"""Quickstart: create a BOINC project, submit jobs, run the volunteer grid.

Builds a project server with replication validation, a 20-host heterogeneous
volunteer population (5% flaky, 10% malicious), streams 200 jobs through the
EmBOINC-style virtual-time simulator, and prints the ledger — everything the
paper's middleware does, in ~30 lines of API.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (
    App,
    AppVersion,
    GridSimulation,
    Job,
    Platform,
    ProjectServer,
    default_cpu_plan_class,
    fuzzy_comparator,
    make_population,
    next_id,
    reset_ids,
)


def main() -> None:
    reset_ids()
    server = ProjectServer(name="quickstart", purge_delay=1e18)

    app = App(
        name="simulate",
        min_quorum=2,  # replication-based validation (§3.4)
        init_ninstances=2,
        delay_bound=6 * 3600.0,  # straggler re-dispatch deadline (§4)
        adaptive_replication=True,  # reputation lowers overhead toward 1x
        comparator=fuzzy_comparator(rtol=1e-6, atol=1e-9),
    )
    for osn in ("windows", "mac", "linux"):
        app.add_version(
            AppVersion(
                id=next_id("appver"),
                app_name="simulate",
                platform=Platform(osn, "x86_64"),
                version_num=1,
                plan_class=default_cpu_plan_class(),
            )
        )
    server.add_app(app)

    for _ in range(200):
        server.submit_job(
            Job(id=next_id("job"), app_name="simulate", est_flop_count=0.25 * 3600 * 16.5e9)
        )

    population = make_population(
        20, seed=1, availability=0.8, error_prob=0.05, malicious_fraction=0.1
    )
    sim = GridSimulation(server, population, seed=7)
    metrics = sim.run(horizon=3 * 86400.0)
    sim.audit_validation()

    counts = server.counts()
    print(f"jobs completed:        {counts['jobs_success']}/200")
    print(f"instances executed:    {metrics.instances_executed}")
    print(f"replication overhead:  {metrics.replication_overhead:.2f}x")
    print(f"corrupt results accepted: {metrics.wrong_accepted} (validation caught the rest)")
    print(f"RPCs handled:          {metrics.rpcs}")
    top = sorted(
        ((k, v) for k, v in server.credit.total.items() if k.startswith("host:")),
        key=lambda kv: -kv[1],
    )[:3]
    print("top credited hosts:    " + ", ".join(f"{k}={v:.2f}" for k, v in top))


if __name__ == "__main__":
    main()
