"""End-to-end driver: train a language model on the volunteer grid.

Each optimizer step is decomposed into per-shard gradient jobs dispatched
through the real BOINC scheduler to emulated hosts (virtual time, REAL JAX
gradients). Hosts are unreliable: 5% flaky results, 10% malicious, 85%
availability, permanent churn — the validator's gradient quorum keeps every
accepted update correct, deadlines re-dispatch stragglers, and the credit
system doubles as the FLOPs ledger.

Default config trains a ~1M-param Qwen3-style model for 60 steps in a few
minutes on CPU; pass ``--full`` for a ~100M-param run (hours on CPU — sized
for a real machine).

    PYTHONPATH=src python examples/train_volunteer_grid.py [--steps N] [--full]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import get_config, get_smoke_config
from repro.core import reset_ids
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.runtime import GridTrainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--hosts", type=int, default=10)
    ap.add_argument("--full", action="store_true",
                    help="~100M-param config (slow on CPU)")
    args = ap.parse_args()

    reset_ids()
    if args.full:
        # ~100M params: qwen3-family, 12 layers, d=512
        cfg = get_config("qwen3-0.6b").scaled(
            name="qwen3-100m", n_layers=12, d_model=512, n_heads=8,
            n_kv_heads=4, head_dim=64, d_ff=1536, remat=False,
        )
        data = DataConfig(vocab=cfg.vocab, seq_len=256, batch_size=8, n_shards=4, seed=0)
    else:
        cfg = get_smoke_config("qwen3-0.6b").scaled(n_layers=4, d_model=128, d_ff=384)
        data = DataConfig(vocab=cfg.vocab, seq_len=128, batch_size=8, n_shards=2, seed=0)

    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.1f}M  "
          f"tokens/step={data.batch_size * data.seq_len * data.n_shards}")

    opt = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps, schedule="cosine")
    trainer = GridTrainer(
        cfg, data, opt,
        n_steps=args.steps,
        n_hosts=args.hosts,
        seed=0,
        adaptive_replication=True,
        error_prob=0.05,
        malicious_fraction=0.10,
        availability=0.85,
        churn_rate=1.0 / (30 * 86400.0),
    )
    result = trainer.run()

    print(f"\nsteps completed:      {result.steps_completed}/{args.steps}")
    print(f"loss:                 {result.losses[0]:.4f} -> {result.final_loss:.4f}")
    print(f"virtual time:         {result.virtual_time/3600.0:.1f} h")
    print(f"instances executed:   {result.metrics.instances_executed}")
    print(f"replication overhead: {result.metrics.replication_overhead:.2f}x")
    print(f"corrupt grads accepted: {result.metrics.wrong_accepted}"
          "  (adaptive replication trades a bounded error rate for ~1x overhead, §3.4;"
          " set adaptive_replication=False for quorum-2 on every job -> zero)")
    print(f"straggler retries:    {result.jobs_retried}")
    n = 5
    tail = ", ".join(f"{l:.3f}" for l in result.losses[-n:])
    print(f"last {n} losses:        {tail}")


if __name__ == "__main__":
    main()
