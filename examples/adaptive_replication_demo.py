"""Adaptive replication (§3.4) in action: replication overhead decays
toward 1x as hosts build reputation, while malicious hosts — whose
reputation resets on every caught result — stay fully replicated and never
sneak a wrong result in.

    PYTHONPATH=src python examples/adaptive_replication_demo.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (
    App,
    AppVersion,
    GridSimulation,
    Job,
    Platform,
    ProjectServer,
    default_cpu_plan_class,
    fuzzy_comparator,
    make_population,
    next_id,
    reset_ids,
)


def main() -> None:
    reset_ids()
    server = ProjectServer(name="demo", purge_delay=1e18)
    app = App(
        name="w",
        min_quorum=2,
        init_ninstances=2,
        delay_bound=6 * 3600.0,
        adaptive_replication=True,
        comparator=fuzzy_comparator(rtol=1e-6, atol=1e-9),
    )
    for osn in ("windows", "mac", "linux"):
        app.add_version(
            AppVersion(
                id=next_id("appver"), app_name="w",
                platform=Platform(osn, "x86_64"), version_num=1,
                plan_class=default_cpu_plan_class(),
            )
        )
    server.add_app(app)

    pop = make_population(30, seed=1, availability=1.0, malicious_fraction=0.1)
    sim = GridSimulation(server, pop, seed=7)

    def wave(now):
        for _ in range(100):
            server.submit_job(
                Job(id=next_id("job"), app_name="w", est_flop_count=0.25 * 3600 * 16.5e9),
                now,
            )

    horizon = 14 * 86400.0
    t = 0.0
    while t < horizon:
        sim.schedule_callback(t, wave)
        t += 6 * 3600.0

    # sample the overhead trajectory day by day
    print("day  jobs_done  overhead  reputation(median)  wrong_accepted")
    day = 86400.0
    done_prev = exec_prev = 0
    for d in range(1, 15):
        sim.run(d * day)
        sim.metrics.correct_accepted = sim.metrics.wrong_accepted = 0
        sim.audit_validation()
        done = sim.metrics.correct_accepted + sim.metrics.wrong_accepted
        execd = sim.metrics.instances_executed
        d_done = done - done_prev
        d_exec = execd - exec_prev
        overhead = d_exec / d_done if d_done else float("nan")
        reps = sorted(server.adaptive.consecutive_valid.values())
        med = reps[len(reps) // 2] if reps else 0
        print(f"{d:3d}  {d_done:9d}  {overhead:8.2f}  {med:18d}  {sim.metrics.wrong_accepted}")
        done_prev, exec_prev = done, execd

    # who is still being watched? malicious hosts hold zero reputation
    mal = {s.host.id for s in sim.specs.values() if s.malicious}
    held = {hid: n for (hid, _), n in server.adaptive.consecutive_valid.items() if n > 10}
    caught = [h for h in mal if h not in held]
    print(f"\nmalicious hosts: {sorted(mal)}; with reputation >10: {sorted(set(held) & mal)}")
    print(f"validation caught every malicious host: {len(caught) == len(mal)}")


if __name__ == "__main__":
    main()
