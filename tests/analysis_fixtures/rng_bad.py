"""rng-discipline true positives: every draw shape the rule must catch."""
import random

import numpy as np


def jitter(x: float) -> float:
    return x * random.uniform(0.9, 1.1)  # global-stream draw


def pick(items):
    return random.choice(items)  # global-stream draw


def make_rng():
    return random.Random()  # unseeded construction


def legacy_table(n: int):
    rs = np.random.RandomState(7)  # legacy hidden-state RNG
    return rs.rand(n)


def entropy_rng():
    return np.random.default_rng()  # unseeded default_rng
