"""index-bypass clean twin: observed assignment and untracked-field bypasses."""


def set_state(inst) -> None:
    inst.state = 2  # normal assignment: routed through IndexObserved


def set_untracked(inst) -> None:
    # untracked fields carry no index obligations; the fast path is fine
    inst.__dict__["claimed_credit"] = 0.5
    object.__setattr__(inst, "_store", None)
