"""purge-complete true positives: host-keyed containers with no purge path."""
from dataclasses import dataclass, field
from typing import Dict, Tuple


@dataclass
class LeakyTracker:
    """Both detection heuristics fire: name fragment and host-id subscript."""

    host_scores: Dict[int, float] = field(default_factory=dict)  # name says host
    latencies: Dict[int, list] = field(default_factory=dict)  # subscript says host

    def record(self, host_id: int, score: float, ms: float) -> None:
        self.host_scores[host_id] = score
        self.latencies.setdefault(host_id, []).append(ms)


class LeakyInitStyle:
    """Containers declared in __init__, cleared nowhere."""

    def __init__(self) -> None:
        self.by_host: Dict[int, int] = {}

    def bump(self, hid: int) -> None:
        self.by_host[hid] = self.by_host.get(hid, 0) + 1


@dataclass
class HalfPurged:
    """Has a forget_host — but it only clears one of the two containers."""

    host_state: Dict[int, float] = field(default_factory=dict)
    host_extra: Dict[Tuple[int, int], float] = field(default_factory=dict)

    def forget_host(self, host_id: int) -> None:
        self.host_state.pop(host_id, None)
        # host_extra deliberately forgotten: the rule must still flag it
