"""purge-complete clean twin: every host-keyed container has a purge path."""
from dataclasses import dataclass, field
from typing import Dict


@dataclass
class TidyTracker:
    host_scores: Dict[int, float] = field(default_factory=dict)
    latencies: Dict[int, list] = field(default_factory=dict)
    jobs_by_id: Dict[int, object] = field(default_factory=dict)  # job-keyed: out of scope

    def record(self, host_id: int, score: float, ms: float) -> None:
        self.host_scores[host_id] = score
        self.latencies.setdefault(host_id, []).append(ms)

    def forget_host(self, host_id: int) -> None:
        self.host_scores.pop(host_id, None)
        self.latencies.pop(host_id, None)


class TidyChurnStyle:
    """Cleared through a churn-named path instead of forget_host."""

    def __init__(self) -> None:
        self.by_host: Dict[int, int] = {}

    def bump(self, hid: int) -> None:
        self.by_host[hid] = self.by_host.get(hid, 0) + 1

    def _churn(self, hid: int) -> None:
        self.by_host.pop(hid, None)


@dataclass
class TickPlan:
    """Per-tick ephemeral by whitelist membership would be one way out;
    this one is simply not host-keyed (seq-keyed), so it never fires."""

    callbacks: Dict[int, object] = field(default_factory=dict)

    def pop(self, seq: int):
        return self.callbacks.pop(seq, None)
