"""parity-float true positives (file named batch_*.py to enter scope)."""
import math

import numpy as np


def total_runtime(col: np.ndarray) -> float:
    return float(np.sum(col))  # pairwise summation, not the scalar fold


def mean_credit(col: np.ndarray) -> float:
    return float(col.mean())  # method form of the same unordered reduction


def product_term(col: np.ndarray) -> float:
    return float(np.prod(col))


def compensated(xs) -> float:
    return math.fsum(xs)  # compensated summation: not the oracle's fold


def accumulate_over_hosts(host_ids, table) -> float:
    acc = 0.0
    for hid in set(host_ids):  # hash order feeds a float fold
        acc += table[hid]
    return acc
