"""parity-float clean twin: order-mirroring folds (file in batch_* scope)."""
import numpy as np


def total_runtime(col: np.ndarray) -> float:
    return float(np.add.reduce(col))  # sequential fold, scalar-loop order


def min_deadline(col: np.ndarray) -> float:
    return float(np.minimum.reduce(col))


def counts(rows: np.ndarray, n: int) -> np.ndarray:
    return np.bincount(rows, minlength=n)


def accumulate_over_hosts(host_ids, table) -> float:
    acc = 0.0
    for hid in sorted(set(host_ids)):  # sorted(): fold order pinned
        acc += table[hid]
    return acc
