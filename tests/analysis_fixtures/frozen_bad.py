"""frozen-mut true positives: mutation of frozen specs outside __post_init__."""
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class LocalSpec:
    seed: int
    n_hosts: int = 10
    derived: Tuple[int, ...] = field(default=())

    def __post_init__(self) -> None:
        # sanctioned: construction-time derivation
        object.__setattr__(self, "derived", (self.seed, self.n_hosts))

    def rescale(self, k: int) -> None:
        object.__setattr__(self, "n_hosts", self.n_hosts * k)  # mutation!


def tweak_local(spec: LocalSpec) -> None:
    spec.n_hosts = 99  # would raise FrozenInstanceError; lint catches it first


def tweak_known(spec: "ScenarioSpec") -> None:
    # ScenarioSpec comes from config.KNOWN_FROZEN_CLASSES, not this file
    spec.seed = 1


def force_known(spec: "ScenarioSpec") -> None:
    object.__setattr__(spec, "seed", 2)
