"""index-bypass true positives: tracked-field writes that skip the observer."""


def sneak_state(inst) -> None:
    object.__setattr__(inst, "state", 2)  # tracked field, observer skipped


def sneak_dict(inst) -> None:
    inst.__dict__["validate_state"] = 1  # tracked field via __dict__


def sneak_update(inst) -> None:
    inst.__dict__.update({"outcome": 3, "claimed_credit": 0.5})  # outcome tracked
