"""rng-discipline clean twin: the sanctioned seed-entry forms."""
import random

import numpy as np


def make_seeded(seed: int) -> random.Random:
    return random.Random(seed)  # seeded entry point


def make_salted(seed: int, index: int) -> random.Random:
    # integer-arithmetic salt, scenario-generation style
    return random.Random(seed * 2_654_435_761 + 97 * index + 13)


def draw_from(rng: random.Random) -> float:
    # drawing from a caller-supplied rng object is always fine: the
    # object's provenance is what the seed-entry rule pins down
    return rng.uniform(0.9, 1.1)


def make_np(seed: int):
    return np.random.default_rng(np.random.SeedSequence([seed, 0xB01AC]))
