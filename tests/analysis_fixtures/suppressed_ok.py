"""Inline-suppression fixture: each finding is silenced on its own line."""
import random

import numpy as np


def fixed_table(n: int):
    rs = np.random.RandomState(7)  # reprolint: ignore[rng-discipline]
    return rs.rand(n)


def any_rule_jitter(x: float) -> float:
    return x * random.uniform(0.9, 1.1)  # reprolint: ignore


def unsuppressed_draw():
    return random.random()  # the one finding this file must still produce
