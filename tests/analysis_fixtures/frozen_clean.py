"""frozen-mut clean twin: replace() derivation and __post_init__ writes."""
import dataclasses
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class LocalSpec:
    seed: int
    n_hosts: int = 10
    derived: Tuple[int, ...] = field(default=())

    def __post_init__(self) -> None:
        object.__setattr__(self, "derived", (self.seed, self.n_hosts))


@dataclass
class MutableConfig:
    """Not frozen: plain mutation is fine."""

    retries: int = 3

    def bump(self) -> None:
        self.retries += 1


def rescaled(spec: LocalSpec, k: int) -> LocalSpec:
    return dataclasses.replace(spec, n_hosts=spec.n_hosts * k)


def mutate_unannotated(thing) -> None:
    thing.n_hosts = 99  # no frozen annotation: out of the rule's reach
