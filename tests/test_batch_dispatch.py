"""Batch/scalar dispatch parity (§5.1, §6.4).

``Scheduler.handle_batch`` is specified to be *result-identical* to N
sequential ``handle_request`` calls on the same store snapshot: same RNG
consumption, same (job, host) assignments in the same order, same metrics,
and same slot bookkeeping (taken flags, skipped counts, HR-class and
homogeneous-app-version locks). These tests build two identical servers,
drive one scalar and one batched, and compare exhaustively — including
HR-class locking within a batch, deadline-infeasible jobs, keyword vetoes,
disk rejects, the one-instance-per-volunteer slow check, and a second round
with completed-result reporting (which mutates the estimator and the
allocation balances between requests).
"""
import random

import pytest

from repro.core import (
    App,
    AppVersion,
    BatchDispatchEngine,
    CompletedResult,
    HRLevel,
    Host,
    InstanceOutcome,
    InstanceState,
    Job,
    KeywordPrefs,
    Platform,
    ProcessingResource,
    ProjectServer,
    ResourceRequest,
    ResourceType,
    ScheduleRequest,
    default_cpu_plan_class,
    next_id,
    reset_ids,
)
from repro.core.simulator import GridSimulation, make_population

OSES = ("windows", "mac", "linux")


def _make_server(seed: int, n_jobs: int = 150, n_hosts: int = 24, cache_size: int = 96):
    """A server with a feature-dense workload: plain, HR-locked,
    homogeneous-app-version, keyworded, locality, multi-size, targeted,
    pinned, deadline-tight, and disk-heavy jobs across several submitters,
    with a GPU-capable app version on a subset of hosts."""
    reset_ids()
    rng = random.Random(seed)
    server = ProjectServer(name="p", cache_size=cache_size)

    plain = App(name="plain", min_quorum=1, init_ninstances=1)
    hr = App(name="hr", min_quorum=2, init_ninstances=2, hr_level=HRLevel.FINE)
    kw = App(name="kw", min_quorum=1, init_ninstances=1, keywords=("physics",))
    hav = App(name="hav", min_quorum=2, init_ninstances=2, homogeneous_app_version=True)
    loc = App(name="loc", min_quorum=1, init_ninstances=1, uses_locality=True)
    ms = App(name="ms", min_quorum=1, init_ninstances=1, multi_size=True, n_size_classes=3)
    for app in (plain, hr, kw, hav, loc, ms):
        for osn in OSES:
            app.add_version(
                AppVersion(
                    id=next_id("appver"),
                    app_name=app.name,
                    platform=Platform(osn, "x86_64"),
                    version_num=1,
                    plan_class=default_cpu_plan_class(),
                )
            )
        server.add_app(app)
    # GPU build of the plain app (§3.1 plan classes; §6.4 GPUs handled first)
    from repro.core import gpu_plan_class

    for osn in OSES:
        server.store.add_app_version(
            AppVersion(
                id=next_id("appver"),
                app_name="plain",
                platform=Platform(osn, "x86_64"),
                version_num=1,
                plan_class=gpu_plan_class(),
            )
        )

    app_mix = ("plain", "plain", "hr", "kw", "hav", "loc", "ms")
    for i in range(n_jobs):
        app_name = rng.choice(app_mix)
        delay = rng.choice((6 * 3600.0, 6 * 3600.0, 6 * 3600.0, 1e-3))  # some infeasible
        disk = rng.choice((0.0, 0.0, 1e9, 1e15))  # some exceed host disk
        keywords = ("astrophysics",) if app_name == "kw" and rng.random() < 0.5 else ()
        input_files = (
            tuple(f"f{rng.randrange(6)}.dat" for _ in range(2)) if app_name == "loc" else ()
        )
        server.submit_job(
            Job(
                id=next_id("job"),
                app_name=app_name,
                est_flop_count=rng.uniform(1e12, 2e13),
                delay_bound=delay,
                disk_bytes=disk,
                priority=rng.random() * 3.0,
                keywords=keywords,
                input_files=input_files,
                size_class=rng.randrange(3) if app_name == "ms" else 0,
                target_host=rng.randrange(1, n_hosts + 1) if rng.random() < 0.1 else None,
                pinned_version_num=rng.choice((1, 2)) if rng.random() < 0.1 else None,
                submitter=rng.choice(("alice", "bob", "carol")),
            ),
            0.0,
        )

    hosts = []
    for i in range(n_hosts):
        resources = {
            ResourceType.CPU: ProcessingResource(
                ResourceType.CPU, 4, rng.uniform(5e9, 4e10)
            )
        }
        if rng.random() < 0.3:
            resources[ResourceType.GPU] = ProcessingResource(
                ResourceType.GPU, 1, rng.uniform(1e11, 1e12)
            )
        h = Host(
            id=i + 1,
            platforms=(Platform(rng.choice(OSES), "x86_64"),),
            resources=resources,
            cpu_vendor=rng.choice(("genuineintel", "authenticamd")),
            cpu_model=f"model{rng.randrange(3)}",
            disk_free_bytes=1e12,
            volunteer_id=(i % (n_hosts // 2)) + 1,  # pairs share a volunteer
        )
        server.add_host(h)
        hosts.append(h)
    server.tick(0.0)
    return server, hosts


def _make_requests(hosts, seed: int):
    rng = random.Random(seed + 1000)
    reqs = []
    for h in hosts:
        prefs = KeywordPrefs.make(
            yes=("physics",) if rng.random() < 0.3 else (),
            no=("astrophysics",) if rng.random() < 0.2 else (),
        )
        requests = {
            ResourceType.CPU: ResourceRequest(
                req_runtime=rng.choice((500.0, 3000.0, 20000.0)), req_idle=1
            )
        }
        if ResourceType.GPU in h.resources:
            requests[ResourceType.GPU] = ResourceRequest(req_runtime=1000.0, req_idle=1)
        sticky = tuple(f"f{rng.randrange(6)}.dat" for _ in range(rng.randrange(3)))
        reqs.append(
            ScheduleRequest(
                host_id=h.id,
                requests=requests,
                usable_disk=h.disk_free_bytes,
                keyword_prefs=prefs,
                sticky_files=sticky,
            )
        )
    # edge requests: unknown host, over disk limit
    reqs.append(
        ScheduleRequest(
            host_id=10_000,
            requests={ResourceType.CPU: ResourceRequest(req_runtime=100.0)},
        )
    )
    reqs.append(
        ScheduleRequest(
            host_id=hosts[0].id,
            requests={ResourceType.CPU: ResourceRequest(req_runtime=100.0)},
            usable_disk=-1.0,
            sticky_files=("old.dat",),
        )
    )
    return reqs


def _reply_sig(replies):
    return [
        (
            r.request_delay,
            tuple(r.delete_sticky),
            tuple(
                (d.job.id, d.instance.id, d.version.id, d.est_flops, d.est_runtime)
                for d in r.jobs
            ),
        )
        for r in replies
    ]


def _store_sig(server):
    inst = tuple(
        (i.id, i.state.value, i.host_id, i.app_version_id, i.sent_time, i.deadline)
        for i in sorted(server.store.instances.values(), key=lambda x: x.id)
    )
    jobs = tuple(
        (j.id, j.hr_class, j.hav_version_id, j.min_quorum, j.transition_flag)
        for j in sorted(server.store.jobs.values(), key=lambda x: x.id)
    )
    slots = tuple(
        (s.instance_id, s.taken, s.skipped) if s is not None else None
        for s in server.feeder.slots
    )
    return inst, jobs, slots


def _completions_from(replies, rng):
    """Deterministic completed-result reports for a subset of dispatches."""
    out = {}
    for reply in replies:
        for d in reply.jobs:
            if rng.random() < 0.5:
                host_id = d.instance.host_id
                out.setdefault(host_id, []).append(
                    CompletedResult(
                        instance_id=d.instance.id,
                        outcome=InstanceOutcome.SUCCESS,
                        runtime=d.est_runtime * 1.1,
                        peak_flop_count=d.job.est_flop_count,
                        output=1.0,
                    )
                )
    return out


@pytest.mark.parametrize("seed", range(6))
def test_batch_matches_sequential_scalar(seed):
    """Property: handle_batch == N sequential handle_request, over randomized
    feature-dense workloads, for two rounds (the second carries completed
    results, so estimator and allocator state mutates mid-batch)."""
    server_a, hosts_a = _make_server(seed)
    server_b, hosts_b = _make_server(seed)
    sched_a = server_a.schedulers[0]
    sched_b = server_b.schedulers[0]

    reqs_a = _make_requests(hosts_a, seed)
    reqs_b = _make_requests(hosts_b, seed)
    replies_a = [sched_a.handle_request(r, 10.0) for r in reqs_a]
    replies_b = sched_b.handle_batch(reqs_b, 10.0)
    assert _reply_sig(replies_a) == _reply_sig(replies_b)
    assert sched_a.metrics == sched_b.metrics
    assert _store_sig(server_a) == _store_sig(server_b)

    # round 2: refill the cache, report some completions inline
    server_a.tick(600.0)
    server_b.tick(600.0)
    comp_a = _completions_from(replies_a, random.Random(seed + 5))
    comp_b = _completions_from(replies_b, random.Random(seed + 5))
    reqs_a2 = _make_requests(hosts_a, seed + 77)
    reqs_b2 = _make_requests(hosts_b, seed + 77)
    for r in reqs_a2:
        r.completed = comp_a.get(r.host_id, [])
    for r in reqs_b2:
        r.completed = comp_b.get(r.host_id, [])
    replies_a2 = [sched_a.handle_request(r, 1200.0) for r in reqs_a2]
    replies_b2 = sched_b.handle_batch(reqs_b2, 1200.0)
    assert _reply_sig(replies_a2) == _reply_sig(replies_b2)
    assert sched_a.metrics == sched_b.metrics
    assert _store_sig(server_a) == _store_sig(server_b)


def test_candidate_list_matches_engine_ordering():
    """The engine's vectorized per-host scoring must reproduce the scalar
    cache scan exactly: same candidates, same stable descending order, same
    scores."""
    server, hosts = _make_server(3)
    sched = server.schedulers[0]
    engine = BatchDispatchEngine(server.store, server.feeder)
    for host in hosts[:8]:
        req = ScheduleRequest(
            host_id=host.id,
            requests={ResourceType.CPU: ResourceRequest(req_runtime=1000.0)},
            keyword_prefs=KeywordPrefs.make(yes=("physics",)),
        )
        state = sched._rng.getstate()
        scalar = sched._candidate_list(host, req, ResourceType.CPU, 5.0)
        sched._rng.setstate(state)
        start = sched._rng.randrange(len(server.feeder.slots))
        vec = list(engine.candidates(sched, host, req, ResourceType.CPU, start, 5.0))
        assert [(c.score, c.slot.instance_id, c.job.id, c.version.id) for c in scalar] == [
            (c.score, c.slot.instance_id, c.job.id, c.version.id) for c in vec
        ]
        # est_rt precomputed by the engine must equal the scalar tail's value
        for c in vec:
            assert c.est_rt == sched.estimator.est_runtime(c.job, host, c.version)


def test_hr_class_locks_within_batch():
    """First dispatch of an HR job locks its equivalence class (§3.4); a
    later request in the same batch from a different class must not receive
    the job's second instance — and the scalar path must agree."""
    def build():
        reset_ids()
        server = ProjectServer(name="p", cache_size=16)
        app = App(name="hr", min_quorum=2, init_ninstances=2, hr_level=HRLevel.FINE)
        for osn in OSES:
            app.add_version(
                AppVersion(
                    id=next_id("appver"),
                    app_name="hr",
                    platform=Platform(osn, "x86_64"),
                    version_num=1,
                    plan_class=default_cpu_plan_class(),
                )
            )
        server.add_app(app)
        server.submit_job(
            Job(id=next_id("job"), app_name="hr", est_flop_count=1e12), 0.0
        )
        specs = [
            ("windows", "genuineintel", "m0", 1),
            ("windows", "authenticamd", "m1", 2),  # different HR class
            ("windows", "genuineintel", "m0", 1),  # same volunteer as host 1
            ("windows", "genuineintel", "m0", 3),  # same class, new volunteer
        ]
        hosts = []
        for osn, vendor, model, vid in specs:
            h = Host(
                id=len(hosts) + 1,
                platforms=(Platform(osn, "x86_64"),),
                resources={
                    ResourceType.CPU: ProcessingResource(ResourceType.CPU, 4, 2e10)
                },
                cpu_vendor=vendor,
                cpu_model=model,
                volunteer_id=vid,
            )
            server.add_host(h)
            hosts.append(h)
        server.tick(0.0)
        return server, hosts

    def reqs_for(hosts):
        return [
            ScheduleRequest(
                host_id=h.id,
                requests={ResourceType.CPU: ResourceRequest(req_runtime=1e5, req_idle=4)},
            )
            for h in hosts
        ]

    server_a, hosts_a = build()
    server_b, hosts_b = build()
    replies_a = [server_a.schedulers[0].handle_request(r, 0.0) for r in reqs_for(hosts_a)]
    replies_b = server_b.schedulers[0].handle_batch(reqs_for(hosts_b), 0.0)
    assert _reply_sig(replies_a) == _reply_sig(replies_b)
    got = [h for h, r in zip((1, 2, 3, 4), replies_b) if r.jobs]
    # host 2: HR-class mismatch; host 3: one-instance-per-volunteer slow check
    assert got == [1, 4]
    assert server_b.schedulers[0].metrics.slow_check_rejects >= 1
    assert server_a.schedulers[0].metrics == server_b.schedulers[0].metrics
    assert _store_sig(server_a) == _store_sig(server_b)


def test_deadline_infeasible_never_dispatched():
    """§6.4 fast check b: jobs whose scaled runtime exceeds the delay bound
    are skipped by both paths, and the skip bumps match."""
    def build():
        reset_ids()
        server = ProjectServer(name="p", cache_size=8)
        app = App(name="a", min_quorum=1, init_ninstances=1)
        app.add_version(
            AppVersion(
                id=next_id("appver"),
                app_name="a",
                platform=Platform("linux", "x86_64"),
                version_num=1,
                plan_class=default_cpu_plan_class(),
            )
        )
        server.add_app(app)
        server.submit_job(
            Job(id=next_id("job"), app_name="a", est_flop_count=1e14, delay_bound=1.0),
            0.0,
        )
        h = Host(
            id=1,
            platforms=(Platform("linux", "x86_64"),),
            resources={ResourceType.CPU: ProcessingResource(ResourceType.CPU, 4, 1e9)},
            volunteer_id=1,
        )
        server.add_host(h)
        server.tick(0.0)
        return server

    server_a, server_b = build(), build()
    req = lambda: ScheduleRequest(  # noqa: E731
        host_id=1, requests={ResourceType.CPU: ResourceRequest(req_runtime=100.0)}
    )
    ra = server_a.schedulers[0].handle_request(req(), 0.0)
    (rb,) = server_b.schedulers[0].handle_batch([req()], 0.0)
    assert ra.jobs == [] and rb.jobs == []
    assert server_a.schedulers[0].metrics.fast_check_rejects == 1
    assert server_a.schedulers[0].metrics == server_b.schedulers[0].metrics
    assert _store_sig(server_a) == _store_sig(server_b)


def test_batch_empty_cache_and_unknown_host():
    reset_ids()
    server = ProjectServer(name="p", cache_size=4)
    app = App(name="a", min_quorum=1)
    app.add_version(
        AppVersion(
            id=next_id("appver"),
            app_name="a",
            platform=Platform("linux", "x86_64"),
            version_num=1,
            plan_class=default_cpu_plan_class(),
        )
    )
    server.add_app(app)
    replies = server.schedulers[0].handle_batch(
        [
            ScheduleRequest(
                host_id=99,
                requests={ResourceType.CPU: ResourceRequest(req_runtime=10.0)},
            )
        ],
        0.0,
    )
    assert replies[0].request_delay == 3600.0 and replies[0].jobs == []


def test_server_rpc_batch_matches_sequential_rpc():
    """ProjectServer.rpc_batch == sequential ProjectServer.rpc (single
    scheduler instance), including trickle handling."""
    server_a, hosts_a = _make_server(11)
    server_b, hosts_b = _make_server(11)
    reqs_a = _make_requests(hosts_a, 11)
    reqs_b = _make_requests(hosts_b, 11)
    replies_a = [server_a.rpc(r, 2.0) for r in reqs_a]
    replies_b = server_b.rpc_batch(reqs_b, 2.0)
    assert _reply_sig(replies_a) == _reply_sig(replies_b)
    assert _store_sig(server_a) == _store_sig(server_b)


def test_rpc_batch_multi_scheduler_falls_back_to_sequential():
    """With >1 scheduler instance and sharded dispatch opted out, the
    sequential path round-robins across distinct RNG streams; rpc_batch must
    preserve that identity by falling back to per-request dispatch.  (With
    sharding enabled — the default for multi-instance servers — rpc_batch
    instead routes by host affinity; see tests/test_shard_dispatch.py.)"""
    def build():
        reset_ids()
        server = ProjectServer(
            name="p", cache_size=32, n_scheduler_instances=3, sharded_dispatch=False
        )
        app = App(name="a", min_quorum=1, init_ninstances=1)
        for osn in OSES:
            app.add_version(
                AppVersion(
                    id=next_id("appver"),
                    app_name="a",
                    platform=Platform(osn, "x86_64"),
                    version_num=1,
                    plan_class=default_cpu_plan_class(),
                )
            )
        server.add_app(app)
        for i in range(40):
            server.submit_job(
                Job(id=next_id("job"), app_name="a", est_flop_count=1e12), 0.0
            )
        hosts = []
        for i in range(9):
            h = Host(
                id=i + 1,
                platforms=(Platform(OSES[i % 3], "x86_64"),),
                resources={
                    ResourceType.CPU: ProcessingResource(ResourceType.CPU, 4, 2e10)
                },
                volunteer_id=i + 1,
            )
            server.add_host(h)
            hosts.append(h)
        server.tick(0.0)
        return server, hosts

    def reqs_for(hosts):
        return [
            ScheduleRequest(
                host_id=h.id,
                requests={ResourceType.CPU: ResourceRequest(req_runtime=500.0)},
            )
            for h in hosts
        ]

    server_a, hosts_a = build()
    server_b, hosts_b = build()
    replies_a = [server_a.rpc(r, 0.0) for r in reqs_for(hosts_a)]
    replies_b = server_b.rpc_batch(reqs_for(hosts_b), 0.0)
    assert _reply_sig(replies_a) == _reply_sig(replies_b)
    assert server_a._rr == server_b._rr
    assert _store_sig(server_a) == _store_sig(server_b)


def _sim_pair(coalesce):
    reset_ids()
    server = ProjectServer(name="p", cache_size=64)
    app = App(name="work", min_quorum=1, init_ninstances=1, delay_bound=6 * 3600.0)
    for osn in OSES:
        app.add_version(
            AppVersion(
                id=next_id("appver"),
                app_name="work",
                platform=Platform(osn, "x86_64"),
                version_num=1,
                plan_class=default_cpu_plan_class(),
            )
        )
    server.add_app(app)
    for i in range(60):
        server.submit_job(
            Job(id=next_id("job"), app_name="work", est_flop_count=1e12), 0.0
        )
    pop = make_population(16, seed=4)
    return GridSimulation(server, pop, seed=4, coalesce_rpcs=coalesce)


def test_simulator_coalesced_batch_path():
    """Driving _handle_rpc_batch directly must agree with per-host
    _handle_rpc calls at the same virtual time on a twin simulation."""
    sim_a = _sim_pair(False)
    sim_b = _sim_pair(True)
    ids = list(sim_a.clients.keys())
    for hid in ids:
        sim_a._handle_rpc(hid, 0.0)
    sim_b._handle_rpc_batch(ids, 0.0)
    assert _store_sig(sim_a.server) == _store_sig(sim_b.server)
    for hid in ids:
        ja = [(j.instance_id, j.job_id) for j in sim_a.clients[hid].jobs]
        jb = [(j.instance_id, j.job_id) for j in sim_b.clients[hid].jobs]
        assert ja == jb
    assert sim_a.metrics.rpcs == sim_b.metrics.rpcs
    assert sim_a.metrics.rpcs_with_work == sim_b.metrics.rpcs_with_work


def test_simulator_end_to_end_with_coalescing():
    """A coalescing-enabled simulation still drives jobs to completion.
    (Completed jobs are purged from the store with purge_delay=0, so assert
    on execution metrics and assimilated outputs rather than live rows.)"""
    sim = _sim_pair(True)
    metrics = sim.run(12 * 3600.0)
    assert metrics.instances_executed == 60
    assert len(sim.server.assimilated_outputs) == 60


def test_engine_event_bookkeeping():
    """Dispatch events must invalidate slots and propagate skip bumps so the
    next request in a batch scores against current state."""
    server, hosts = _make_server(1, n_jobs=30, n_hosts=4, cache_size=32)
    sched = server.schedulers[0]
    engine = BatchDispatchEngine(server.store, server.feeder)
    host = hosts[0]
    req = ScheduleRequest(
        host_id=host.id,
        requests={ResourceType.CPU: ResourceRequest(req_runtime=2000.0)},
    )
    start = sched._rng.randrange(engine.n)
    cands = list(engine.candidates(sched, host, req, ResourceType.CPU, start, 0.0))
    assert cands
    top = cands[0]
    assert engine.valid[top.index]
    engine.apply([("dispatch", top)])
    assert not engine.valid[top.index]
    other = next((c for c in cands[1:] if c.job.id != top.job.id), None)
    if other is not None:
        other.slot.skipped += 3
        engine.apply([("skip", other)])
        positions = engine._job_slots[other.job.id]
        if positions and positions[0] == other.index:
            assert engine.skips[other.index] == other.slot.skipped


@pytest.mark.parametrize("seed", range(4))
def test_persistent_engine_matches_scalar_sequential(seed):
    """ISSUE 5: ``vector_dispatch=True`` routes *every* request — singleton
    RPCs included — through a persistent cache snapshot that survives
    across requests (rebuilt only on feeder-generation changes) and an
    array-prefix dispatch tail. It must stay result- and metrics-identical
    to the scalar per-request scan across interleaved RPCs, server ticks
    (feeder refills invalidate the snapshot), and completion reports."""
    server_a, hosts_a = _make_server(seed)  # scalar reference
    server_b, hosts_b = _make_server(seed)
    server_b.set_vector_dispatch(True)
    rng = random.Random(seed + 31)
    now = 10.0
    for rnd in range(4):
        reqs_a = _make_requests(hosts_a, seed + rnd * 13)
        reqs_b = _make_requests(hosts_b, seed + rnd * 13)
        replies_a = [server_a.rpc(r, now) for r in reqs_a]
        replies_b = [server_b.rpc(r, now) for r in reqs_b]
        assert _reply_sig(replies_a) == _reply_sig(replies_b)
        assert _store_sig(server_a) == _store_sig(server_b)
        assert server_a.schedulers[0].metrics == server_b.schedulers[0].metrics
        # the snapshot genuinely persists within a round of singleton RPCs
        assert server_b.feeder._engines.get(None) is not None
        comp_a = _completions_from(replies_a, random.Random(seed + rnd))
        comp_b = _completions_from(replies_b, random.Random(seed + rnd))
        ra = _make_requests(hosts_a, seed + rnd * 7 + 1)[0]
        rb = _make_requests(hosts_b, seed + rnd * 7 + 1)[0]
        ra.completed = comp_a.get(ra.host_id, [])
        rb.completed = comp_b.get(rb.host_id, [])
        assert _reply_sig([server_a.rpc(ra, now + 1.0)]) == _reply_sig(
            [server_b.rpc(rb, now + 1.0)]
        )
        now += 600.0
        server_a.tick(now)
        server_b.tick(now)
        assert _store_sig(server_a) == _store_sig(server_b)
    # a fill that changed the cache must have bumped the generation; the
    # next RPC rebuilds rather than serving the stale snapshot
    engine = server_b.feeder._engines.get(None)
    assert engine is not None
    if engine.version != server_b.feeder.version:
        server_b.rpc(_make_requests(hosts_b, seed)[0], now)
        assert server_b.feeder._engines[None].version == server_b.feeder.version


def test_persistent_engine_survives_and_rebuilds_on_fill():
    """The engine object is reused across requests with an unchanged cache
    and replaced after a feeder fill (version bump)."""
    server, hosts = _make_server(2, n_jobs=60, n_hosts=6, cache_size=48)
    server.set_vector_dispatch(True)
    req = lambda h: ScheduleRequest(  # noqa: E731
        host_id=h.id,
        requests={ResourceType.CPU: ResourceRequest(req_runtime=100.0)},
    )
    server.rpc(req(hosts[0]), 0.0)
    e1 = server.feeder._engines.get(None)
    assert e1 is not None
    server.rpc(req(hosts[1]), 0.1)
    assert server.feeder._engines.get(None) is e1  # persisted: no cache change
    server.tick(600.0)  # transition + fill: cache contents change
    server.rpc(req(hosts[2]), 600.1)
    e2 = server.feeder._engines.get(None)
    assert e2 is not e1
    assert e2.version == server.feeder.version
