"""Per-kernel shape/dtype sweeps, assert_allclose vs the pure-jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.int8_quant.ops import int8_quantize, quantize_dequantize
from repro.kernels.int8_quant.ref import int8_quantize_ref
from repro.kernels.quorum_compare.ops import quorum_compare, tree_quorum_agree
from repro.kernels.quorum_compare.ref import quorum_compare_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.swiglu.ops import swiglu
from repro.kernels.swiglu.ref import swiglu_ref

KEY = jax.random.PRNGKey(7)


class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,s,h,kv,d,causal,dtype",
        [
            (2, 256, 8, 4, 64, True, jnp.float32),
            (1, 384, 4, 1, 128, True, jnp.float32),
            (2, 200, 4, 4, 48, False, jnp.float32),
            (1, 256, 8, 2, 128, False, jnp.float32),
            (1, 256, 4, 2, 64, True, jnp.bfloat16),
            (1, 130, 2, 2, 32, True, jnp.float32),  # ragged padding path
        ],
    )
    def test_matches_oracle(self, b, s, h, kv, d, causal, dtype):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32).astype(dtype)
        k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32).astype(dtype)
        v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32).astype(dtype)
        out = flash_attention(q, k, v, causal=causal, interpret=True)
        qh, kh, vh = (jnp.moveaxis(x, 1, 2) for x in (q, k, v))
        ref = jnp.moveaxis(attention_ref(qh, kh, vh, causal=causal), 1, 2)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
        )


class TestSSDScan:
    @pytest.mark.parametrize(
        "b,s,h,p,g,n,bq",
        [
            (2, 256, 4, 64, 1, 64, 128),
            (1, 200, 8, 32, 2, 32, 64),  # padding path + groups
            (1, 128, 2, 16, 1, 128, 128),
        ],
    )
    def test_matches_sequential_recurrence(self, b, s, h, p, g, n, bq):
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.05 + 0.001
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        Bm = jax.random.normal(ks[3], (b, s, g, n), jnp.float32) * 0.3
        Cm = jax.random.normal(ks[4], (b, s, g, n), jnp.float32) * 0.3
        y, st_ = ssd_scan(x, dt, A, Bm, Cm, block_q=bq, interpret=True)
        yr, str_ = ssd_ref(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=3e-4, rtol=3e-4)
        np.testing.assert_allclose(np.asarray(st_), np.asarray(str_), atol=3e-4, rtol=3e-4)


class TestRMSNorm:
    @pytest.mark.parametrize("shape", [(4, 256), (3, 77, 256), (2, 5, 8, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, shape, dtype):
        x = jax.random.normal(KEY, shape, jnp.float32).astype(dtype)
        sc = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],), jnp.float32)
        out = rmsnorm(x, sc, interpret=True)
        ref = rmsnorm_ref(x, sc)
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
        )


class TestSwiGLU:
    @pytest.mark.parametrize("shape", [(16, 128), (5, 100, 128), (1, 7, 384)])
    def test_matches_oracle(self, shape):
        g = jax.random.normal(KEY, shape, jnp.float32)
        u = jax.random.normal(jax.random.PRNGKey(3), shape, jnp.float32)
        np.testing.assert_allclose(
            np.asarray(swiglu(g, u, interpret=True)),
            np.asarray(swiglu_ref(g, u)),
            atol=1e-6,
        )


class TestQuorumCompare:
    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(min_value=10, max_value=5000),
        bad_frac=st.floats(min_value=0.0, max_value=0.2),
    )
    def test_bad_count_matches_oracle(self, n, bad_frac):
        rng = np.random.default_rng(n)
        a = rng.standard_normal(n).astype(np.float32)
        b = a.copy()
        n_bad = int(n * bad_frac)
        if n_bad:
            b[:n_bad] += 1.0
        nb, sq = quorum_compare(jnp.asarray(a), jnp.asarray(b), rtol=1e-5, atol=1e-6, interpret=True)
        nbr, sqr = quorum_compare_ref(jnp.asarray(a), jnp.asarray(b), rtol=1e-5, atol=1e-6)
        assert float(nb) == float(nbr)
        np.testing.assert_allclose(float(sq), float(sqr), rtol=1e-5)

    def test_tree_agreement(self):
        a = {"w": jnp.ones((100, 7)), "b": jnp.zeros((13,))}
        assert tree_quorum_agree(a, jax.tree_util.tree_map(lambda x: x + 1e-9, a))
        b = {"w": jnp.ones((100, 7)).at[0, 0].set(5.0), "b": jnp.zeros((13,))}
        assert not tree_quorum_agree(a, b)
        assert not tree_quorum_agree(a, {"w": jnp.ones((100, 7))})  # missing leaf

    def test_transitioner_integration_tensor_payloads(self, monkeypatch):
        """The kernel wired through the validator stack, not in isolation:
        ``Transitioner(batch_validate=True, engine_backend="jax")`` on
        tensor payloads routes the fuzzy digest through ``quorum_compare``
        and must reach the same canonical choices, validate states, and
        granted credit as the scalar comparator path."""
        from repro.core import jax_backend
        from test_batch_validate import build_pending, snapshot

        calls = {"n": 0}
        real = jax_backend.quorum_group_codes

        def counting(*a, **kw):
            calls["n"] += 1
            return real(*a, **kw)

        monkeypatch.setattr(jax_backend, "quorum_group_codes", counting)

        sa, ta = build_pending(
            payload="array", comparator="fuzzy", batch_validate=False,
            bad_frac=0.3,
        )
        ta.tick(60.0)
        snap_a = snapshot(sa, ta)

        sb, tb = build_pending(
            payload="array", comparator="fuzzy", batch_validate=True,
            bad_frac=0.3,
        )
        tb.engine_backend = "jax"  # engine is built lazily on first tick
        tb.tick(60.0)
        snap_b = snapshot(sb, tb)

        assert calls["n"] > 0  # the Pallas grouping actually ran
        assert snap_a == snap_b
        sb.check_invariants()


class TestInt8Quant:
    @pytest.mark.parametrize("shape", [(100, 300), (17,), (4, 5, 6)])
    def test_roundtrip_error_bounded(self, shape):
        x = jax.random.normal(KEY, shape, jnp.float32) * 3.0
        rt = quantize_dequantize(x)
        amax = float(jnp.max(jnp.abs(x)))
        assert float(jnp.max(jnp.abs(rt - x))) <= amax / 127.0 + 1e-7

    def test_matches_oracle(self):
        x = jax.random.normal(KEY, (512, 256), jnp.float32)
        q, s = int8_quantize(x, block_rows=256, interpret=True)
        qr, sr = int8_quantize_ref(np.asarray(x).reshape(512, 256), 256)
        np.testing.assert_array_equal(np.asarray(q), qr)
        np.testing.assert_allclose(np.asarray(s), sr, rtol=1e-6)
