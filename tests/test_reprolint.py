"""reprolint analyzer tests: exact finding sets on the fixture corpus, the
suppression/baseline machinery, the CLI, and — the point of the whole
exercise — that the live tree is reprolint-clean.

Every rule is proven non-vacuous here: its ``*_bad.py`` fixture must
produce the exact expected finding set, and its clean twin must produce
nothing.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import ALL_RULES, dump_baseline, load_baseline, run_checks
from repro.analysis import config as rlconfig

pytestmark = pytest.mark.lint

FIXTURES = Path(__file__).parent / "analysis_fixtures"
REPO_ROOT = Path(__file__).parent.parent


def findings_on(name: str):
    report = run_checks([str(FIXTURES / name)])
    return report


def rule_symbol_set(report):
    return {(f.rule, f.symbol) for f in report.findings}


# ---------------------------------------------------------------------------
# rng-discipline
# ---------------------------------------------------------------------------


class TestRngRule:
    def test_bad_fixture_exact_findings(self):
        report = findings_on("rng_bad.py")
        assert rule_symbol_set(report) == {
            ("rng-discipline", "jitter:random.uniform"),
            ("rng-discipline", "pick:random.choice"),
            ("rng-discipline", "make_rng:random.Random()"),
            ("rng-discipline", "legacy_table:np.random.RandomState"),
            ("rng-discipline", "entropy_rng:np.random.default_rng()"),
        }
        # precise output format: path:line:col rule-id message
        lead = report.findings[0].format()
        path, line, col_and_rest = lead.split(":", 2)
        assert path.endswith("rng_bad.py") and int(line) > 0
        # each message names the contract and the whitelist that would apply
        for f in report.findings:
            assert "contract" in f.message and "Whitelist" in f.message

    def test_clean_twin(self):
        assert findings_on("rng_clean.py").findings == []


# ---------------------------------------------------------------------------
# purge-complete
# ---------------------------------------------------------------------------


class TestPurgeRule:
    def test_bad_fixture_exact_findings(self):
        report = findings_on("core/purge_bad.py")
        assert rule_symbol_set(report) == {
            ("purge-complete", "LeakyTracker.host_scores"),
            ("purge-complete", "LeakyTracker.latencies"),
            ("purge-complete", "LeakyInitStyle.by_host"),
            ("purge-complete", "HalfPurged.host_extra"),
        }

    def test_clean_twin(self):
        assert findings_on("core/purge_clean.py").findings == []

    def test_out_of_scope_without_core_segment(self):
        """The same leaky code outside core/ is out of the rule's scope."""
        src = (FIXTURES / "core/purge_bad.py").read_text()
        import shutil
        import tempfile

        with tempfile.TemporaryDirectory() as d:
            p = Path(d) / "purge_bad.py"
            p.write_text(src)
            assert run_checks([str(p)]).findings == []


# ---------------------------------------------------------------------------
# parity-float
# ---------------------------------------------------------------------------


class TestFloatRule:
    def test_bad_fixture_exact_findings(self):
        report = findings_on("batch_float_bad.py")
        assert rule_symbol_set(report) == {
            ("parity-float", "total_runtime:np.sum"),
            ("parity-float", "mean_credit:.mean()"),
            ("parity-float", "product_term:np.prod"),
            ("parity-float", "compensated:math.fsum"),
            ("parity-float", "accumulate_over_hosts:set-iter-accum"),
        }

    def test_clean_twin(self):
        assert findings_on("batch_float_clean.py").findings == []

    def test_scope_is_engine_files_only(self):
        """np.mean in a non-engine file (models, runtime) is not flagged."""
        import shutil
        import tempfile

        src = (FIXTURES / "batch_float_bad.py").read_text()
        with tempfile.TemporaryDirectory() as d:
            p = Path(d) / "layers.py"
            p.write_text(src)
            assert run_checks([str(p)]).findings == []


# ---------------------------------------------------------------------------
# frozen-mut
# ---------------------------------------------------------------------------


class TestFrozenRule:
    def test_bad_fixture_exact_findings(self):
        report = findings_on("frozen_bad.py")
        assert rule_symbol_set(report) == {
            ("frozen-mut", "LocalSpec.n_hosts"),  # object.__setattr__(self, ...)
            ("frozen-mut", "ScenarioSpec.seed"),  # known-frozen annotation
        }
        # the LocalSpec symbol fires twice: method escape + annotated param
        syms = [f.symbol for f in report.findings]
        assert syms.count("LocalSpec.n_hosts") == 2
        assert syms.count("ScenarioSpec.seed") == 2

    def test_clean_twin(self):
        assert findings_on("frozen_clean.py").findings == []


# ---------------------------------------------------------------------------
# index-bypass
# ---------------------------------------------------------------------------


class TestBypassRule:
    def test_bad_fixture_exact_findings(self):
        report = findings_on("observer_bad.py")
        assert rule_symbol_set(report) == {
            ("index-bypass", "sneak_state:state"),
            ("index-bypass", "sneak_dict:validate_state"),
            ("index-bypass", "sneak_update:outcome"),
        }

    def test_clean_twin(self):
        assert findings_on("observer_clean.py").findings == []

    def test_tracked_fields_config_matches_types(self):
        """config.TRACKED_FIELDS mirrors the IndexObserved classes — if a
        tracked field is added to types.py, the rule must learn it."""
        from repro.core.types import Job, JobInstance

        assert rlconfig.TRACKED_FIELDS == frozenset(
            Job._TRACKED | JobInstance._TRACKED
        )

    def test_store_module_is_whitelisted(self):
        """The store's fused bulk writers are the sanctioned bypass."""
        store = REPO_ROOT / "src/repro/core/store.py"
        assert run_checks([str(store)]).findings == []


# ---------------------------------------------------------------------------
# suppressions, baseline ratchet, CLI
# ---------------------------------------------------------------------------


class TestSuppression:
    def test_inline_ignores(self):
        report = findings_on("suppressed_ok.py")
        assert rule_symbol_set(report) == {
            ("rng-discipline", "unsuppressed_draw:random.random"),
        }
        assert {f.symbol for f in report.suppressed} == {
            "fixed_table:np.random.RandomState",
            "any_rule_jitter:random.uniform",
        }


class TestBaseline:
    def test_ratchet_roundtrip(self, tmp_path):
        bad = str(FIXTURES / "rng_bad.py")
        report = run_checks([bad])
        assert len(report.new) == 5 and not report.ok

        # grandfather everything: the same findings are now baselined
        bl = tmp_path / "baseline.json"
        dump_baseline(str(bl), report.findings)
        report2 = run_checks([bad], baseline_path=str(bl))
        assert report2.ok
        assert len(report2.baselined) == 5 and report2.new == []

        # shrink the tree (scan the clean twin instead): every baseline
        # entry goes stale — the ratchet direction the CI job enforces
        report3 = run_checks([str(FIXTURES / "rng_clean.py")], baseline_path=str(bl))
        assert report3.ok and len(report3.stale_baseline) == 5

        # a baseline can never hide a *new* finding
        entries = load_baseline(str(bl))
        assert all(e[1] == "rng-discipline" for e in entries)
        report4 = run_checks([str(FIXTURES / "observer_bad.py")], baseline_path=str(bl))
        assert not report4.ok and len(report4.new) == 3

    def test_baseline_keys_ignore_line_numbers(self, tmp_path):
        """Unrelated edits (line drift) must not churn the baseline: keys
        are (path, rule, symbol)."""
        src = (FIXTURES / "rng_bad.py").read_text()
        p = tmp_path / "rng_bad.py"
        p.write_text(src)
        bl = tmp_path / "baseline.json"
        dump_baseline(str(bl), run_checks([str(p)]).findings)
        p.write_text("# a new comment shifting every line\n" + src)
        report = run_checks([str(p)], baseline_path=str(bl))
        assert report.ok and len(report.baselined) == 5


class TestCLI:
    def run_cli(self, *args, cwd=None):
        env = dict(os.environ)
        env["PYTHONPATH"] = str(REPO_ROOT / "src") + os.pathsep + env.get("PYTHONPATH", "")
        return subprocess.run(
            [sys.executable, "-m", "repro.analysis", *args],
            capture_output=True,
            text=True,
            env=env,
            cwd=cwd or str(REPO_ROOT),
        )

    def test_exit_codes_and_report(self, tmp_path):
        report_file = tmp_path / "REPROLINT_report.json"
        r = self.run_cli(
            str(FIXTURES / "rng_bad.py"), "--no-baseline", "--report", str(report_file)
        )
        assert r.returncode == 1
        assert "rng-discipline" in r.stdout
        data = json.loads(report_file.read_text())
        assert data["tool"] == "reprolint" and not data["ok"]
        assert len(data["new"]) == 5
        assert set(data["rules"]) == set(ALL_RULES)

        r2 = self.run_cli(str(FIXTURES / "rng_clean.py"), "--no-baseline")
        assert r2.returncode == 0

    def test_fail_on_stale_enforces_shrink(self, tmp_path):
        bl = tmp_path / "baseline.json"
        dump_baseline(str(bl), run_checks([str(FIXTURES / "rng_bad.py")]).findings)
        r = self.run_cli(
            str(FIXTURES / "rng_clean.py"), "--baseline", str(bl), "--fail-on-stale"
        )
        assert r.returncode == 1 and "stale" in r.stdout
        r2 = self.run_cli(str(FIXTURES / "rng_clean.py"), "--baseline", str(bl))
        assert r2.returncode == 0  # stale alone is a warning without the flag


# ---------------------------------------------------------------------------
# the live tree
# ---------------------------------------------------------------------------


class TestLiveTree:
    def test_src_repro_is_clean_against_baseline(self):
        """The acceptance gate: `python -m repro.analysis src/repro` exits 0
        — every finding fixed, inline-suppressed, or baselined."""
        baseline = REPO_ROOT / "reprolint_baseline.json"
        report = run_checks(
            [str(REPO_ROOT / "src/repro")],
            baseline_path=str(baseline) if baseline.exists() else None,
            root=str(REPO_ROOT),
        )
        assert report.ok, "\n".join(f.format() for f in report.new)
        # and the ratchet holds: no stale grandfathered entries linger
        assert report.stale_baseline == []

    def test_known_true_positive_fixes_stay_fixed(self):
        """Module-level regression pins for the violations this pass
        surfaced: the coordinator purge path and the validator mix-vector
        rederivation must keep their modules reprolint-clean."""
        for mod in ("core/coordinator.py", "core/validator.py", "core/credit.py"):
            report = run_checks([str(REPO_ROOT / "src/repro" / mod)])
            assert report.ok, "\n".join(f.format() for f in report.new)
