"""Validator quorum logic (§3.4) and the transitioner FSM (§4)."""
import numpy as np
import pytest

from repro.core import (
    App,
    AppVersion,
    InstanceOutcome,
    InstanceState,
    Job,
    JobInstance,
    JobState,
    JobStore,
    Platform,
    Transitioner,
    ValidateState,
    bitwise_equal,
    check_set,
    default_cpu_plan_class,
    fuzzy_comparator,
    next_id,
    reset_ids,
)
from repro.core.validator import validate_against_canonical


def _inst(output, outcome=InstanceOutcome.SUCCESS, iid=None):
    return JobInstance(
        id=iid or next_id("instance"),
        job_id=1,
        state=InstanceState.OVER,
        outcome=outcome,
        output=output,
    )


class TestComparators:
    def test_bitwise(self):
        a = np.arange(8, dtype=np.float32)
        assert bitwise_equal({"x": a}, {"x": a.copy()})
        b = a.copy()
        b[3] = np.nextafter(b[3], np.float32(10.0))  # one ULP
        assert not bitwise_equal({"x": a}, {"x": b})

    def test_fuzzy(self):
        cmp = fuzzy_comparator(rtol=1e-5)
        a = np.linspace(1, 2, 100)
        assert cmp(a, a * (1 + 1e-7))
        assert not cmp(a, a * 1.01)

    def test_fuzzy_bad_fraction(self):
        cmp = fuzzy_comparator(rtol=1e-5, max_bad_fraction=0.05)
        a = np.ones(100)
        b = a.copy()
        b[:3] = 2.0  # 3% bad
        assert cmp(a, b)
        b[:10] = 2.0  # 10% bad
        assert not cmp(a, b)


class TestCheckSet:
    def setup_method(self):
        reset_ids()

    def test_agreeing_pair_forms_quorum(self):
        r = check_set([_inst(1.0), _inst(1.0)], None, min_quorum=2)
        assert r.canonical is not None
        assert len(r.valid) == 2

    def test_disagreeing_pair_inconclusive(self):
        r = check_set([_inst(1.0), _inst(2.0)], None, min_quorum=2)
        assert r.canonical is None
        assert len(r.inconclusive) == 2

    def test_tiebreaker_resolves(self):
        r = check_set([_inst(1.0), _inst(2.0), _inst(1.0)], None, min_quorum=2)
        assert r.canonical is not None
        assert r.canonical.output == 1.0
        assert len(r.invalid) == 1

    def test_many_distinct_corruptions_need_quorum(self):
        # 2 agreeing + 3 distinct corruptions: quorum reached by the pair
        insts = [_inst(1.0), _inst(7.0), _inst(1.0), _inst(8.0), _inst(9.0)]
        r = check_set(insts, None, min_quorum=2)
        assert r.canonical is not None and r.canonical.output == 1.0
        assert len(r.invalid) == 3

    def test_below_quorum_waits(self):
        r = check_set([_inst(1.0)], None, min_quorum=2)
        assert r.canonical is None

    def test_single_quorum_trusted(self):
        r = check_set([_inst(3.0)], None, min_quorum=1)
        assert r.canonical is not None

    def test_nontransitive_fuzzy_grouping_order_pinned(self):
        """The check_set grouping-order contract (pinned; the batch engine
        mirrors it): instances are visited in the given order, each joins
        the first group whose *representative* (first member) it matches,
        and ties between equal-size groups go to the earlier group.

        With a tolerance relation a~b, b~c, a!~c the outcome is therefore
        order-dependent — this test pins it so the contract can't drift.
        """
        cmp = fuzzy_comparator(rtol=0.0, atol=1.0)
        a, b, c = _inst(0.0), _inst(0.9), _inst(1.8)
        assert cmp(a.output, b.output) and cmp(b.output, c.output)
        assert not cmp(a.output, c.output)

        # order [a, b, c]: b joins a's group; c is compared against the
        # *representative* a (never b), fails, and opens its own group
        r = check_set([a, b, c], cmp, min_quorum=2)
        assert r.canonical is a
        assert r.valid == [a, b] and r.invalid == [c]

        # order [c, b, a]: the mirror outcome — same sizes, different split
        for i in (a, b, c):
            i.validate_state = ValidateState.INIT
        r = check_set([c, b, a], cmp, min_quorum=2)
        assert r.canonical is c
        assert r.valid == [c, b] and r.invalid == [a]

        # order [b, a, c]: everyone matches representative b — one group,
        # even though a and c disagree with each other
        for i in (a, b, c):
            i.validate_state = ValidateState.INIT
        r = check_set([b, a, c], cmp, min_quorum=2)
        assert r.canonical is b
        assert r.valid == [b, a, c] and r.invalid == []

        # equal-size tie: the earlier-created group wins
        for i in (a, c):
            i.validate_state = ValidateState.INIT
        d = _inst(0.1)
        e = _inst(1.9)
        r = check_set([a, c, d, e], cmp, min_quorum=2)
        assert r.canonical is a
        assert r.valid == [a, d] and r.invalid == [c, e]

    def test_late_validate_against_canonical(self):
        canonical = _inst(1.0)
        late_ok = _inst(1.0)
        late_bad = _inst(2.0)
        assert validate_against_canonical(late_ok, canonical, None)
        assert not validate_against_canonical(late_bad, canonical, None)
        assert late_bad.validate_state == ValidateState.INVALID


def make_store(min_quorum=2, max_err=3, max_succ=6):
    reset_ids()
    store = JobStore()
    app = App(
        name="a",
        min_quorum=min_quorum,
        init_ninstances=min_quorum,
        max_error_instances=max_err,
        max_success_instances=max_succ,
    )
    app.add_version(
        AppVersion(
            id=next_id("appver"),
            app_name="a",
            platform=Platform("windows", "x86_64"),
            version_num=1,
            plan_class=default_cpu_plan_class(),
        )
    )
    store.add_app(app)
    return store


class TestTransitioner:
    def test_initial_instances_created(self):
        store = make_store()
        job = store.submit_job(Job(id=next_id("job"), app_name="a", est_flop_count=1e9))
        tr = Transitioner(store=store)
        tr.tick(0.0)
        assert len(store.job_instances(job.id)) == 2

    def test_deadline_miss_creates_retry(self):
        store = make_store()
        job = store.submit_job(Job(id=next_id("job"), app_name="a", est_flop_count=1e9, delay_bound=100.0))
        tr = Transitioner(store=store)
        tr.tick(0.0)
        insts = store.job_instances(job.id)
        for i in insts:
            i.state = InstanceState.IN_PROGRESS
            i.deadline = 100.0
        tr.tick(200.0)  # past deadline
        insts = store.job_instances(job.id)
        assert sum(1 for i in insts if i.outcome == InstanceOutcome.NO_REPLY) == 2
        assert sum(1 for i in insts if i.state == InstanceState.UNSENT) == 2
        assert tr.metrics.timeouts == 2

    def test_quorum_validates_and_cancels_unsent(self):
        store = make_store()
        job = store.submit_job(Job(id=next_id("job"), app_name="a", est_flop_count=1e9))
        tr = Transitioner(store=store)
        tr.tick(0.0)
        i1, i2 = store.job_instances(job.id)
        for i in (i1, i2):
            i.state = InstanceState.OVER
            i.outcome = InstanceOutcome.SUCCESS
            i.output = 42.0
        job.transition_flag = True
        tr.tick(1.0)
        assert job.state == JobState.SUCCESS
        assert job.canonical_instance_id in (i1.id, i2.id)

    def test_disagreement_spawns_tiebreaker(self):
        store = make_store()
        job = store.submit_job(Job(id=next_id("job"), app_name="a", est_flop_count=1e9))
        tr = Transitioner(store=store)
        tr.tick(0.0)
        i1, i2 = store.job_instances(job.id)
        i1.state = i2.state = InstanceState.OVER
        i1.outcome = i2.outcome = InstanceOutcome.SUCCESS
        i1.output, i2.output = 1.0, 2.0
        job.transition_flag = True
        tr.tick(1.0)
        assert job.state == JobState.ACTIVE
        unsent = [
            i for i in store.job_instances(job.id) if i.state == InstanceState.UNSENT
        ]
        assert len(unsent) == 1  # the tie-breaker

    def test_error_limit_fails_job(self):
        store = make_store(max_err=2)
        job = store.submit_job(Job(id=next_id("job"), app_name="a", est_flop_count=1e9, max_error_instances=2))
        tr = Transitioner(store=store)
        for round_ in range(4):
            tr.tick(float(round_))
            for i in store.job_instances(job.id):
                if i.state == InstanceState.UNSENT:
                    i.state = InstanceState.OVER
                    i.outcome = InstanceOutcome.CLIENT_ERROR
            job.transition_flag = True
        tr.tick(10.0)
        assert job.state == JobState.FAILURE

    def test_daemon_pause_accumulates_work(self):
        """§5.1 fault tolerance: stopping the transitioner doesn't lose
        anything — flags accumulate and are processed on resume."""
        store = make_store()
        jobs = [store.submit_job(Job(id=next_id("job"), app_name="a", est_flop_count=1e9)) for _ in range(5)]
        tr = Transitioner(store=store)
        # daemon "down": nothing processed
        assert all(not store.job_instances(j.id) for j in jobs)
        # daemon resumes
        n = tr.tick(0.0)
        assert n == 5
        assert all(len(store.job_instances(j.id)) == 2 for j in jobs)
