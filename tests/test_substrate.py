"""Data pipeline, optimizer, compression, checkpointing, fault tolerance."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, CheckpointPolicy
from repro.data import DataConfig, DataShard, global_batch, make_batch
from repro.distributed import (
    HeartbeatMonitor,
    StragglerPolicy,
    candidate_meshes,
    plan_elastic_config,
)
from repro.optim import (
    AdamWConfig,
    apply_updates,
    compress_tree,
    compressed_bytes,
    decompress_tree,
    ef_quantize_tree,
    init_residual,
    init_state,
    lr_at,
)

KEY = jax.random.PRNGKey(0)


class TestData:
    def test_deterministic_per_shard_step(self):
        cfg = DataConfig(vocab=100, seq_len=32, batch_size=4, n_shards=2)
        a = make_batch(cfg, 0, 5)
        b = make_batch(cfg, 0, 5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = make_batch(cfg, 1, 5)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab=100, seq_len=32, batch_size=2)
        b = make_batch(cfg, 0, 0)
        np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])

    def test_embeds_mode(self):
        cfg = DataConfig(vocab=50, seq_len=16, batch_size=2, input_mode="embeds", d_model=8)
        b = make_batch(cfg, 0, 0)
        assert b["embeds"].shape == (2, 16, 8)

    def test_global_batch_concatenates_shards(self):
        cfg = DataConfig(vocab=50, seq_len=16, batch_size=2, n_shards=3)
        g = global_batch(cfg, 0)
        assert g["tokens"].shape == (6, 16)

    def test_shard_iterator(self):
        cfg = DataConfig(vocab=50, seq_len=8, batch_size=1)
        it = iter(DataShard(cfg, shard=0))
        b0, b1 = next(it), next(it)
        assert not np.array_equal(b0["tokens"], b1["tokens"])


class TestOptim:
    def test_schedules(self):
        cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100, schedule="cosine")
        assert float(lr_at(cfg, jnp.asarray(0))) < 1e-3 * 0.2
        assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1e-3, rel=0.1)
        assert float(lr_at(cfg, jnp.asarray(100))) == pytest.approx(1e-4, rel=0.1)
        wsd = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100, schedule="wsd")
        assert float(lr_at(wsd, jnp.asarray(50))) == pytest.approx(1e-3, rel=0.05)

    def test_adamw_reduces_quadratic(self):
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = init_state(params)
        cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200, weight_decay=0.0, clip_norm=0)
        for _ in range(150):
            g = {"w": 2 * params["w"]}
            params, state, _ = apply_updates(cfg, params, g, state)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.5

    def test_clip_norm_metric(self):
        params = {"w": jnp.zeros(3)}
        state = init_state(params)
        cfg = AdamWConfig(clip_norm=1.0)
        _, _, m = apply_updates(cfg, params, {"w": jnp.asarray([3.0, 4.0, 0.0])}, state)
        assert float(m["grad_norm"]) == pytest.approx(5.0)

    def test_error_feedback_compensates_bias(self):
        g = {"w": jax.random.normal(KEY, (256,)) * 1e-3}
        res = init_residual(g)
        total_q = jnp.zeros(256)
        for _ in range(50):
            q, res = ef_quantize_tree(g, res)
            total_q = total_q + q["w"]
        # accumulated quantized grads track accumulated true grads
        np.testing.assert_allclose(
            np.asarray(total_q), np.asarray(g["w"] * 50), atol=float(jnp.max(jnp.abs(g["w"]))) * 2
        )

    def test_wire_compression_roundtrip(self):
        tree = {"a": jax.random.normal(KEY, (100, 4)), "b": jnp.ones((7,))}
        packed = compress_tree(tree)
        assert compressed_bytes(packed) < 100 * 4 * 4  # ~4x smaller than f32
        out = decompress_tree(packed)
        amax = float(jnp.max(jnp.abs(tree["a"])))
        np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(tree["a"]), atol=amax / 100)


class TestCheckpoint:
    def test_save_restore_roundtrip(self):
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3), "n": np.asarray(3)}
            ck.save(10, {"params": tree})
            step, out = ck.restore({"params": {"w": np.zeros((2, 3), np.float32), "n": np.asarray(0)}})
            assert step == 10
            np.testing.assert_array_equal(out["params"]["w"], tree["w"])

    def test_gc_keeps_latest(self):
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d, keep=2)
            for s in (1, 2, 3, 4):
                ck.save(s, {"t": {"x": np.zeros(1)}})
            assert ck.latest_step() == 4
            assert len(ck._steps()) == 2

    def test_checksum_validation(self):
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            path = ck.save(1, {"t": {"x": np.ones(4)}})
            # corrupt the file (hash validation, §2.2/§3.10)
            fpath = os.path.join(path, "t.npz")
            with open(fpath, "r+b") as f:
                f.seek(30)
                f.write(b"\x00\x01\x02")
            with pytest.raises(IOError):
                ck.restore({"t": {"x": np.zeros(4)}})

    def test_policy_cadence(self):
        p = CheckpointPolicy(period_steps=10)
        assert not p.should_checkpoint(5)
        assert p.should_checkpoint(10)


class TestFaultTolerance:
    def test_heartbeat_death_detection(self):
        mon = HeartbeatMonitor(period=10.0, max_misses=3)
        mon.register(1, 0.0)
        mon.register(2, 0.0)
        mon.heartbeat(1, 25.0)
        died = mon.sweep(35.0)
        assert died == [2]
        assert mon.live() == [1]

    def test_elastic_plan_preserves_global_batch(self):
        plan = plan_elastic_config(live_chips=256, global_batch=256, model_axis=16)
        assert plan is not None
        data_ways = plan.mesh_shape[0]
        assert data_ways * plan.microbatch_per_worker * plan.grad_accum_steps == 256
        # lose half the fleet: still plannable
        plan2 = plan_elastic_config(live_chips=128, global_batch=256, model_axis=16)
        assert plan2 is not None
        assert plan2.mesh_shape[0] == 8

    def test_candidate_meshes_shrink(self):
        shapes = candidate_meshes(256, model_axis=16)
        assert shapes[0] == (16, 16)
        assert (1, 16) in shapes

    def test_straggler_deadline_adapts(self):
        sp = StragglerPolicy(factor=3.0, min_samples=2)
        sp.observe(10.0)
        sp.observe(20.0)
        assert sp.deadline(100.0) == pytest.approx(100.0 + 45.0)
