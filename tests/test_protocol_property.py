"""Hypothesis round-trips on the service wire codec: every encodable
request and reply (error frames included) must decode back to an equal
dataclass, for arbitrary floats, unicode strings, and field subsets.

NaN is excluded from the generated floats only because ``nan != nan``
breaks dataclass equality — the codec itself carries it fine
(``repr``/``float`` round-trips ``nan`` textually; see the explicit
non-finite example test in ``test_service.py``).
"""
import pytest

pytest.importorskip("hypothesis")  # optional dep: see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import (
    CompletedResult,
    InstanceOutcome,
    ResourceRequest,
    ResourceType,
    ScheduleRequest,
)
from repro.core.scheduler import TrickleUp
from repro.service import (
    ErrorReply,
    JobOffer,
    PingRequest,
    PongReply,
    StatsReply,
    StatsRequest,
    WorkReply,
    WorkRequest,
    decode_reply,
    decode_request,
    encode_reply,
    encode_request,
)

seqs = st.integers(min_value=0, max_value=2**31)
ids = st.integers(min_value=1, max_value=2**40)
exits = st.integers(min_value=-2**31, max_value=2**31)
floats = st.floats(allow_nan=False)  # inf allowed: repr round-trips it
texts = st.text(max_size=40)

resource_requests = st.builds(ResourceRequest, floats, floats, floats)

completions = st.builds(
    CompletedResult,
    instance_id=ids,
    outcome=st.sampled_from(list(InstanceOutcome)),
    runtime=floats,
    peak_flop_count=floats,
    exit_code=exits,
)

trickles = st.builds(TrickleUp, instance_id=ids, fraction_done=floats)

schedule_requests = st.builds(
    ScheduleRequest,
    host_id=ids,
    requests=st.dictionaries(
        st.sampled_from(list(ResourceType)), resource_requests, max_size=3
    ),
    completed=st.lists(completions, max_size=4),
    trickles=st.lists(trickles, max_size=3),
    sticky_files=st.lists(texts, max_size=3).map(tuple),
    usable_disk=floats,
)

requests = st.one_of(
    st.builds(PingRequest, seq=seqs),
    st.builds(StatsRequest, seq=seqs),
    st.builds(WorkRequest, seq=seqs, request=schedule_requests),
)

job_offers = st.builds(
    JobOffer,
    job_id=ids,
    instance_id=ids,
    version_id=ids,
    est_runtime=floats,
    est_flops=floats,
)

replies = st.one_of(
    st.builds(PongReply, seq=seqs),
    st.builds(
        WorkReply,
        seq=seqs,
        request_delay=floats,
        jobs=st.lists(job_offers, max_size=4),
        delete_sticky=st.lists(texts, max_size=3),
    ),
    st.builds(
        StatsReply,
        seq=seqs,
        values=st.dictionaries(texts, floats, max_size=4),
    ),
    st.builds(
        ErrorReply,
        seq=seqs,
        code=st.text(
            alphabet="abcdefghijklmnopqrstuvwxyz-", min_size=1, max_size=16
        ),
        message=texts,
    ),
)


@settings(max_examples=300, deadline=None)
@given(requests)
def test_request_roundtrip(req):
    wire = encode_request(req)
    assert "\n" not in wire
    assert decode_request(wire) == req


@settings(max_examples=300, deadline=None)
@given(replies)
def test_reply_roundtrip(rep):
    wire = encode_reply(rep)
    assert "\n" not in wire
    assert decode_reply(wire) == rep
