"""Persistent columnar world state + vectorized simulation event loop.

Parity contract (ISSUE 5): ``GridSimulation(vector_world=True)`` — the
epoch-batched fused loop over ``core/world.py``'s ``HostArrays`` — must be
bit-identical to the scalar per-event oracle (``vector_world=False``):
same SimMetrics, same job/instance states, same granted credit, with and
without event-time quantization. Plus the satellite regressions: clamped
accrual (busy <= capacity, exact flops accounting), churn purging every
per-host trace, and RNG-stream identity for the prefetched draw batches.
"""
import math
import random

import numpy as np
import pytest

from repro.core import (
    App,
    AppVersion,
    Client,
    ExpDrawCache,
    GridSimulation,
    HostArrays,
    Job,
    Platform,
    ProjectServer,
    default_cpu_plan_class,
    fuzzy_comparator,
    make_population,
    next_id,
    reset_ids,
)
from repro.core.client import ClientJob, ClientPrefs, ClientResource, ProjectAttachment, RunState
from repro.core.types import ResourceType

DAY = 86400.0


def build_sim(vector_world, epoch=0.0, n_hosts=10, n_jobs=50, horizon=DAY,
              sim_seed=3, pop_seed=1, est_hours=0.15, **pop_kw):
    reset_ids()
    server = ProjectServer(name="p", purge_delay=1e18)
    app = App(name="w", min_quorum=2, init_ninstances=2, delay_bound=4 * 3600.0,
              comparator=fuzzy_comparator(rtol=1e-6, atol=1e-9))
    for osn in ("windows", "mac", "linux"):
        app.add_version(AppVersion(id=next_id("appver"), app_name="w",
                                   platform=Platform(osn, "x86_64"), version_num=1,
                                   plan_class=default_cpu_plan_class()))
    server.add_app(app)
    pop = make_population(n_hosts, seed=pop_seed, horizon=horizon, **pop_kw)
    sim = GridSimulation(server, pop, seed=sim_seed,
                         vector_world=vector_world, epoch=epoch)
    for _ in range(n_jobs):
        server.submit_job(Job(id=next_id("job"), app_name="w",
                              est_flop_count=est_hours * 3600 * 16.5e9), 0.0)
    return server, sim


def run_sim(vector_world, epoch=0.0, horizon=DAY, **kw):
    server, sim = build_sim(vector_world, epoch=epoch, horizon=horizon, **kw)
    m = sim.run(horizon)
    sim.audit_validation()
    states = {
        i: (x.validate_state, x.granted_credit, x.outcome, x.runtime)
        for i, x in server.store.instances.items()
    }
    jobs = {j: x.state for j, x in server.store.jobs.items()}
    return (
        vars(m).copy(), server.counts(), server.credit.total, states, jobs,
        dict(sim._wrong_outputs), server, sim,
    )


CONFIGS = [
    dict(),
    dict(availability=0.6),
    dict(churn_rate=1.0 / (1.2 * DAY)),
    dict(availability=0.55, churn_rate=1.0 / (2 * DAY), error_prob=0.02),
]


class TestVectorWorldParity:
    @pytest.mark.parametrize("epoch", [0.0, 60.0])
    @pytest.mark.parametrize("cfg", range(len(CONFIGS)))
    def test_bit_identical_to_scalar_oracle(self, cfg, epoch):
        """Whole-sim identity: metrics, server counts, credit, instance
        validate-states/credit/outcomes/runtimes, job states, and the
        wrong-output map — continuous and epoch-quantized event times."""
        kw = CONFIGS[cfg]
        a = run_sim(False, epoch=epoch, **kw)
        b = run_sim(True, epoch=epoch, **kw)
        for x, y, name in zip(a[:6], b[:6], (
                "metrics", "counts", "credit", "instance states",
                "job states", "wrong outputs")):
            assert x == y, f"vector world diverged from oracle: {name}"

    def test_rng_stream_identity(self):
        """Same seeds => the vectorized loop's prefetched exponential
        availability draws and the per-event corruption/runtime draws
        reproduce the scalar ``random.Random`` sequences host-for-host: the
        final RNG state and every stochastic outcome coincide."""
        kw = dict(availability=0.5, error_prob=0.05)
        a = run_sim(False, epoch=45.0, **kw)
        b = run_sim(True, epoch=45.0, **kw)
        assert a[5] == b[5]  # per-instance corruption outcomes
        assert a[0] == b[0]
        # identical RNG consumption: the generators end in the same state
        assert a[7].rng.getstate() == b[7].rng.getstate()
        assert len(b[7].world.draws) == 0  # prefetched batches fully drained

    def test_exp_draw_cache_matches_expovariate(self):
        """ExpDrawCache.draw == random.Random.expovariate, bitwise, for any
        prefetch batching."""
        means = [60.0, 3600.0, 8 * 3600.0, 1.5]
        ref = random.Random(42)
        want = [ref.expovariate(1.0 / m) for m in means * 50]
        rng = random.Random(42)
        cache = ExpDrawCache()
        got = []
        i = 0
        for chunk in (1, 7, 32, 160):  # arbitrary prefetch sizes
            cache.prefetch(rng, chunk)
            for _ in range(chunk):
                got.append(cache.draw(rng, 1.0 / means[i % len(means)]))
                i += 1
        assert got == want[: len(got)]


class TestClampedAccrual:
    def test_advance_clamps_at_actual_total(self):
        """Unit-level: advancing past the nominal finish charges at most
        the remaining work — accrued, busy and fraction all cap."""
        world = HostArrays()
        client = Client(
            host_id=1,
            resources={ResourceType.CPU: ClientResource(ResourceType.CPU, 4, 1e10)},
            prefs=ClientPrefs(),
        )
        client.attach(ProjectAttachment(name="p"))
        world.add_host(1, client, 4)
        cj = ClientJob(
            instance_id=7, job_id=7, project="p", app_name="w",
            usage={ResourceType.CPU: 1.0}, est_flops=1e10,
            est_flop_count=1e13, deadline=1e9, state=RunState.RUNNING,
        )
        client.jobs.append(cj)
        world.add_job(1, cj, actual_total=100.0)
        world.sync_run_state(1)
        world.advance_host(1, 70.0)
        assert world.get_accrued(1, 7) == 70.0
        assert world.busy_total() == 70.0
        # event lands 50s after the nominal finish: only 30s left to charge
        world.advance_host(1, 150.0)
        assert world.get_accrued(1, 7) == 100.0
        assert world.busy_total() == 100.0
        assert cj.fraction_done == 1.0
        assert cj.runtime == 100.0
        # REC was debited for executed work only
        assert client.rec.accounts["p"].total_used == 100.0
        # further advances charge nothing
        world.advance_host(1, 500.0)
        assert world.get_accrued(1, 7) == 100.0
        assert world.busy_total() == 100.0

    @pytest.mark.parametrize("vector_world", [False, True])
    def test_busy_bounded_by_capacity_under_epoch(self, vector_world):
        """End-to-end: epoch quantization guarantees events land after
        nominal finish times (completions round up to the grid); clamped
        accrual keeps busy <= capacity and flops accounting exact."""
        a = run_sim(vector_world, epoch=120.0, availability=0.6,
                    n_hosts=8, n_jobs=40, horizon=1.5 * DAY)
        m, server, sim = a[0], a[6], a[7]
        assert m["busy_cpu_seconds"] <= m["capacity_cpu_seconds"]
        # exact flops accounting: every executed instance contributes its
        # est_flop_count exactly once
        per_job = 0.15 * 3600 * 16.5e9
        assert m["flops_done"] == pytest.approx(
            m["instances_executed"] * per_job, rel=0, abs=1e-3
        )
        # and no instance is charged past its drawn actual_total: total
        # busy CPU-seconds is bounded by the sum of actual runtimes over
        # every instance ever dispatched (pre-clamp, availability toggles
        # landing after nominal finish times inflated accrual past this)
        assert m["busy_cpu_seconds"] <= sim._dispatched_actual_total + 1e-6


class TestChurnPurge:
    @pytest.mark.parametrize("vector_world", [False, True])
    def test_departed_hosts_leave_no_trace(self, vector_world):
        m, counts, credit, states, jobs, wrong, server, sim = run_sim(
            vector_world, churn_rate=1.0 / (0.5 * DAY), horizon=2 * DAY,
            n_hosts=14, n_jobs=40,
        )
        world = sim.world
        departed = [h for h in world.index if h not in sim.specs]
        assert departed, "churn scenario produced no departures"
        for h in departed:
            i = world.index[h]
            assert not world.alive[i]
            assert not world.available[i]
            assert world.q_count[i] == 0
            assert world.queue_jobs[i] == []
            assert world.row_of[i] == {}
            assert world.clients[i] is None
            assert not world.q_running[:, i].any()
            assert h not in sim.clients
            assert h not in sim.running
        # undelivered instance metadata for departed hosts was purged: any
        # instance still marked in-progress on a departed host (the server
        # only learns of the departure via deadline timeouts) must have had
        # its client-side metadata dropped at churn time
        from repro.core import InstanceState

        departed_set = set(departed)
        stranded = [
            i.id
            for i in server.store.instances.values()
            if i.state == InstanceState.IN_PROGRESS
            and i.host_id in departed_set
        ]
        for iid in stranded:
            assert iid not in sim._instance_meta
        # live hosts' running instances keep theirs
        for h in sim.specs:
            for iid in sim.running[h]:
                assert iid in sim._instance_meta
        # server-side traces are purged too: DB row, estimator stats.
        # (Reputation rows are zeroed at churn but may legitimately re-earn
        # entries from results validated after the departure; the immediate
        # zeroing is unit-tested below.)
        for h in departed:
            assert h not in server.store.hosts
            assert h not in server.estimator._host_versions
            assert not any(
                hk == h for hk, _ in server.estimator.host_version
            )

    def test_server_remove_host_clears_reputation_and_stats(self):
        server, sim = build_sim(True, n_hosts=3, n_jobs=6, horizon=DAY)
        hid = next(iter(sim.specs))
        ver = server.store.apps["w"].versions[0]
        server.adaptive.on_validated(hid, ver.id)
        assert server.adaptive.reputation(hid, ver.id) == 1
        host = server.store.hosts[hid]
        job = next(iter(server.store.jobs.values()))
        server.estimator.record(host, ver, job, 100.0)
        assert (hid, ver.id) in server.estimator.host_version
        server.remove_host(hid)
        assert server.adaptive.reputation(hid, ver.id) == 0
        assert (hid, ver.id) not in server.estimator.host_version
        assert hid not in server.store.hosts


class TestWorldInvariants:
    def test_check_invariants_after_run(self):
        for vw in (False, True):
            *_, server, sim = run_sim(vw, availability=0.7, n_hosts=6,
                                      n_jobs=30, horizon=DAY)
            sim.world.check_invariants(strict_dynamic=not vw)

    def test_dirty_host_refresh(self):
        """mark_dirty => columns rebuilt from objects on next snapshot."""
        server, sim = build_sim(True, n_hosts=4, n_jobs=20, horizon=DAY)
        sim.run(1200.0)
        world = sim.world
        hid = next(h for h in sim.specs if world.q_count[world.index[h]] > 0)
        i = world.index[hid]
        j = world.queue_jobs[i][0]
        j.est_wss = 12345.0  # out-of-band object mutation
        world.mark_dirty(hid)
        sim.client_engine.needs_work_world(world, [hid], sim.now)
        assert world.q_wss[0, i] == 12345.0
        assert hid not in world.dirty
        world.check_invariants()
