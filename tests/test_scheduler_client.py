"""Server dispatch policy (§6.4) and client scheduling/work fetch (§6.1-6.2)."""
import pytest

from repro.core import (
    App,
    AppVersion,
    Client,
    ClientJob,
    ClientPrefs,
    ClientResource,
    CompletedResult,
    Feeder,
    HRLevel,
    Host,
    InstanceOutcome,
    InstanceState,
    Job,
    KeywordPrefs,
    Platform,
    ProcessingResource,
    ProjectAttachment,
    ProjectServer,
    ResourceRequest,
    ResourceType,
    ScheduleRequest,
    default_cpu_plan_class,
    next_id,
    reset_ids,
)
from repro.core.client import RunState, wrr_simulate


def make_server(hr_level=HRLevel.NONE, locality=False, keywords=()):
    reset_ids()
    server = ProjectServer(name="p", purge_delay=1e18)
    app = App(
        name="a",
        min_quorum=1,
        init_ninstances=1,
        hr_level=hr_level,
        uses_locality=locality,
    )
    for osn in ("windows", "linux"):
        app.add_version(
            AppVersion(
                id=next_id("appver"),
                app_name="a",
                platform=Platform(osn, "x86_64"),
                version_num=1,
                plan_class=default_cpu_plan_class(),
            )
        )
    server.add_app(app)
    return server


def make_host(hid=1, os_name="windows", flops=16.5e9):
    return Host(
        id=hid,
        platforms=(Platform(os_name, "x86_64"),),
        resources={
            ResourceType.CPU: ProcessingResource(ResourceType.CPU, 4, flops)
        },
        volunteer_id=hid,
    )


def req(host_id, runtime=1e5, idle=4.0, **kw):
    return ScheduleRequest(
        host_id=host_id,
        requests={ResourceType.CPU: ResourceRequest(req_runtime=runtime, req_idle=idle)},
        **kw,
    )


class TestDispatch:
    def test_basic_dispatch_fills_request(self):
        server = make_server()
        host = server.add_host(make_host())
        for _ in range(10):
            server.submit_job(Job(id=next_id("job"), app_name="a", est_flop_count=16.5e9 * 3600))
        server.tick(0.0)
        reply = server.rpc(req(host.id, runtime=4 * 3600.0, idle=4), 0.0)
        assert reply.jobs, "no jobs dispatched"
        # instances marked in progress with deadlines
        for dj in reply.jobs:
            assert dj.instance.state == InstanceState.IN_PROGRESS
            assert dj.instance.deadline > 0

    def test_platform_filter(self):
        server = make_server()
        mac = make_host(os_name="mac")
        server.add_host(mac)
        server.submit_job(Job(id=next_id("job"), app_name="a", est_flop_count=1e9))
        server.tick(0.0)
        reply = server.rpc(req(mac.id), 0.0)
        assert not reply.jobs  # no mac app version exists

    def test_one_instance_per_volunteer(self):
        server = make_server()
        server.store.apps["a"].min_quorum = 2
        server.store.apps["a"].init_ninstances = 2
        host = server.add_host(make_host())
        job = server.submit_job(Job(id=next_id("job"), app_name="a", est_flop_count=1e9,
                                    min_quorum=2, init_ninstances=2))
        server.tick(0.0)
        r1 = server.rpc(req(host.id), 0.0)
        assert len(r1.jobs) == 1
        r2 = server.rpc(req(host.id), 1.0)
        assert not r2.jobs  # second instance must go to a different volunteer

    def test_deadline_infeasible_skipped(self):
        server = make_server()
        slow = make_host(flops=1e6)  # hopeless host
        server.add_host(slow)
        server.submit_job(
            Job(id=next_id("job"), app_name="a", est_flop_count=1e15, delay_bound=60.0)
        )
        server.tick(0.0)
        reply = server.rpc(req(slow.id), 0.0)
        assert not reply.jobs

    def test_keyword_no_filtered(self):
        server = make_server()
        host = server.add_host(make_host())
        server.submit_job(
            Job(id=next_id("job"), app_name="a", est_flop_count=1e9, keywords=("biomedicine",))
        )
        server.tick(0.0)
        reply = server.rpc(
            req(host.id, keyword_prefs=KeywordPrefs.make(no=["biomedicine"])), 0.0
        )
        assert not reply.jobs

    def test_locality_scheduling_prefers_resident_files(self):
        server = make_server(locality=True)
        host = server.add_host(make_host())
        j_far = server.submit_job(
            Job(id=next_id("job"), app_name="a", est_flop_count=1e9, input_files=("f_other",))
        )
        j_near = server.submit_job(
            Job(id=next_id("job"), app_name="a", est_flop_count=1e9, input_files=("f_mine",))
        )
        server.tick(0.0)
        reply = server.rpc(
            req(host.id, runtime=1.0, idle=1.0, sticky_files=("f_mine",)), 0.0
        )
        assert reply.jobs[0].job.id == j_near.id

    def test_hr_class_locked_after_first_dispatch(self):
        server = make_server(hr_level=HRLevel.COARSE)
        server.store.apps["a"].min_quorum = 2
        win = server.add_host(make_host(1, "windows"))
        linux = server.add_host(make_host(2, "linux"))
        job = server.submit_job(
            Job(id=next_id("job"), app_name="a", est_flop_count=1e9, min_quorum=2, init_ninstances=2)
        )
        server.tick(0.0)
        r1 = server.rpc(req(win.id), 0.0)
        assert r1.jobs
        assert job.hr_class is not None
        r2 = server.rpc(req(linux.id), 1.0)
        assert not r2.jobs  # different equivalence class

    def test_completed_report_updates_instance(self):
        server = make_server()
        host = server.add_host(make_host())
        job = server.submit_job(Job(id=next_id("job"), app_name="a", est_flop_count=1e9))
        server.tick(0.0)
        r1 = server.rpc(req(host.id), 0.0)
        inst = r1.jobs[0].instance
        server.rpc(
            ScheduleRequest(
                host_id=host.id,
                completed=[
                    CompletedResult(
                        instance_id=inst.id,
                        outcome=InstanceOutcome.SUCCESS,
                        runtime=100.0,
                        peak_flop_count=1e12,
                        output=1.0,
                    )
                ],
            ),
            10.0,
        )
        assert inst.state == InstanceState.OVER
        assert inst.outcome == InstanceOutcome.SUCCESS
        server.tick(11.0)
        assert job.canonical_instance_id is not None


class TestFeeder:
    def test_feeder_interleaves_apps(self):
        reset_ids()
        server = ProjectServer(name="p", cache_size=8, purge_delay=1e18)
        for name in ("a", "b"):
            app = App(name=name, min_quorum=1, init_ninstances=1)
            app.add_version(
                AppVersion(
                    id=next_id("appver"),
                    app_name=name,
                    platform=Platform("windows", "x86_64"),
                    version_num=1,
                    plan_class=default_cpu_plan_class(),
                )
            )
            server.add_app(app)
        for _ in range(20):
            server.submit_job(Job(id=next_id("job"), app_name="a", est_flop_count=1e9))
            server.submit_job(Job(id=next_id("job"), app_name="b", est_flop_count=1e9))
        server.tick(0.0)
        apps_in_cache = {s.app_name for s in server.feeder.slots if s is not None}
        assert apps_in_cache == {"a", "b"}  # category diversity (§5.1)


# ---------------------------------------------------------------------------
# client: WRR simulation, EDF, work fetch (§6.1–6.2)
# ---------------------------------------------------------------------------


def make_client(ncpus=2, flops=1e9):
    c = Client(
        host_id=1,
        resources={ResourceType.CPU: ClientResource(ResourceType.CPU, ncpus, flops)},
        prefs=ClientPrefs(buffer_lo_days=0.1, buffer_hi_days=0.5),
    )
    c.attach(ProjectAttachment(name="p"))
    return c


def cjob(iid, est_s=3600.0, deadline=1e9, cpus=1.0, project="p"):
    return ClientJob(
        instance_id=iid,
        job_id=iid,
        project=project,
        app_name="a",
        usage={ResourceType.CPU: cpus},
        est_flops=1e9,
        est_flop_count=est_s * 1e9,
        deadline=deadline,
    )


class TestClientScheduling:
    def test_maximal_feasible_set(self):
        c = make_client(ncpus=2)
        c.jobs = [cjob(1), cjob(2), cjob(3)]
        running = c.schedule(0.0)
        assert len(running) == 2  # 2 CPUs

    def test_edf_override_on_predicted_miss(self):
        c = make_client(ncpus=1)
        # urgent job queued behind a long job
        c.jobs = [cjob(1, est_s=10 * 3600, deadline=1e9), cjob(2, est_s=3600, deadline=2 * 3600.0)]
        running = c.schedule(0.0)
        assert running[0].instance_id == 2  # deadline-miss job runs first EDF

    def test_ram_constraint(self):
        c = make_client(ncpus=4)
        c.ram_bytes = 1e9
        j1, j2 = cjob(1), cjob(2)
        j1.est_wss = 0.8e9
        j2.est_wss = 0.8e9
        c.jobs = [j1, j2]
        running = c.schedule(0.0)
        assert len(running) == 1  # both don't fit in RAM

    def test_wrr_shortfall_empty_queue(self):
        c = make_client(ncpus=2)
        sim = wrr_simulate([], c.resources, {}, c.prefs, 0.0)
        full = c.prefs.b_hi * 2
        assert sim.shortfall[ResourceType.CPU] == pytest.approx(full)
        assert sim.idle_instances[ResourceType.CPU] == 2

    def test_work_fetch_targets_highest_priority_project(self):
        c = make_client()
        c.attach(ProjectAttachment(name="q", resource_share=300.0))
        # make p over-served so q has higher priority
        c.rec.debit("p", 1e5, 0.0)
        wr = c.choose_fetch_project(1.0)
        assert wr is not None and wr.project == "q"
        assert wr.requests[ResourceType.CPU].req_runtime > 0

    def test_no_fetch_when_buffer_full(self):
        c = make_client(ncpus=1)
        c.jobs = [cjob(i, est_s=100 * 3600) for i in range(1, 4)]
        assert c.choose_fetch_project(0.0) is None

    def test_backoff_blocks_fetch(self):
        c = make_client()
        c.projects["p"].backoff_for(ResourceType.CPU).register_failure(0.0)
        assert c.choose_fetch_project(1.0) is None  # only project is backed off

    def test_report_batching_and_deadline_flush(self):
        c = make_client()
        done = cjob(1, deadline=10_000.0)
        done.state = RunState.DONE
        c.completed = [done]
        assert not c.should_report("p", 0.0)  # defer: batch of 1, far deadline
        assert c.should_report("p", 9_500.0)  # deadline approaching
        c.completed = [cjob(i, deadline=1e9) for i in range(4)]
        assert c.should_report("p", 0.0)  # batch threshold

    def test_am_attach_detach(self):
        c = make_client()
        c.jobs = [cjob(1)]
        c.apply_am_reply([ProjectAttachment(name="new")], ["p"], 0.0)
        assert "new" in c.projects and "p" not in c.projects
        assert not c.jobs  # p's jobs abandoned (§2.3)


class TestTrickleUp:
    """Trickle-up messages (§3.5): immediate server-side handling."""

    def test_custom_handler_invoked(self):
        from repro.core.scheduler import TrickleUp

        server = make_server()
        host = server.add_host(make_host())
        server.submit_job(Job(id=next_id("job"), app_name="a", est_flop_count=1e12))
        server.tick(0.0)
        r = server.rpc(req(host.id), 0.0)
        inst = r.jobs[0].instance
        got = []
        server.trickle_handlers["a"] = lambda i, t, now: got.append((i.id, t.fraction_done))
        server.rpc(
            ScheduleRequest(
                host_id=host.id,
                trickles=[TrickleUp(instance_id=inst.id, fraction_done=0.5)],
            ),
            10.0,
        )
        assert got == [(inst.id, 0.5)]

    def test_default_handler_grants_partial_credit(self):
        from repro.core.scheduler import TrickleUp

        server = make_server()
        host = server.add_host(make_host())
        server.submit_job(Job(id=next_id("job"), app_name="a", est_flop_count=86400.0 * 1e9))
        server.tick(0.0)
        r = server.rpc(req(host.id), 0.0)
        inst = r.jobs[0].instance
        server.rpc(
            ScheduleRequest(
                host_id=host.id,
                trickles=[TrickleUp(instance_id=inst.id, fraction_done=0.25)],
            ),
            10.0,
        )
        key = f"host:{host.id}:partial"
        assert server.credit.total.get(key, 0.0) == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# client accounting bugfix regressions (PR 3)
# ---------------------------------------------------------------------------


class TestClientAccountingFixes:
    def test_simulator_debits_rec_and_priorities_diverge(self):
        """Regression: GridSimulation._advance_running bypassed rec.debit,
        freezing §6.1 project priorities at their initial resource-share
        values. With the fix, the served project's balance is drawn down:
        despite a 3× larger share, its priority ends *below* an idle
        project's."""
        from repro.core.simulator import GridSimulation, make_population

        reset_ids()
        server = ProjectServer(name="p", purge_delay=1e18)
        app = App(name="a", min_quorum=1, init_ninstances=1, delay_bound=6 * 3600.0)
        for osn in ("windows", "mac", "linux"):
            app.add_version(
                AppVersion(
                    id=next_id("appver"),
                    app_name="a",
                    platform=Platform(osn, "x86_64"),
                    version_num=1,
                    plan_class=default_cpu_plan_class(),
                )
            )
        server.add_app(app)
        for _ in range(40):
            server.submit_job(
                Job(id=next_id("job"), app_name="a", est_flop_count=4e13), 0.0
            )
        pop = make_population(2, seed=1)
        sim = GridSimulation(server, pop, seed=1)
        # attach a second, idle project with a *smaller* share to every host
        for c in sim.clients.values():
            c.attach(ProjectAttachment(name="other", resource_share=100.0 / 3.0))
        sim.run(8 * 3600.0)
        busy = [c for c in sim.clients.values()
                if c.rec.accounts["p"].total_used > 0.0]
        assert busy, "no host ever ran work"
        for c in busy:
            prio = c.project_priorities(sim.now)
            # without debiting, p (3x the share => 3x the accrual rate)
            # would always outrank the idle project
            assert prio["p"] < prio["other"]

    def test_wrr_pending_rebuild_by_instance_id(self):
        """Regression: the per-event pending rebuild used `j not in done_now`
        (O(n^2) list membership through dataclass __eq__). Distinct job
        objects with equal fields must still all be simulated: both
        contribute queue duration and finish."""
        c = make_client(ncpus=2)
        twin_a = cjob(1, est_s=3600.0, deadline=1e9)
        twin_b = cjob(2, est_s=3600.0, deadline=1e9)
        # make the *non-identity* fields equal; ids differ so remaining-time
        # bookkeeping stays per-job
        sim = wrr_simulate(
            [twin_a, twin_b], c.resources, {"p": 0.0}, c.prefs, 0.0
        )
        assert sim.deadline_misses == []
        assert sim.queue_dur[ResourceType.CPU] == pytest.approx(7200.0)
        # a long queue with equal-field jobs terminates in O(events) and
        # leaves no job unsimulated
        jobs = [cjob(i, est_s=600.0, deadline=1e9) for i in range(40)]
        sim = wrr_simulate(jobs, c.resources, {"p": 0.0}, c.prefs, 0.0)
        assert sim.deadline_misses == []
        assert sim.queue_dur[ResourceType.CPU] == pytest.approx(40 * 600.0)

    def test_detach_purges_completed_reported_and_rec(self):
        """Regression: detach leaked the project's completed /
        reported_pending entries and its REC allocator row (which kept
        accruing balance and skewing the remaining projects' priorities)."""
        c = make_client()
        c.attach(ProjectAttachment(name="q", resource_share=300.0))
        done_p = cjob(1)
        done_p.state = RunState.DONE
        done_q = cjob(2, project="q")
        done_q.state = RunState.DONE
        c.completed = [done_p, done_q]
        c.reported_pending = [cjob(3), cjob(4, project="q")]
        c.jobs = [cjob(5), cjob(6, project="q")]
        c.detach("p")
        assert "p" not in c.projects
        assert [j.project for j in c.completed] == ["q"]
        assert [j.project for j in c.reported_pending] == ["q"]
        assert all(j.project == "q" for j in c.jobs)
        assert "p" not in c.rec.accounts
        # the remaining project re-absorbs the freed resource share
        assert c.rec.accounts["q"].rate == pytest.approx(1.0)

    def test_should_report_window_is_relative(self):
        """Regression: the report-batching deadline test compared against
        0.1 x the *absolute* virtual-time deadline, so late in long runs
        every completion reported immediately (§6.2 batching silently
        degraded). The window must derive from the job's own deadline
        allowance."""
        c = make_client()
        late = 2_000_000.0  # deep into a long simulation
        done = cjob(1, deadline=late + 86400.0)
        done.state = RunState.DONE
        done.received_time = late
        c.completed = [done]
        # old behaviour: (soonest - now) < 0.1 * soonest  =>  report now
        assert (done.deadline - late) < 0.1 * done.deadline
        assert not c.should_report("p", late)  # fixed: batch, deadline is far
        # the relative window still flushes near the deadline
        assert c.should_report("p", done.deadline - 3600.0)
        window = max(3600.0, 0.1 * 86400.0)
        assert c.should_report("p", done.deadline - window + 1.0)
        assert not c.should_report("p", done.deadline - window - 1.0)
