"""Sharding-rule resolution + a multi-device subprocess correctness check."""
import json
import os
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import ShardingRules


def rules_16x16():
    return ShardingRules(
        mesh_axes=("data", "model"),
        mesh_shape={"data": 16, "model": 16},
        rules={
            "batch": ("pod", "data"),
            "heads": ("model",),
            "kv_heads": ("model",),
            "embed": ("data",),
            "vocab": ("model",),
            "seq": ("model",),
        },
    )


class TestRules:
    def test_divisible_dims_shard(self):
        r = rules_16x16()
        assert r.spec_for((256, 4096), ("batch", "seq")) == P("data", "model")

    def test_indivisible_dims_replicate(self):
        r = rules_16x16()
        # 8 kv heads cannot shard over model=16 -> None
        assert r.spec_for((256, 4096, 8, 128), ("batch", "seq", "kv_heads", None)) == P(
            "data", "model", None, None
        )

    def test_missing_mesh_axis_skipped(self):
        r = rules_16x16()
        # "pod" not in the mesh: batch falls through to "data"
        assert r.spec_for((32,), ("batch",)) == P("data")

    def test_axis_used_once(self):
        r = rules_16x16()
        spec = r.spec_for((4096, 4096), ("seq", "heads"))  # both want "model"
        assert spec == P("model", None)

    def test_none_axes(self):
        r = rules_16x16()
        assert r.spec_for((5, 7), (None, None)) == P(None, None)


@pytest.mark.slow
def test_multidevice_train_step_matches_single_device():
    """Spawn a subprocess with 8 fake devices; the sharded train step must
    produce the same loss as the single-device run here."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, %r)
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_mesh
        from repro.models.config import ShapeConfig
        from repro.models import model_spec, init_params
        from repro.optim import init_state
        from repro.runtime.step_builder import build_step
        from repro.data import DataConfig, global_batch

        cfg = get_smoke_config("qwen3-0.6b").scaled(dtype=jnp.float32, remat=False)
        mesh = make_mesh((4, 2), ("data", "model"))
        shape = ShapeConfig("t", 64, 8, "train")
        bundle = build_step(cfg, shape, mesh, donate=False)
        params = init_params(jax.random.PRNGKey(0), model_spec(cfg))
        opt = init_state(params)
        dc = DataConfig(vocab=cfg.vocab, seq_len=64, batch_size=8, seed=3)
        batch = {k: jnp.asarray(v) for k, v in global_batch(dc, 0).items()}
        _, _, metrics = bundle(params, opt, batch)
        print(json.dumps({"loss": float(metrics["loss"])}))
        """
        % os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=480
    )
    assert out.returncode == 0, out.stderr[-2000:]
    sharded_loss = json.loads(out.stdout.strip().splitlines()[-1])["loss"]

    # single-device reference
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.data import DataConfig, global_batch
    from repro.models import init_params, model_spec, train_loss

    cfg = get_smoke_config("qwen3-0.6b").scaled(dtype=jnp.float32, remat=False)
    params = init_params(jax.random.PRNGKey(0), model_spec(cfg))
    dc = DataConfig(vocab=cfg.vocab, seq_len=64, batch_size=8, seed=3)
    batch = {k: jnp.asarray(v) for k, v in global_batch(dc, 0).items()}
    ref_loss, _ = train_loss(params, cfg, batch)
    assert abs(sharded_loss - float(ref_loss)) < 5e-3, (sharded_loss, float(ref_loss))
