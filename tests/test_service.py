"""Asyncio service layer (§5.1): wire codec, malformed-frame rejection, and
the coalescing TCP front end-to-end.

Codec *property* round-trips live in ``test_protocol_property.py`` (behind
the hypothesis importorskip); this file pins deterministic examples, every
rejection code, and the asyncio service against a real project server.
"""
import asyncio

import pytest

from repro.core import (
    App,
    AppVersion,
    CompletedResult,
    Host,
    InstanceOutcome,
    Job,
    Platform,
    ProcessingResource,
    ProjectServer,
    ResourceRequest,
    ResourceType,
    ScheduleRequest,
    default_cpu_plan_class,
    next_id,
    reset_ids,
)
from repro.core.scheduler import TrickleUp
from repro.service import (
    MAX_LINE,
    ErrorReply,
    JobOffer,
    PingRequest,
    PongReply,
    ProtocolError,
    SchedulerService,
    StatsReply,
    StatsRequest,
    WorkReply,
    WorkRequest,
    decode_reply,
    decode_request,
    encode_reply,
    encode_request,
    run_load,
)

OSES = ("windows", "mac", "linux")


# ---------------------------------------------------------------------------
# codec: deterministic examples
# ---------------------------------------------------------------------------


class TestCodecExamples:
    def test_ping_stats_roundtrip(self):
        for req in (PingRequest(seq=7), StatsRequest(seq=0)):
            assert decode_request(encode_request(req)) == req
        for rep in (PongReply(seq=7), StatsReply(seq=3, values={"a b": 1.5})):
            assert decode_reply(encode_reply(rep)) == rep

    def test_work_request_roundtrip_full(self):
        sched = ScheduleRequest(
            host_id=42,
            requests={
                ResourceType.CPU: ResourceRequest(500.0, 1, 80.5),
                ResourceType.GPU: ResourceRequest(1000.0, 0, 0.0),
            },
            completed=[
                CompletedResult(
                    instance_id=9,
                    outcome=InstanceOutcome.SUCCESS,
                    runtime=123.456,
                    peak_flop_count=1e12,
                    exit_code=0,
                ),
                CompletedResult(
                    instance_id=10,
                    outcome=InstanceOutcome.CLIENT_ERROR,
                    exit_code=-9,
                ),
            ],
            trickles=[TrickleUp(instance_id=9, fraction_done=0.25)],
            sticky_files=("a b.dat", "comma,colon:.bin", "uni⊕code"),
            usable_disk=5e11,
        )
        wire = encode_request(WorkRequest(seq=3, request=sched))
        back = decode_request(wire)
        assert isinstance(back, WorkRequest)
        assert back.seq == 3
        assert back.request == sched

    def test_work_reply_roundtrip(self):
        rep = WorkReply(
            seq=11,
            request_delay=6.5,
            jobs=[JobOffer(1, 2, 3, 100.25, 1e12)],
            delete_sticky=["old file.dat"],
        )
        assert decode_reply(encode_reply(rep)) == rep

    def test_error_reply_roundtrip(self):
        rep = ErrorReply(seq=0, code="bad-frame", message="what is this?")
        assert decode_reply(encode_reply(rep)) == rep

    def test_float_fidelity_and_nonfinite(self):
        # repr/float is the identity on doubles, inf included
        vals = (0.1 + 0.2, 1e-308, float("inf"), -0.0)
        sched = ScheduleRequest(
            host_id=1,
            requests={ResourceType.CPU: ResourceRequest(vals[0], vals[1], vals[2])},
            usable_disk=vals[3],
        )
        back = decode_request(encode_request(WorkRequest(seq=1, request=sched)))
        rr = back.request.requests[ResourceType.CPU]
        assert (rr.req_runtime, rr.req_idle, rr.queue_dur) == vals[:3]
        assert str(back.request.usable_disk) == "-0.0"


class TestMalformedFrames:
    @pytest.mark.parametrize(
        "line,code",
        [
            ("", "bad-frame"),
            ("PING", "bad-frame"),
            ("PING x", "bad-int"),
            ("NOPE 1", "bad-verb"),
            ("PING 1 extra", "bad-field"),
            ("STATS 1 v=1", "bad-field"),
            ("WORK 1 host=1", "bad-field"),  # missing disk
            ("WORK 1 disk=0.0", "bad-field"),  # missing host
            ("WORK 1 host=abc disk=0.0", "bad-int"),
            ("WORK 1 host=1 disk=abc", "bad-float"),
            ("WORK 1 host=1 disk=0.0 host=2", "bad-field"),  # duplicate key
            ("WORK 1 host=1 disk=0.0 bogus=3", "bad-field"),
            ("WORK 1 host=1 disk=0.0 cpu=1.0:2.0", "bad-field"),  # 3 cols
            ("WORK 1 host=1 disk=0.0 done=", "bad-field"),  # empty list
            ("WORK 1 host=1 disk=0.0 done=1:2:3", "bad-field"),  # 5 cols
            ("WORK 1 host=1 disk=0.0 done=1:weird:0.0:0.0:0", "bad-field"),
            ("WORK 1 host=1 disk=0.0 trickle=1", "bad-field"),
            ("W" * (MAX_LINE + 1), "too-long"),
        ],
    )
    def test_request_rejection(self, line, code):
        with pytest.raises(ProtocolError) as e:
            decode_request(line)
        assert e.value.code == code

    @pytest.mark.parametrize(
        "line,code",
        [
            ("WAT 1", "bad-verb"),
            ("JOBS 1", "bad-field"),  # missing delay
            ("JOBS 1 delay=x", "bad-float"),
            ("JOBS 1 delay=0.0 job=1:2:3", "bad-field"),
            ("ERR 1 code", "bad-field"),  # missing message
            ("PONG 1 extra", "bad-field"),
        ],
    )
    def test_reply_rejection(self, line, code):
        with pytest.raises(ProtocolError) as e:
            decode_reply(line)
        assert e.value.code == code


# ---------------------------------------------------------------------------
# the asyncio service end-to-end
# ---------------------------------------------------------------------------


def _make_project(n_sched=4, vector=True, cache_size=48, n_jobs=200, n_hosts=64):
    reset_ids()
    server = ProjectServer(
        name="svc",
        cache_size=cache_size,
        n_scheduler_instances=n_sched,
        vector_dispatch=vector,
    )
    app = App(name="a", min_quorum=1, init_ninstances=1)
    for osn in OSES:
        app.add_version(
            AppVersion(
                id=next_id("appver"),
                app_name="a",
                platform=Platform(osn, "x86_64"),
                version_num=1,
                plan_class=default_cpu_plan_class(),
            )
        )
    server.add_app(app)
    for _ in range(n_jobs):
        server.submit_job(
            Job(id=next_id("job"), app_name="a", est_flop_count=1e12), 0.0
        )
    for i in range(n_hosts):
        server.add_host(
            Host(
                id=i + 1,
                platforms=(Platform(OSES[i % 3], "x86_64"),),
                resources={
                    ResourceType.CPU: ProcessingResource(ResourceType.CPU, 4, 2e10)
                },
                volunteer_id=i + 1,
            )
        )
    server.tick(0.0)
    return server


class TestSchedulerService:
    def test_coalesced_load(self):
        server = _make_project()

        async def main():
            svc = SchedulerService(server, coalesce=True, max_batch=256)
            await svc.start()
            try:
                report = await run_load(
                    "127.0.0.1", svc.port, n_clients=200, n_conns=16,
                    host_ids=list(range(1, 65)),
                )
            finally:
                await svc.stop()
            return report, svc.stats()

        report, stats = asyncio.run(main())
        assert report.replies == report.requests == 200
        assert report.errors == 0
        assert report.jobs_received > 0
        assert stats["requests"] == 200
        # concurrent clients actually coalesced into rpc_batch waves
        assert stats["max_wave"] > 1
        assert stats["waves"] < 200
        # the sharded project reports per-shard utilization
        shard_reqs = [row["requests"] for row in stats["shards"]]
        assert sum(shard_reqs) == 200
        assert all(r > 0 for r in shard_reqs)

    def test_sequential_baseline_mode(self):
        server = _make_project(n_sched=1, vector=False)

        async def main():
            svc = SchedulerService(server, coalesce=False)
            await svc.start()
            try:
                report = await run_load("127.0.0.1", svc.port, n_clients=30,
                                        n_conns=4)
            finally:
                await svc.stop()
            return report

        report = asyncio.run(main())
        assert report.replies == 30
        assert report.errors == 0
        assert report.jobs_received > 0

    def test_ping_stats_and_error_frames_inline(self):
        server = _make_project(n_sched=1, n_jobs=10, n_hosts=4)

        async def main():
            svc = SchedulerService(server)
            await svc.start()
            try:
                reader, writer = await asyncio.open_connection(
                    "127.0.0.1", svc.port
                )
                writer.write(b"PING 5\n")
                writer.write(b"this is not a frame\n")  # ERR, conn survives
                writer.write(b"STATS 6\n")
                await writer.drain()
                lines = [await reader.readline() for _ in range(3)]
                writer.close()
            finally:
                await svc.stop()
            return [decode_reply(l.decode().rstrip("\n")) for l in lines]

        pong, err, stats = asyncio.run(main())
        assert pong == PongReply(seq=5)
        assert isinstance(err, ErrorReply) and err.code == "bad-int"
        assert isinstance(stats, StatsReply)
        assert stats.values["errors"] == 1.0

    def test_work_frame_reports_completions(self):
        # a done= report flows through the real scheduler: the instance
        # leaves IN_PROGRESS and the reply still offers new work
        server = _make_project(n_sched=2, n_jobs=40, n_hosts=8)

        async def main():
            svc = SchedulerService(server)
            await svc.start()
            try:
                reader, writer = await asyncio.open_connection(
                    "127.0.0.1", svc.port
                )

                async def ask(seq, host_id, done=""):
                    line = f"WORK {seq} host={host_id} disk=1e+15 cpu=3000.0:1.0:0.0"
                    if done:
                        line += f" done={done}"
                    writer.write((line + "\n").encode())
                    await writer.drain()
                    return decode_reply((await reader.readline()).decode().rstrip("\n"))

                first = await ask(1, 2)
                assert first.jobs
                inst = first.jobs[0].instance_id
                second = await ask(
                    2, 2, done=f"{inst}:success:120.0:1e+12:0"
                )
                writer.close()
            finally:
                await svc.stop()
            return inst, second

        inst_id, second = asyncio.run(main())
        assert isinstance(second, WorkReply)
        inst = server.store.instances[inst_id]
        assert not inst.is_outstanding()
