"""Coordinated VC model (§10.1): keyword assignment + guaranteed shares,
and the elastic-reconfiguration integration path (checkpoint -> smaller
mesh plan -> resume)."""
import tempfile

import pytest

from repro.core.coordinator import AMReply, Coordinator, VettedProject
from repro.core.keywords import KeywordPrefs
from repro.core.types import ResourceType


class TestCoordinator:
    def make(self):
        co = Coordinator()
        co.vet_project(VettedProject("einstein", keywords=("astrophysics",), share=2.0))
        co.vet_project(VettedProject("rosetta", keywords=("biomedicine",), share=1.0))
        co.vet_project(VettedProject("climate", keywords=("climate",), share=1.0))
        return co

    def test_no_keyword_never_assigned(self):
        co = self.make()
        co.register_volunteer(1, KeywordPrefs.make(no=["biomedicine"]))
        assert "rosetta" not in co.eligible_projects(1)

    def test_yes_keyword_preferred(self):
        co = self.make()
        co.register_volunteer(1, KeywordPrefs.make(yes=["physics"]))
        assert co.eligible_projects(1)[0] == "einstein"

    def test_am_rpc_attaches_and_switches(self):
        co = self.make()
        co.register_volunteer(1, KeywordPrefs())
        r1 = co.am_rpc(host_id=10, volunteer_id=1, now=100.0)
        assert len(r1.attach) == 1
        seen = {r1.attach[0].name}
        # heavy usage burns each assignment's balance: the AM must rotate
        # the host across projects (detaching the previous one each time)
        for t in range(1, 40):
            r = co.am_rpc(10, 1, now=100.0 + t * 600.0, used_seconds=50_000.0)
            if r.attach:
                assert r.detach  # switching always detaches the old project
                seen.add(r.attach[0].name)
        assert len(seen) >= 2, "linear-bounded balances never rotated the host"
        assert all(a.total_used > 0 for a in co.allocator.accounts.values())

    def test_forget_host_purges_assignment(self):
        """Churn hygiene (the reprolint purge-complete contract): a departed
        host must vanish from the coordinator's per-host state — before the
        fix, ``attached_hosts`` reported churned hosts forever."""
        co = self.make()
        co.register_volunteer(1, KeywordPrefs())
        r = co.am_rpc(host_id=10, volunteer_id=1, now=0.0)
        project = r.attach[0].name
        assert 10 in co.attached_hosts(project)

        was = co.forget_host(10)
        assert was == project
        assert 10 not in co.assignments
        assert 10 not in co.attached_hosts(project)
        # idempotent; unknown hosts are a no-op
        assert co.forget_host(10) is None
        assert co.forget_host(999) is None
        # the volunteer survives host churn (§2.3): prefs stay, and a new
        # host of the same volunteer can still be assigned
        assert 1 in co.volunteer_prefs
        r2 = co.am_rpc(host_id=11, volunteer_id=1, now=0.0)
        assert r2.attach
        # account deletion drops the prefs too
        co.forget_volunteer(1)
        assert 1 not in co.volunteer_prefs

    def test_forget_host_rebalances_future_assignment(self):
        """After a heavy-usage host departs, its project's burned balance
        stays debited, but no phantom row skews attached_hosts-based views."""
        co = self.make()
        co.register_volunteer(1, KeywordPrefs())
        co.am_rpc(10, 1, now=0.0)
        co.am_rpc(10, 1, now=600.0, used_seconds=50_000.0)
        co.forget_host(10)
        assert co.assignments == {}
        # a fresh host assigns normally against the debited balances
        r = co.am_rpc(20, 1, now=1200.0)
        assert r.attach and co.attached_hosts(r.attach[0].name) == [20]

    def test_guaranteed_share_before_any_volunteers(self):
        """§10.1: 'a prospective new project can be guaranteed a certain
        amount of computing power before any investment is made'."""
        co = self.make()
        assert co.guaranteed_share("einstein") == pytest.approx(0.5)
        co.vet_project(VettedProject("new-project", keywords=("machine_learning",), share=4.0))
        assert co.guaranteed_share("new-project") == pytest.approx(0.5)

    def test_share_drives_long_term_assignment_mix(self):
        co = Coordinator()
        co.vet_project(VettedProject("big", keywords=("physics",), share=3.0))
        co.vet_project(VettedProject("small", keywords=("physics",), share=1.0))
        for v in range(20):
            co.register_volunteer(v, KeywordPrefs())
        # simulate periodic AM RPCs with usage reporting
        counts = {"big": 0.0, "small": 0.0}
        now = 0.0
        for step in range(200):
            now += 600.0
            for host in range(20):
                r = co.am_rpc(host, host, now, used_seconds=600.0 / 20)
            for host, proj in co.assignments.items():
                counts[proj] += 1
        frac_big = counts["big"] / (counts["big"] + counts["small"])
        assert 0.55 <= frac_big <= 0.95  # ~3:1 share target, coarse check


class TestElasticIntegration:
    def test_checkpoint_then_smaller_mesh_resume(self):
        """Churn half the fleet: plan a smaller mesh, restore the checkpoint,
        keep training — the fleet-level restart path (DESIGN §5)."""
        import jax.numpy as jnp

        from repro.configs import get_smoke_config
        from repro.data import DataConfig
        from repro.distributed import plan_elastic_config
        from repro.optim import AdamWConfig
        from repro.runtime import train

        cfg = get_smoke_config("qwen3-0.6b").scaled(n_layers=2, d_model=64)
        dc = DataConfig(vocab=cfg.vocab, seq_len=32, batch_size=4, seed=1)
        oc = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
        with tempfile.TemporaryDirectory() as d:
            r1 = train(cfg, dc, oc, steps=6, checkpoint_dir=d, checkpoint_period=3,
                       log_every=0)
            # "churn": 256 -> 128 live chips; the planner must keep the
            # global batch by doubling accumulation or halving microbatch
            plan = plan_elastic_config(live_chips=128, global_batch=256, model_axis=16)
            assert plan is not None
            assert plan.mesh_shape[0] * plan.microbatch_per_worker * plan.grad_accum_steps == 256
            # resume from checkpoint and continue
            r2 = train(cfg, dc, oc, steps=10, checkpoint_dir=d, checkpoint_period=3,
                       log_every=0)
            assert r2.restored_from == 6
            assert r2.final_loss < r1.losses[0]
