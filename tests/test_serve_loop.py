"""Serving-loop satellites: heap-based EDF admission and the on-device
single-slot cache merge.

The admission queue must pop earliest-deadline-first with FIFO tie order —
exactly what the old stable ``list.sort`` + ``pop(0)`` produced — and
``_merge_slot`` must write only the target slot without pulling any cache
leaf to the host (pinned by running it under ``jax.jit``, where a host
round-trip raises ``TracerArrayConversionError``).
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.runtime.serve_loop import AdmissionQueue, Request, _merge_slot  # noqa: E402


def _req(rid, deadline):
    return Request(id=rid, prompt=np.zeros((4,), np.int32), deadline=deadline)


class TestAdmissionQueue:
    def test_pops_earliest_deadline_first(self):
        q = AdmissionQueue()
        for rid, dl in ((1, 30.0), (2, 10.0), (3, float("inf")), (4, 20.0)):
            q.push(_req(rid, dl))
        assert [q.pop().id for _ in range(len(q))] == [2, 4, 1, 3]

    def test_deadline_ties_pop_fifo(self):
        # the old implementation was a *stable* sort: equal deadlines kept
        # submission order; the heap's monotone sequence number pins that
        q = AdmissionQueue()
        for rid in range(1, 7):
            q.push(_req(rid, 5.0))
        assert [q.pop().id for _ in range(len(q))] == [1, 2, 3, 4, 5, 6]

    def test_interleaved_push_pop(self):
        q = AdmissionQueue()
        q.push(_req(1, 50.0))
        q.push(_req(2, 10.0))
        assert q.pop().id == 2
        q.push(_req(3, 5.0))
        q.push(_req(4, 60.0))
        assert [q.pop().id for _ in range(len(q))] == [3, 1, 4]
        assert len(q) == 0 and not q


class TestMergeSlot:
    def _trees(self, slots=4, seq=8):
        # attention-style (L, B, S, H) + SSM-style (L, B, H) + a leaf with
        # identical shapes (merge must leave it untouched)
        batch = {
            "attn": jnp.arange(2 * slots * seq * 3, dtype=jnp.float32).reshape(
                2, slots, seq, 3
            ),
            "ssm": jnp.ones((2, slots, 5), jnp.float32),
            "step": jnp.zeros((2,), jnp.int32),
        }
        one = {
            "attn": -jnp.ones((2, 1, seq, 3), jnp.float32),
            "ssm": 7.0 * jnp.ones((2, 1, 5), jnp.float32),
            "step": jnp.zeros((2,), jnp.int32),
        }
        return batch, one

    def test_writes_only_target_slot(self):
        batch, one = self._trees()
        slot = 2
        merged = _merge_slot(batch, one, slot)
        for key, ax in (("attn", 1), ("ssm", 1)):
            got = np.asarray(merged[key])
            want = np.asarray(batch[key]).copy()
            idx = [slice(None)] * want.ndim
            idx[ax] = slice(slot, slot + 1)
            want[tuple(idx)] = np.asarray(one[key])
            np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(np.asarray(merged["step"]),
                                      np.asarray(batch["step"]))

    def test_traceable_no_host_round_trip(self):
        # np.asarray on a tracer raises TracerArrayConversionError, so a
        # successful jit compile + run proves the merge stays on-device
        batch, one = self._trees()

        @jax.jit
        def merge2(b, o):
            return _merge_slot(b, o, 2)

        merged = merge2(batch, one)
        eager = _merge_slot(batch, one, 2)
        for key in batch:
            np.testing.assert_array_equal(np.asarray(merged[key]),
                                          np.asarray(eager[key]))
