"""Batch validation engine (core/batch_validate.py): parity with the
scalar check_set/credit/reputation oracle, digest contracts, fallback
behaviour, and the validation-pending store index."""
import random

import numpy as np
import pytest

from repro.core import (
    AdaptiveReplication,
    App,
    AppVersion,
    CreditSystem,
    GridSimulation,
    Host,
    InstanceOutcome,
    InstanceState,
    Job,
    JobInstance,
    JobState,
    JobStore,
    Platform,
    ProcessingResource,
    ProjectServer,
    ResourceType,
    Transitioner,
    ValidateState,
    bitwise_digest_batch,
    check_set,
    default_cpu_plan_class,
    digest_batch_for,
    fuzzy_comparator,
    make_population,
    next_id,
    reset_ids,
)


# ---------------------------------------------------------------------------
# store-level twin ticks
# ---------------------------------------------------------------------------


def build_pending(
    n_jobs=200,
    per_job=2,
    quorum=2,
    bad_frac=0.1,
    payload="float",
    comparator="fuzzy",
    batch_validate=True,
    adaptive=True,
    seed=3,
    dim=32,
):
    """A store whose jobs all sit at the validation step."""
    reset_ids()
    rng = random.Random(seed)
    rs = np.random.RandomState(seed)
    store = JobStore()
    cmp = {
        "fuzzy": fuzzy_comparator(rtol=1e-6, atol=1e-9),
        "bitwise": None,
        "badfrac": fuzzy_comparator(rtol=1e-6, atol=1e-9, max_bad_fraction=0.5),
        "custom": lambda a, b: abs(a - b) < 0.5,
    }[comparator]
    app = App(
        name="w",
        min_quorum=quorum,
        init_ninstances=quorum,
        max_success_instances=max(6, per_job + 2),
        comparator=cmp,
    )
    vid = next_id("appver")
    app.add_version(
        AppVersion(
            id=vid,
            app_name="w",
            platform=Platform("linux", "x86_64"),
            version_num=1,
            plan_class=default_cpu_plan_class(),
        )
    )
    store.add_app(app)
    for h in range(40):
        store.add_host(
            Host(
                id=h + 1,
                platforms=(Platform("linux", "x86_64"),),
                resources={
                    ResourceType.CPU: ProcessingResource(ResourceType.CPU, 4, 16.5e9)
                },
                volunteer_id=(h % 30) + 1,  # some hosts share a volunteer
            )
        )
    for _ in range(n_jobs):
        job = Job(
            id=next_id("job"),
            app_name="w",
            est_flop_count=0.2 * 3600 * 16.5e9,
            min_quorum=quorum,
            init_ninstances=quorum,
            max_success_instances=max(6, per_job + 2),
        )
        store.submit_job(job)
        if payload == "float":
            truth = float(job.id) * 1.5
        else:
            truth = rs.standard_normal(dim).astype(np.float32)
        for k in range(per_job):
            inst = store.create_instance(job)
            inst.host_id = rng.randrange(40) + 1
            inst.app_version_id = vid
            inst.state = InstanceState.IN_PROGRESS
            inst.state = InstanceState.OVER
            inst.outcome = InstanceOutcome.SUCCESS
            inst.runtime = 700.0 + rng.random() * 100
            inst.peak_flop_count = inst.runtime * 16.5e9
            if rng.random() < bad_frac:
                if payload == "float":
                    inst.output = truth + rng.uniform(1.0, 2.0)
                else:
                    inst.output = truth + rs.uniform(1, 2, size=dim).astype(np.float32)
            else:
                inst.output = truth
    tr = Transitioner(
        store=store,
        credit=CreditSystem(),
        adaptive=AdaptiveReplication() if adaptive else None,
        batch_validate=batch_validate,
    )
    return store, tr


def snapshot(store, tr):
    return {
        "instances": {
            i: (x.validate_state, x.claimed_credit, x.granted_credit, x.outcome)
            for i, x in store.instances.items()
        },
        "jobs": {
            j: (x.state, x.canonical_instance_id, x.transition_flag)
            for j, x in store.jobs.items()
        },
        "metrics": dict(vars(tr.metrics)),
        "credit_total": dict(tr.credit.total),
        "credit_recent": dict(tr.credit.recent),
        "reputation": tr.adaptive.consecutive_valid if tr.adaptive else None,
    }


def run_twins(**kw):
    """Build scalar/engine twins, tick each right after building (the id
    counters are global), and return both snapshots."""
    sa, ta = build_pending(batch_validate=False, **kw)
    ta.tick(60.0)
    snap_a = snapshot(sa, ta)
    sb, tb = build_pending(batch_validate=True, **kw)
    tb.tick(60.0)
    snap_b = snapshot(sb, tb)
    sb.check_invariants()
    sa.check_invariants()
    return snap_a, snap_b, sa, sb


class TestTickParity:
    """One validate-pass tick through the engine must equal the scalar
    oracle on validate states, canonicals, granted credit (bit-exact),
    metrics, and reputation."""

    @pytest.mark.parametrize(
        "kw",
        [
            dict(),
            dict(per_job=3, quorum=3, bad_frac=0.4),  # contested
            dict(per_job=6, quorum=3, bad_frac=0.5),  # malicious-heavy
            dict(comparator="bitwise"),
            dict(payload="array"),
            dict(payload="array", comparator="bitwise"),
            dict(quorum=1, per_job=1, bad_frac=0.0),  # trusted singletons
            dict(adaptive=False),
        ],
        ids=[
            "steady",
            "contested",
            "malicious",
            "bitwise",
            "tensor",
            "tensor-bitwise",
            "singleton",
            "no-adaptive",
        ],
    )
    def test_tick_identical(self, kw):
        snap_a, snap_b, _, _ = run_twins(**kw)
        assert snap_a == snap_b

    def test_multi_tick_convergence(self):
        """Tie-breakers created by tick 1 are validated by later ticks:
        the whole multi-round cascade must stay identical."""

        def run(batch):
            store, tr = build_pending(
                batch_validate=batch, per_job=2, quorum=2, bad_frac=0.3
            )
            for t in range(5):
                tr.tick(60.0 * (t + 1))
                # completed tie-breakers: report them as agreeing successes
                for job in store.jobs.values():
                    truth = float(job.id) * 1.5
                    for inst in store.job_instances(job.id):
                        if inst.state == InstanceState.UNSENT:
                            inst.host_id = (inst.id % 40) + 1
                            inst.app_version_id = next(iter(store.app_versions))
                            inst.state = InstanceState.IN_PROGRESS
                            inst.state = InstanceState.OVER
                            inst.outcome = InstanceOutcome.SUCCESS
                            inst.runtime = 750.0
                            inst.peak_flop_count = inst.runtime * 16.5e9
                            inst.output = truth
                            job.transition_flag = True
            return store, tr

        sa, ta = run(False)
        snap_a = snapshot(sa, ta)
        sb, tb = run(True)
        snap_b = snapshot(sb, tb)
        assert snap_a == snap_b
        assert any(
            j.state == JobState.SUCCESS for j in sb.jobs.values()
        )  # the cascade actually validated work
        sb.check_invariants()

    def test_sharded_transitioners_identical(self):
        def run(batch):
            store, _ = build_pending(batch_validate=batch, bad_frac=0.3)
            credit = CreditSystem()
            adaptive = AdaptiveReplication()
            trs = [
                Transitioner(
                    store=store,
                    credit=credit,
                    adaptive=adaptive,
                    instance=i,
                    n_instances=2,
                    batch_validate=batch,
                )
                for i in range(2)
            ]
            for tr in trs:
                tr.tick(60.0)
            return store, credit, adaptive

        sa, ca, aa = run(False)
        sb, cb, ab = run(True)
        assert {
            i: (x.validate_state, x.granted_credit) for i, x in sa.instances.items()
        } == {i: (x.validate_state, x.granted_credit) for i, x in sb.instances.items()}
        assert ca.total == cb.total
        assert aa.consecutive_valid == ab.consecutive_valid
        sb.check_invariants()

    def test_scalar_fallback_for_undigestable_comparators(self):
        """Comparators without a digest hook (custom fn, fuzzy with a
        bad-fraction allowance) route through scalar check_set — results
        still identical."""
        for comparator in ("custom", "badfrac"):
            snap_a, snap_b, _, sb = run_twins(comparator=comparator, bad_frac=0.3)
            assert snap_a == snap_b, comparator
            app = sb.apps["w"]
            assert digest_batch_for(app.comparator) is None

    def test_straggler_validates_against_canonical(self):
        """A fresh success reported while the job already has a canonical
        instance takes the §4 straggler path in both engines."""

        def run(batch):
            store, tr = build_pending(
                n_jobs=30, batch_validate=batch, bad_frac=0.0
            )
            tr.tick(60.0)
            vid = next(iter(store.app_versions))
            for j, job in enumerate(store.jobs.values()):
                # forge the state the paper describes: job active again with
                # a canonical present and one late fresh success
                inst = store.create_instance(job)
                inst.host_id = (j % 40) + 1
                inst.app_version_id = vid
                inst.state = InstanceState.IN_PROGRESS
                inst.state = InstanceState.OVER
                inst.outcome = InstanceOutcome.SUCCESS
                inst.runtime = 800.0
                inst.peak_flop_count = inst.runtime * 16.5e9
                inst.output = (
                    float(job.id) * 1.5 if j % 3 else float(job.id) * 1.5 + 1.3
                )
                job.state = JobState.ACTIVE
                job.transition_flag = True
            tr.tick(120.0)
            return store, tr

        sa, ta = run(False)
        snap_a = snapshot(sa, ta)
        sb, tb = run(True)
        snap_b = snapshot(sb, tb)
        assert snap_a == snap_b
        states = [i.validate_state for i in sb.instances.values()]
        assert ValidateState.INVALID in states  # disagreeing stragglers seen


# ---------------------------------------------------------------------------
# whole-simulation twins (the acceptance-criterion parity)
# ---------------------------------------------------------------------------


def make_server(batch_validate, adaptive=False, quorum=2):
    server = ProjectServer(
        name="p", purge_delay=1e18, batch_validate=batch_validate
    )
    app = App(
        name="w",
        min_quorum=quorum,
        init_ninstances=quorum,
        delay_bound=4 * 3600.0,
        adaptive_replication=adaptive,
        comparator=fuzzy_comparator(rtol=1e-6, atol=1e-9),
    )
    for osn in ("windows", "mac", "linux"):
        app.add_version(
            AppVersion(
                id=next_id("appver"),
                app_name="w",
                platform=Platform(osn, "x86_64"),
                version_num=1,
                plan_class=default_cpu_plan_class(),
            )
        )
    server.add_app(app)
    return server


def run_sim(batch_validate, n_jobs=50, n_hosts=12, horizon=2 * 86400.0, **kw):
    reset_ids()
    server = make_server(batch_validate, adaptive=kw.pop("adaptive", False))
    for _ in range(n_jobs):
        server.submit_job(
            Job(id=next_id("job"), app_name="w", est_flop_count=0.2 * 3600 * 16.5e9)
        )
    pop = make_population(n_hosts, seed=1, **kw)
    sim = GridSimulation(server, pop, seed=3)
    m = sim.run(horizon)
    sim.audit_validation()
    return server, sim, m


class TestSimulationParity:
    """Whole-simulation engine-vs-oracle identity: metrics, job validate
    states, and granted credit (the PR acceptance criterion)."""

    @pytest.mark.parametrize(
        "kw",
        [
            dict(),
            dict(error_prob=0.05, malicious_fraction=0.2),
            dict(adaptive=True, error_prob=0.02, malicious_fraction=0.05,
                 horizon=3 * 86400.0),
            dict(availability=0.6, horizon=3 * 86400.0),
        ],
        ids=["clean", "faulty", "adaptive", "intermittent"],
    )
    def test_sim_identical(self, kw):
        srv_b, sim_b, m_b = run_sim(True, **dict(kw))
        srv_s, sim_s, m_s = run_sim(False, **dict(kw))
        assert vars(m_b) == vars(m_s)
        assert {
            i: (x.validate_state, x.claimed_credit, x.granted_credit)
            for i, x in srv_b.store.instances.items()
        } == {
            i: (x.validate_state, x.claimed_credit, x.granted_credit)
            for i, x in srv_s.store.instances.items()
        }
        assert {j: (x.state, x.canonical_instance_id) for j, x in srv_b.store.jobs.items()} == \
               {j: (x.state, x.canonical_instance_id) for j, x in srv_s.store.jobs.items()}
        assert srv_b.credit.total == srv_s.credit.total
        assert srv_b.adaptive.consecutive_valid == srv_s.adaptive.consecutive_valid
        for tb, ts in zip(srv_b.transitioners, srv_s.transitioners):
            assert vars(tb.metrics) == vars(ts.metrics)
        assert m_b.completed_instances > 0  # the scenario did real work


# ---------------------------------------------------------------------------
# digest contracts
# ---------------------------------------------------------------------------


class TestDigests:
    def test_bitwise_float_semantics(self):
        d = bitwise_digest_batch([1.5, 1.5, 2.0, -0.0, 0.0, float("nan"), float("nan")])
        assert d[0] == d[1] != d[2]
        assert d[3] == d[4]  # -0.0 == 0.0 under Python ==
        assert d[5] != d[6]  # NaN equals nothing, itself included

    def test_bitwise_numeric_cross_type(self):
        d = bitwise_digest_batch([1, 1.0, True, 2])
        assert d[0] == d[1] == d[2] != d[3]  # 1 == 1.0 == True

    def test_bitwise_ndarray_one_ulp(self):
        a = np.arange(8, dtype=np.float32)
        b = a.copy()
        b[3] = np.nextafter(b[3], np.float32(10))
        d = bitwise_digest_batch([{"x": a}, {"x": a.copy()}, {"x": b}])
        assert d[0] == d[1] != d[2]

    def test_mix_vector_is_hash_derived_odd_and_deterministic(self):
        """The row-hash multipliers are blake2b-derived constants: odd (so
        each is invertible mod 2^64), stable across calls/processes, and
        built without touching any RNG namespace (rng-discipline)."""
        from repro.core.validator import _mix_cache, _mix_vector

        _mix_cache.pop(7, None)
        a = _mix_vector(7)
        b = _mix_vector(7)
        assert a is b  # cached
        assert a.dtype == np.int64 and a.shape == (7,)
        assert np.all(a % 2 != 0)
        _mix_cache.pop(7, None)
        c = _mix_vector(7)
        assert np.array_equal(a, c)  # re-derivation is bit-identical
        assert len(set(a.tolist())) == 7  # no degenerate repeats

    def test_bitwise_matches_comparator_on_random_payloads(self):
        from repro.core.validator import bitwise_equal

        rng = np.random.RandomState(0)
        outs = [rng.randint(0, 3, size=6).astype(np.float64) for _ in range(40)]
        d = bitwise_digest_batch(outs)
        for i in range(len(outs)):
            for j in range(len(outs)):
                assert (d[i] == d[j]) == bitwise_equal(outs[i], outs[j])

    @pytest.mark.parametrize("rtol,atol", [(1e-6, 1e-9), (0.0, 0.5), (1e-4, 0.0)])
    def test_fuzzy_buckets_follow_comparator(self, rtol, atol):
        """Well-separated-or-identical payloads: digest grouping must agree
        with the pairwise comparator (the documented bucketing contract)."""
        cmp = fuzzy_comparator(rtol=rtol, atol=atol)
        fd = digest_batch_for(cmp)
        base = [0.0, 3.0, 1234.5678, -1234.5678, 7e8]
        outs = []
        for b in base:
            outs += [b, b]  # identical replicas
            outs.append(b + max(10.0 * atol, abs(b) * max(rtol, 1e-9) * 1e3) + 1.0)
        d = fd(outs)
        for i in range(len(outs)):
            for j in range(len(outs)):
                if outs[i] == outs[j]:
                    assert d[i] == d[j]
                elif cmp(outs[i], outs[j]) != cmp(outs[j], outs[i]):
                    continue  # asymmetric edge of isclose: no contract
                elif not cmp(outs[i], outs[j]):
                    assert d[i] != d[j], (outs[i], outs[j])

    def test_fuzzy_matrix_path_matches_scalar_groups(self):
        cmp = fuzzy_comparator(rtol=1e-6, atol=1e-9)
        fd = digest_batch_for(cmp)
        rs = np.random.RandomState(1)
        truth = rs.standard_normal(64).astype(np.float32)
        other = truth + rs.uniform(1, 2, 64).astype(np.float32)
        d = fd([truth, truth.copy(), other, truth.copy(), other.copy()])
        assert d[0] == d[1] == d[3]
        assert d[2] == d[4]
        assert d[0] != d[2]

    def test_fuzzy_nan_and_inf(self):
        fd = digest_batch_for(fuzzy_comparator(rtol=1e-6, atol=1e-9))
        inf = float("inf")
        d = fd([inf, inf, -inf, float("nan"), float("nan")])
        assert d[0] == d[1] != d[2]
        assert d[3] != d[4]  # NaN matches nothing
        # array payloads containing NaN match nothing either
        a = np.array([1.0, np.nan])
        d2 = fd([a, a.copy()])
        assert d2[0] != d2[1]

    def test_digest_hook_absent_for_unsupported_comparators(self):
        assert digest_batch_for(fuzzy_comparator(max_bad_fraction=0.05)) is None
        assert digest_batch_for(lambda a, b: True) is None
        assert digest_batch_for(None) is bitwise_digest_batch


# ---------------------------------------------------------------------------
# array-backed reputation table: batched ops == sequential ops
# ---------------------------------------------------------------------------


class TestAdaptiveBatchOps:
    def test_apply_events_matches_sequential(self):
        rng = random.Random(7)
        for trial in range(60):
            a = AdaptiveReplication(threshold=3, seed=trial)
            b = AdaptiveReplication(threshold=3, seed=trial)
            pre = [
                (rng.randrange(5), rng.randrange(3), rng.random() < 0.8)
                for _ in range(rng.randrange(20))
            ]
            for h, v, ok in pre:
                (a.on_validated if ok else a.on_invalid)(h, v)
                (b.on_validated if ok else b.on_invalid)(h, v)
            ev = [
                (rng.randrange(5), rng.randrange(3), rng.random() < 0.7)
                for _ in range(rng.randrange(1, 30))
            ]
            for h, v, ok in ev:
                (a.on_validated if ok else a.on_invalid)(h, v)
            b.apply_events([e[0] for e in ev], [e[1] for e in ev], [e[2] for e in ev])
            assert a.consecutive_valid == b.consecutive_valid, trial

    def test_should_replicate_batch_consumes_same_stream(self):
        """Batched decisions pop the identical RNG stream as per-call use,
        regardless of how many draws were prefetched."""
        rng = random.Random(1)
        for prefetch in (0, 3, 50):
            a = AdaptiveReplication(threshold=2, seed=9)
            b = AdaptiveReplication(threshold=2, seed=9)
            pairs = [(rng.randrange(4), rng.randrange(2)) for _ in range(30)]
            for h, v in pairs[:10]:
                a.on_validated(h, v)
                b.on_validated(h, v)
            seq = [a.should_replicate(h, v) for h, v in pairs]
            b.prefetch_draws(prefetch)
            assert list(b.should_replicate_batch(
                [p[0] for p in pairs], [p[1] for p in pairs]
            )) == seq

    def test_reputation_gathers(self):
        a = AdaptiveReplication(threshold=10)
        for _ in range(12):
            a.on_validated(1, 7)
        a.on_validated(2, 7)
        reps = a.reputations([1, 2, 99], [7, 7, 7])
        assert list(reps) == [12, 1, 0]  # unknown pairs read 0
        probs = a.replication_probabilities([1, 2, 99], [7, 7, 7])
        assert probs[0] == a.replication_probability(1, 7) < 1.0
        assert probs[1] == probs[2] == 1.0


# ---------------------------------------------------------------------------
# validation-pending index
# ---------------------------------------------------------------------------


class TestValidationPendingIndex:
    def test_index_tracks_fresh_successes(self):
        store, tr = build_pending(n_jobs=10, bad_frac=0.0)
        job_ids = set(store.jobs)
        assert store.pending_validation() == job_ids
        # oracle scan agrees
        store.use_indexes = False
        assert store.pending_validation() == job_ids
        store.use_indexes = True
        # validation consumes the freshness
        tr.tick(60.0)
        assert store.pending_validation() == set()
        store.check_invariants()

    def test_index_sharded(self):
        store, _ = build_pending(n_jobs=10, bad_frac=0.0)
        shard0 = store.pending_validation(0, 2)
        shard1 = store.pending_validation(1, 2)
        assert shard0 | shard1 == set(store.jobs)
        assert not shard0 & shard1

    def test_index_survives_mutation_paths(self):
        store, _ = build_pending(n_jobs=4, bad_frac=0.0)
        inst = next(iter(store.instances.values()))
        # un-succeeding an instance removes freshness
        inst.outcome = InstanceOutcome.CLIENT_ERROR
        store.check_invariants()
        inst.outcome = InstanceOutcome.SUCCESS
        store.check_invariants()
        inst.validate_state = ValidateState.INCONCLUSIVE
        store.check_invariants()
        inst.validate_state = ValidateState.INIT
        job = store.jobs[inst.job_id]
        store.purge_job(job)
        assert job.id not in store.pending_validation()
        store.check_invariants()
