"""Per-architecture smoke tests (reduced same-family configs, one
forward/train step on CPU, shape + finiteness assertions) and decode-cache
consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import (
    SHAPES,
    cell_supported,
    count_params,
    forward,
    init_cache,
    init_params,
    model_spec,
    train_loss,
)

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=64):
    out = {"labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab)}
    if cfg.input_mode == "embeds":
        out["embeds"] = jax.random.normal(KEY, (b, s, cfg.d_model), jnp.float32)
    else:
        out["tokens"] = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    return out


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_and_loss(self, arch):
        cfg = get_smoke_config(arch)
        params = init_params(KEY, model_spec(cfg))
        batch = _batch(cfg)
        loss, metrics = train_loss(params, cfg, batch)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss))
        logits, _, _ = forward(
            params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds")
        )
        assert logits.shape == (2, 64, cfg.padded_vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    def test_train_step_grads_finite(self, arch):
        cfg = get_smoke_config(arch)
        params = init_params(KEY, model_spec(cfg))
        batch = _batch(cfg, b=2, s=32)
        grads = jax.grad(lambda p: train_loss(p, cfg, batch)[0])(params)
        leaves = jax.tree_util.tree_leaves(grads)
        assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32)))) for l in leaves)
        gnorm = sum(float(jnp.sum(jnp.square(l.astype(jnp.float32)))) for l in leaves)
        assert gnorm > 0.0

    def test_decode_step(self, arch):
        cfg = get_smoke_config(arch)
        if not cfg.has_decode:
            pytest.skip("encoder-only")
        params = init_params(KEY, model_spec(cfg))
        B, MAX = 2, 32
        cache = init_cache(cfg, B, MAX)
        toks = jax.random.randint(KEY, (B, 8), 0, cfg.vocab)
        logits, cache, _ = forward(params, cfg, tokens=toks, cache=cache, cache_index=jnp.asarray(0))
        tok = jnp.argmax(logits[:, -1:, : cfg.vocab], -1)
        logits, cache, _ = forward(params, cfg, tokens=tok, cache=cache, cache_index=jnp.asarray(8))
        assert logits.shape == (B, 1, cfg.padded_vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize(
    "arch", [a for a in ARCHS if get_smoke_config(a).has_decode
             and get_smoke_config(a).input_mode == "tokens"
             and get_smoke_config(a).family != "moe"]
)
def test_decode_matches_full_forward(arch):
    """KV-cache/SSM-state decode == uncached forward (MoE excluded: capacity
    dropping makes batch-composition-dependent results; covered below)."""
    cfg = get_smoke_config(arch).scaled(dtype=jnp.float32)
    params = init_params(KEY, model_spec(cfg))
    B, S = 2, 17
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    full, _, _ = forward(params, cfg, tokens=toks)
    cache = init_cache(cfg, B, 32)
    _, cache, _ = forward(params, cfg, tokens=toks[:, :16], cache=cache, cache_index=jnp.asarray(0))
    dec, _, _ = forward(params, cfg, tokens=toks[:, 16:17], cache=cache, cache_index=jnp.asarray(16))
    a = np.asarray(full[:, 16, : cfg.vocab], np.float32)
    b = np.asarray(dec[:, 0, : cfg.vocab], np.float32)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 2e-3, f"{arch} decode mismatch {err}"


@pytest.mark.parametrize("arch", ["qwen3-moe-235b-a22b", "llama4-scout-17b-a16e"])
def test_moe_decode_matches_with_dropless_capacity(arch):
    cfg = get_smoke_config(arch)
    cfg = cfg.scaled(dtype=jnp.float32, capacity_factor=float(cfg.n_experts) / cfg.top_k)
    params = init_params(KEY, model_spec(cfg))
    B, S = 2, 17
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    full, _, _ = forward(params, cfg, tokens=toks)
    cache = init_cache(cfg, B, 32)
    _, cache, _ = forward(params, cfg, tokens=toks[:, :16], cache=cache, cache_index=jnp.asarray(0))
    dec, _, _ = forward(params, cfg, tokens=toks[:, 16:17], cache=cache, cache_index=jnp.asarray(16))
    a = np.asarray(full[:, 16, : cfg.vocab], np.float32)
    b = np.asarray(dec[:, 0, : cfg.vocab], np.float32)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 2e-3


def test_full_param_counts_match_published_sizes():
    expected = {
        "mamba2-130m": (0.10, 0.17),
        "minicpm3-4b": (3.8, 4.3),
        "qwen3-0.6b": (0.55, 0.65),
        "command-r-plus-104b": (98, 110),
        "phi4-mini-3.8b": (3.5, 4.2),
        "pixtral-12b": (11, 13),
        "hubert-xlarge": (0.9, 1.4),
        "zamba2-1.2b": (0.9, 1.4),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo}, {hi}]"
    # MoE: total and ACTIVE
    qw = get_config("qwen3-moe-235b-a22b")
    assert 220 <= qw.param_count() / 1e9 <= 250
    assert 18 <= qw.active_param_count() / 1e9 <= 26
    ll = get_config("llama4-scout-17b-a16e")
    assert 95 <= ll.param_count() / 1e9 <= 115
    assert 13 <= ll.active_param_count() / 1e9 <= 20


def test_cell_support_matrix():
    """The assignment's 40 cells: 31 runnable + 9 documented skips."""
    runnable = skipped = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, why = cell_supported(cfg, shape)
            if ok:
                runnable += 1
            else:
                skipped += 1
                assert why
    assert runnable == 31
    assert skipped == 9


def test_losses_start_near_log_vocab():
    for arch in ("qwen3-0.6b", "mamba2-130m", "hubert-xlarge"):
        cfg = get_smoke_config(arch)
        params = init_params(KEY, model_spec(cfg))
        loss, _ = train_loss(params, cfg, _batch(cfg))
        assert abs(float(loss) - np.log(cfg.vocab)) < 0.5
