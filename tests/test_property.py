"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import (
    AdaptiveReplication,
    CreditSystem,
    ExponentialBackoff,
    InstanceOutcome,
    InstanceState,
    JobInstance,
    LinearBoundedAllocator,
    check_set,
    fuzzy_comparator,
    next_id,
    reset_ids,
)
from repro.data.pipeline import DataConfig, make_batch


def _inst(output):
    return JobInstance(
        id=next_id("instance"),
        job_id=1,
        state=InstanceState.OVER,
        outcome=InstanceOutcome.SUCCESS,
        output=output,
    )


# ---------------------------------------------------------------------------
# validator invariants (§3.4)
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(
    outputs=st.lists(st.integers(min_value=0, max_value=3), min_size=1, max_size=9),
    quorum=st.integers(min_value=1, max_value=4),
)
def test_quorum_requires_min_agreeing_group(outputs, quorum):
    """Canonical exists iff some value occurs >= min_quorum times, and the
    canonical instance always belongs to (one of) the largest groups."""
    reset_ids()
    insts = [_inst(float(o)) for o in outputs]
    counts = {v: outputs.count(v) for v in set(outputs)}
    best = max(counts.values())
    r = check_set(insts, None, quorum)
    if best >= quorum:
        assert r.canonical is not None
        assert counts[int(r.canonical.output)] == best or counts[int(r.canonical.output)] >= quorum
        # valid/invalid partition the successes
        assert len(r.valid) + len(r.invalid) == len(insts)
        # every valid instance agrees with the canonical
        for v in r.valid:
            assert v.output == r.canonical.output
    else:
        assert r.canonical is None


@settings(max_examples=40, deadline=None)
@given(
    base=st.floats(min_value=-1e3, max_value=1e3, allow_nan=False),
    scale=st.floats(min_value=1e-9, max_value=1e-6),
)
def test_fuzzy_comparator_tolerates_small_noise(base, scale):
    cmp = fuzzy_comparator(rtol=1e-4, atol=1e-6)
    a = np.full(64, base, dtype=np.float64)
    b = a + scale * max(abs(base), 1.0) * 0.01
    assert cmp(a, b)


# ---------------------------------------------------------------------------
# adaptive replication (§3.4): malicious hosts never hold reputation
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    events=st.lists(st.booleans(), min_size=1, max_size=200),
    threshold=st.integers(min_value=1, max_value=20),
)
def test_reputation_resets_on_any_invalid(events, threshold):
    ar = AdaptiveReplication(threshold=threshold, seed=1)
    run = 0
    for ok in events:
        if ok:
            ar.on_validated(1, 1)
            run += 1
        else:
            ar.on_invalid(1, 1)
            run = 0
        assert ar.reputation(1, 1) == run
        p = ar.replication_probability(1, 1)
        assert 0.0 < p <= 1.0
        if run <= threshold:
            assert p == 1.0  # below threshold: always replicate


@settings(max_examples=20, deadline=None)
@given(n_valid=st.integers(min_value=0, max_value=10_000))
def test_replication_probability_monotone_decreasing(n_valid):
    ar = AdaptiveReplication(threshold=10)
    for _ in range(n_valid):
        ar.on_validated(2, 2)
    p1 = ar.replication_probability(2, 2)
    ar.on_validated(2, 2)
    assert ar.replication_probability(2, 2) <= p1


# ---------------------------------------------------------------------------
# check_set partition invariant (§3.4/§4)
# ---------------------------------------------------------------------------


@settings(max_examples=80, deadline=None)
@given(
    outputs=st.lists(
        st.one_of(
            st.integers(min_value=0, max_value=4).map(float),
            st.floats(min_value=-10, max_value=10, allow_nan=False),
        ),
        min_size=0,
        max_size=10,
    ),
    quorum=st.integers(min_value=1, max_value=5),
    fuzzy=st.booleans(),
)
def test_check_set_partitions_successes(outputs, quorum, fuzzy):
    """check_set always splits the successes into valid ∪ invalid ∪
    inconclusive (disjoint, exhaustive), with canonical ∈ valid whenever a
    canonical exists — and never anything in both valid and invalid."""
    reset_ids()
    insts = [_inst(o) for o in outputs]
    cmp = fuzzy_comparator(rtol=1e-9, atol=1e-9) if fuzzy else None
    r = check_set(insts, cmp, quorum)
    valid_ids = {i.id for i in r.valid}
    invalid_ids = {i.id for i in r.invalid}
    inconclusive_ids = {i.id for i in r.inconclusive}
    assert not valid_ids & invalid_ids
    assert not valid_ids & inconclusive_ids
    assert not invalid_ids & inconclusive_ids
    assert valid_ids | invalid_ids | inconclusive_ids == {i.id for i in insts}
    if r.canonical is not None:
        assert r.canonical.id in valid_ids
        assert len(r.valid) >= quorum
    else:
        assert not valid_ids and not invalid_ids


# ---------------------------------------------------------------------------
# grant_amount invariants (§7)
# ---------------------------------------------------------------------------


@settings(max_examples=80, deadline=None)
@given(
    claims=st.lists(
        st.floats(min_value=0.0, max_value=1e6), min_size=1, max_size=8
    ),
    seed=st.integers(min_value=0, max_value=999),
)
def test_grant_amount_permutation_invariant_and_bounded(claims, seed):
    """grant_amount is permutation-invariant and bounded by the [min, max]
    of the surviving (non-negative) claims — zero claims included."""
    import random as _random

    granted = CreditSystem.grant_amount(claims)
    shuffled = list(claims)
    _random.Random(seed).shuffle(shuffled)
    assert CreditSystem.grant_amount(shuffled) == granted
    assert min(claims) <= granted <= max(claims)


@settings(max_examples=40, deadline=None)
@given(
    claims=st.lists(
        st.floats(min_value=0.0, max_value=1e6), min_size=1, max_size=8
    ),
    sentinels=st.lists(
        st.floats(min_value=-1e6, max_value=-1e-9), min_size=0, max_size=4
    ),
)
def test_grant_amount_ignores_negative_sentinels(claims, sentinels):
    assert CreditSystem.grant_amount(claims + sentinels) == \
        CreditSystem.grant_amount(claims)


# ---------------------------------------------------------------------------
# linear-bounded allocation (§3.9)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    debits=st.lists(
        st.tuples(st.floats(min_value=0.1, max_value=100.0), st.floats(min_value=0.0, max_value=10.0)),
        min_size=1,
        max_size=50,
    )
)
def test_balance_never_exceeds_cap(debits):
    alloc = LinearBoundedAllocator(default_rate=1.0, default_cap=100.0)
    alloc.add_account("x", now=0.0)
    t = 0.0
    for dt, amount in debits:
        t += dt
        assert alloc.balance("x", t) <= 100.0 + 1e-9
        alloc.debit("x", amount, t)


# ---------------------------------------------------------------------------
# backoff monotonicity (§2.2)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(n_failures=st.integers(min_value=1, max_value=30))
def test_backoff_never_exceeds_max(n_failures):
    b = ExponentialBackoff(min_interval=10, max_interval=500, jitter=0.0)
    for _ in range(n_failures):
        b.register_failure(0.0)
    assert 10 <= b.current_interval() <= 500


# ---------------------------------------------------------------------------
# credit outlier robustness (§7)
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    honest=st.lists(st.floats(min_value=1.0, max_value=2.0), min_size=2, max_size=6),
    cheat=st.floats(min_value=100.0, max_value=1e6),
)
def test_grant_bounded_by_honest_claims(honest, cheat):
    granted = CreditSystem.grant_amount(honest + [cheat])
    assert granted <= max(honest) * 1.0 + max(honest)  # cheater can't inflate much
    assert granted >= min(honest) * 0.5


# ---------------------------------------------------------------------------
# data pipeline determinism (replication validation soundness)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    shard=st.integers(min_value=0, max_value=7),
    step=st.integers(min_value=0, max_value=1000),
)
def test_batches_deterministic_and_stream_distinct(shard, step):
    cfg = DataConfig(vocab=128, seq_len=16, batch_size=2, seed=5)
    a = make_batch(cfg, shard, step)
    b = make_batch(cfg, shard, step)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    c = make_batch(cfg, shard, step + 1)
    assert any(not np.array_equal(a[k], c[k]) for k in a)
